//! Deterministic exponential backoff with seeded full jitter.
//!
//! This is the one sanctioned wait-before-retry helper: the
//! `sleep_outside_backoff` lint rule bans raw `thread::sleep` everywhere
//! outside `fault/`, so every retry delay in the tree flows through here
//! and is (a) bounded, (b) jittered to avoid retry stampedes, and
//! (c) reproducible from a seed — the jitter stream is SplitMix64, so a
//! rerun with the same seed schedules the same delays.
//!
//! The coordinator does not *sleep* on this: it converts [`Backoff::
//! delay_ms`] into a due-time on the delayed job queue so the leader's
//! event loop keeps draining. [`Backoff::sleep`] exists for call sites
//! that genuinely have nothing else to do (e.g. the leader-side shard
//! write retry).

use crate::util::rng::splitmix64;
use std::time::Duration;

/// Default first-retry delay.
pub const DEFAULT_BASE_MS: u64 = 25;
/// Default delay ceiling.
pub const DEFAULT_CAP_MS: u64 = 2_000;

/// Seeded exponential-backoff delay generator (full jitter).
#[derive(Clone, Debug)]
pub struct Backoff {
    base_ms: u64,
    cap_ms: u64,
    state: u64,
}

impl Backoff {
    pub fn new(seed: u64) -> Backoff {
        Backoff::with_limits(seed, DEFAULT_BASE_MS, DEFAULT_CAP_MS)
    }

    pub fn with_limits(seed: u64, base_ms: u64, cap_ms: u64) -> Backoff {
        Backoff {
            base_ms: base_ms.max(1),
            cap_ms: cap_ms.max(1),
            state: seed ^ 0xBAC0FF,
        }
    }

    /// Delay before retry number `attempt` (1 = first retry), in
    /// milliseconds: uniform over `[0, min(cap, base · 2^(attempt-1))]`
    /// ("full jitter"), drawn from the deterministic seeded stream.
    pub fn delay_ms(&mut self, attempt: u32) -> u64 {
        let ceiling = self
            .base_ms
            .checked_shl(attempt.saturating_sub(1).min(32))
            .unwrap_or(self.cap_ms)
            .min(self.cap_ms);
        splitmix64(&mut self.state) % (ceiling + 1)
    }

    /// Sleep for the next delay; returns the slept milliseconds.
    pub fn sleep(&mut self, attempt: u32) -> u64 {
        let ms = self.delay_ms(attempt);
        if ms > 0 {
            std::thread::sleep(Duration::from_millis(ms));
        }
        ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_are_bounded_and_grow_with_attempt() {
        let mut b = Backoff::with_limits(7, 10, 1_000);
        for attempt in 1..=12u32 {
            let ceiling = 10u64.checked_shl(attempt - 1).unwrap_or(1_000).min(1_000);
            for _ in 0..50 {
                assert!(b.delay_ms(attempt) <= ceiling, "attempt {attempt}");
            }
        }
    }

    #[test]
    fn same_seed_same_schedule() {
        let schedule = |seed| {
            let mut b = Backoff::new(seed);
            (1..=8).map(|a| b.delay_ms(a)).collect::<Vec<u64>>()
        };
        assert_eq!(schedule(3), schedule(3));
        assert_ne!(schedule(3), schedule(4), "different seeds should differ");
    }

    #[test]
    fn huge_attempt_does_not_overflow() {
        let mut b = Backoff::with_limits(1, 100, 500);
        assert!(b.delay_ms(u32::MAX) <= 500);
    }
}
