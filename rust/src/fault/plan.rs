//! Fault-plan grammar and evaluation.
//!
//! A plan is a `;`-separated list of entries, each arming one fault
//! point with selectors and an action:
//!
//! ```text
//! plan     := entry (';' entry)*
//! entry    := point ':' action
//!           | point ':' selectors ':' action
//! selectors:= sel (',' sel)*           (empty list allowed)
//! sel      := part=N | attempt=N | p=F | seed=N | times=N
//! action   := fail | delay(MS) | corrupt
//! ```
//!
//! Examples:
//!
//! ```text
//! worker.train:part=3,attempt=0:fail
//! shard.read:p=0.05,seed=7:corrupt
//! runtime.init:times=1:delay(250)
//! ```
//!
//! Selector semantics, applied in order per firing:
//!
//! * `part` / `attempt` — fire only when the instrumented site supplies
//!   a matching context value (a site without that context never
//!   matches the selector);
//! * `p` — fire with probability `p`, drawn from a per-entry
//!   deterministic stream (`seed` pins the stream; default derives from
//!   the entry's position in the plan);
//! * `times` — fire at most N times over the process lifetime
//!   (probability misses do not count).
//!
//! The first entry that matches and fires wins; later entries are not
//! consulted for that firing. Parsing validates point names against
//! [`super::FAULT_POINTS`] so a typo is a config error, not a silently
//! inert plan; programmatic construction ([`FaultPlan::new`]) skips that
//! check for tests that use synthetic point names.

use crate::error::{Error, Result};
use crate::util::rng::splitmix64;

/// What an armed fault point does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Return an injected error from the instrumented operation.
    Fail,
    /// Stall the operation for this many milliseconds, then proceed.
    Delay(u64),
    /// Deterministically damage the operation's data (sites without a
    /// corruptible payload treat this as [`Action::Fail`]).
    Corrupt,
}

impl Action {
    fn parse(text: &str) -> Result<Action> {
        let text = text.trim();
        match text {
            "fail" => return Ok(Action::Fail),
            "corrupt" => return Ok(Action::Corrupt),
            _ => {}
        }
        if let Some(rest) = text.strip_prefix("delay(") {
            if let Some(ms) = rest.strip_suffix(')') {
                let ms: u64 = ms.trim().parse().map_err(|_| {
                    Error::Config(format!("fault plan: bad delay millis {ms:?}"))
                })?;
                return Ok(Action::Delay(ms));
            }
        }
        Err(Error::Config(format!(
            "fault plan: unknown action {text:?} (expected fail | delay(ms) | corrupt)"
        )))
    }
}

/// One armed fault point.
#[derive(Clone, Debug)]
pub struct PlanEntry {
    pub point: String,
    pub part: Option<u32>,
    pub attempt: Option<u32>,
    pub p: Option<f64>,
    pub times: Option<u32>,
    pub action: Action,
    /// Seed of the per-entry probability/salt stream.
    pub seed: u64,
    /// Probability-draw state (advances on every selector-matched
    /// evaluation, hit or miss, so draws stay reproducible).
    draw_state: u64,
    /// Times this entry has fired.
    hits: u32,
}

impl PlanEntry {
    /// Arm `point` with `action` and no selectors (always fires).
    pub fn new(point: &str, action: Action) -> PlanEntry {
        PlanEntry {
            point: point.to_string(),
            part: None,
            attempt: None,
            p: None,
            times: None,
            action,
            seed: 0,
            draw_state: 0,
            hits: 0,
        }
    }

    pub fn part(mut self, part: u32) -> PlanEntry {
        self.part = Some(part);
        self
    }

    pub fn attempt(mut self, attempt: u32) -> PlanEntry {
        self.attempt = Some(attempt);
        self
    }

    pub fn times(mut self, times: u32) -> PlanEntry {
        self.times = Some(times);
        self
    }

    pub fn probability(mut self, p: f64, seed: u64) -> PlanEntry {
        self.p = Some(p);
        self.seed = seed;
        self.draw_state = seed;
        self
    }

    fn parse(text: &str, index: usize) -> Result<PlanEntry> {
        let segments: Vec<&str> = text.split(':').collect();
        let (point, selectors, action) = match segments.as_slice() {
            [point, action] => (point.trim(), "", action.trim()),
            [point, selectors, action] => (point.trim(), selectors.trim(), action.trim()),
            _ => {
                return Err(Error::Config(format!(
                    "fault plan entry {text:?}: expected point[:selectors]:action"
                )))
            }
        };
        if point.is_empty() {
            return Err(Error::Config(format!("fault plan entry {text:?}: empty point")));
        }
        let mut entry = PlanEntry::new(point, Action::parse(action)?);
        // default seed: distinct per entry position, stable across runs
        entry.seed = 0x5EED ^ (index as u64);
        for sel in selectors.split(',') {
            let sel = sel.trim();
            if sel.is_empty() {
                continue;
            }
            let (key, value) = sel.split_once('=').ok_or_else(|| {
                Error::Config(format!("fault plan selector {sel:?}: expected key=value"))
            })?;
            let bad = |what: &str| {
                Error::Config(format!("fault plan selector {sel:?}: bad {what}"))
            };
            match key.trim() {
                "part" => entry.part = Some(value.trim().parse().map_err(|_| bad("part"))?),
                "attempt" => {
                    entry.attempt = Some(value.trim().parse().map_err(|_| bad("attempt"))?)
                }
                "times" => entry.times = Some(value.trim().parse().map_err(|_| bad("times"))?),
                "seed" => entry.seed = value.trim().parse().map_err(|_| bad("seed"))?,
                "p" => {
                    let p: f64 = value.trim().parse().map_err(|_| bad("probability"))?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(bad("probability (must be in [0, 1])"));
                    }
                    entry.p = Some(p);
                }
                other => {
                    return Err(Error::Config(format!(
                        "fault plan selector {other:?}: unknown key \
                         (expected part | attempt | p | seed | times)"
                    )))
                }
            }
        }
        entry.draw_state = entry.seed;
        Ok(entry)
    }

    /// Whether this entry fires for a `(point, part, attempt)` firing.
    /// Advances internal probability/hit state.
    fn fires(&mut self, point: &str, part: Option<u32>, attempt: Option<u32>) -> bool {
        if self.point != point {
            return false;
        }
        if let Some(want) = self.part {
            if part != Some(want) {
                return false;
            }
        }
        if let Some(want) = self.attempt {
            if attempt != Some(want) {
                return false;
            }
        }
        if let Some(limit) = self.times {
            if self.hits >= limit {
                return false;
            }
        }
        if let Some(p) = self.p {
            let draw = splitmix64(&mut self.draw_state) as f64 / (u64::MAX as f64 + 1.0);
            if draw >= p {
                return false;
            }
        }
        self.hits += 1;
        true
    }

    /// Deterministic per-hit salt: corrupt sites derive byte/bit offsets
    /// from it, so the same plan damages the same bytes every run.
    fn salt(&self, part: Option<u32>) -> u64 {
        let mut s = self
            .seed
            .wrapping_add((self.hits as u64) << 32)
            .wrapping_add(part.map(|p| p as u64 + 1).unwrap_or(0));
        splitmix64(&mut s)
    }
}

/// A parsed, stateful fault plan (entry order is match priority).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub entries: Vec<PlanEntry>,
}

impl FaultPlan {
    pub fn new(entries: Vec<PlanEntry>) -> FaultPlan {
        FaultPlan { entries }
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Parse a spec string, validating point names against the
    /// registered [`super::FAULT_POINTS`].
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut entries = Vec::new();
        for (index, text) in spec.split(';').enumerate() {
            let text = text.trim();
            if text.is_empty() {
                continue;
            }
            let entry = PlanEntry::parse(text, index)?;
            if !super::FAULT_POINTS.contains(&entry.point.as_str()) {
                return Err(Error::Config(format!(
                    "fault plan: unknown fault point {:?} (registered: {})",
                    entry.point,
                    super::FAULT_POINTS.join(", ")
                )));
            }
            entries.push(entry);
        }
        Ok(FaultPlan { entries })
    }

    /// Evaluate a firing; the first matching entry wins. Returns the
    /// action plus the deterministic corruption salt.
    pub fn evaluate(
        &mut self,
        point: &str,
        part: Option<u32>,
        attempt: Option<u32>,
    ) -> Option<(Action, u64)> {
        for entry in &mut self.entries {
            if entry.fires(point, part, attempt) {
                return Some((entry.action, entry.salt(part)));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let plan = FaultPlan::parse(
            "worker.train:part=3,attempt=0:fail; shard.read:p=0.05,seed=7:corrupt",
        )
        .unwrap();
        assert_eq!(plan.entries.len(), 2);
        assert_eq!(plan.entries[0].point, "worker.train");
        assert_eq!(plan.entries[0].part, Some(3));
        assert_eq!(plan.entries[0].attempt, Some(0));
        assert_eq!(plan.entries[0].action, Action::Fail);
        assert_eq!(plan.entries[1].point, "shard.read");
        assert_eq!(plan.entries[1].p, Some(0.05));
        assert_eq!(plan.entries[1].seed, 7);
        assert_eq!(plan.entries[1].action, Action::Corrupt);
    }

    #[test]
    fn parses_delay_and_times() {
        let plan = FaultPlan::parse("runtime.init:times=1:delay(250)").unwrap();
        assert_eq!(plan.entries[0].action, Action::Delay(250));
        assert_eq!(plan.entries[0].times, Some(1));
    }

    #[test]
    fn rejects_unknown_point_action_and_selector() {
        assert!(FaultPlan::parse("worker.nope:fail").is_err());
        assert!(FaultPlan::parse("worker.train:explode").is_err());
        assert!(FaultPlan::parse("worker.train:color=red:fail").is_err());
        assert!(FaultPlan::parse("worker.train:p=1.5:fail").is_err());
        assert!(FaultPlan::parse("worker.train:delay(abc)").is_err());
        assert!(FaultPlan::parse("a:b:c:d").is_err());
    }

    #[test]
    fn empty_spec_is_an_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ; ").unwrap().is_empty());
    }

    #[test]
    fn selectors_gate_firing() {
        let mut plan =
            FaultPlan::parse("worker.train:part=1,attempt=0:fail").unwrap();
        assert!(plan.evaluate("worker.train", Some(0), Some(0)).is_none());
        assert!(plan.evaluate("worker.train", Some(1), Some(1)).is_none());
        assert!(plan.evaluate("worker.train", None, None).is_none());
        assert!(plan.evaluate("shard.read", Some(1), Some(0)).is_none());
        let (action, _) = plan.evaluate("worker.train", Some(1), Some(0)).unwrap();
        assert_eq!(action, Action::Fail);
    }

    #[test]
    fn times_caps_total_fires() {
        let mut plan = FaultPlan::parse("worker.train:times=2:fail").unwrap();
        assert!(plan.evaluate("worker.train", Some(0), Some(0)).is_some());
        assert!(plan.evaluate("worker.train", Some(1), Some(0)).is_some());
        assert!(plan.evaluate("worker.train", Some(2), Some(0)).is_none());
    }

    #[test]
    fn probability_stream_is_deterministic() {
        let run = || {
            let mut plan = FaultPlan::parse("shard.read:p=0.5,seed=42:corrupt").unwrap();
            (0..64)
                .map(|i| plan.evaluate("shard.read", Some(i), None).is_some())
                .collect::<Vec<bool>>()
        };
        let a = run();
        assert_eq!(a, run(), "same seed must reproduce the same fire pattern");
        assert!(a.iter().any(|&f| f) && !a.iter().all(|&f| f), "p=0.5 mixes");
    }

    #[test]
    fn first_matching_entry_wins() {
        let mut plan =
            FaultPlan::parse("worker.train:part=0:corrupt; worker.train:fail").unwrap();
        let (a0, _) = plan.evaluate("worker.train", Some(0), None).unwrap();
        assert_eq!(a0, Action::Corrupt);
        let (a1, _) = plan.evaluate("worker.train", Some(1), None).unwrap();
        assert_eq!(a1, Action::Fail);
    }

    #[test]
    fn salts_are_stable_per_plan() {
        let salt = || {
            let mut plan = FaultPlan::parse("shard.read:seed=9:corrupt").unwrap();
            plan.evaluate("shard.read", Some(3), None).map(|(_, s)| s)
        };
        assert_eq!(salt(), salt());
    }

    #[test]
    fn programmatic_entries_allow_synthetic_points() {
        let mut plan = FaultPlan::new(vec![
            PlanEntry::new("test.alpha", Action::Fail).times(1),
        ]);
        assert!(plan.evaluate("test.alpha", None, None).is_some());
        assert!(plan.evaluate("test.alpha", None, None).is_none());
    }
}
