//! faultkit — deterministic fault injection for the training/serving
//! pipeline, in the style of `obs/`: **off by default, one relaxed
//! atomic load when disabled**.
//!
//! Production code marks its failure domains with named *fault points*:
//!
//! ```ignore
//! if let Some(inj) = fault::point("worker.train").part(job.part_id).attempt(job.attempt).fire() {
//!     return Err(inj.error());
//! }
//! ```
//!
//! A seeded [`FaultPlan`] (parsed from `--fault-plan` / `[fault] plan`,
//! see [`plan`] for the grammar) arms points with `fail`, `delay(ms)`,
//! or `corrupt` actions. `delay` is served inside [`Point::fire`] (the
//! call site never sees it); `fail` and `corrupt` come back as an
//! [`Injection`] for the site to act on — corruption sites derive the
//! damaged byte/bit deterministically from [`Injection::salt`], so a
//! given plan+seed damages the same bytes every run.
//!
//! Every `fault::point("…")` literal must be declared in
//! [`FAULT_POINTS`]; the `undeclared_fault_point` lint rule enforces it
//! (mirroring the CLI `SWITCHES` registry), so the chaos sweep in
//! nightly CI provably covers every point.
//!
//! Firings are counted in the PR 6 registry (`fault.injected`) and
//! emitted as trace events, so a chaos run's timeline shows exactly
//! where faults landed.

pub mod backoff;
pub mod plan;

pub use backoff::Backoff;
pub use plan::{Action, FaultPlan, PlanEntry};

use crate::obs;
use crate::util::json::{num, s};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Registered fault points — the instrumented failure domains:
/// per-machine PJRT client creation, batch assembly, partition
/// training, shard write (leader), shard read (serving), shard
/// manifest load, the four wire-level domains of the TCP transport
/// (connection accept, connection dial, frame send, frame receive),
/// and the three serving-platform domains (HTTP connection accept,
/// bundle publish, bundle hot-swap).
/// Every `fault::point("x")` literal in library code must appear here
/// (`undeclared_fault_point` lint rule).
pub const FAULT_POINTS: &[&str] = &[
    "runtime.init",
    "worker.batch",
    "worker.train",
    "shard.write",
    "shard.read",
    "manifest.load",
    "net.accept",
    "net.connect",
    "net.send",
    "net.recv",
    "http.accept",
    "bundle.publish",
    "bundle.swap",
];

/// Fast-path gate: when false (the default), [`Point::fire`] is a single
/// relaxed load and nothing else.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// The installed plan. Only locked on the slow path (faults enabled).
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);

/// Serializes scoped installs (tests): one plan owner at a time.
static SCOPE: Mutex<()> = Mutex::new(());

fn plan_slot() -> MutexGuard<'static, Option<FaultPlan>> {
    // the slot only ever holds a complete plan — poison (a panicked
    // holder) cannot leave it mid-update, so recovery is safe
    PLAN.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Install a plan process-wide (CLI path; stays until [`clear`]).
/// An empty plan leaves injection disabled.
pub fn install(plan: FaultPlan) {
    let enable = !plan.is_empty();
    *plan_slot() = Some(plan);
    ENABLED.store(enable, Ordering::Relaxed);
}

/// Disarm all fault points and drop the plan.
pub fn clear() {
    ENABLED.store(false, Ordering::Relaxed);
    *plan_slot() = None;
}

/// Whether a plan is currently armed.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// RAII guard for a scoped plan install: holds the global scope lock
/// (serializing concurrent installers — parallel tests queue instead of
/// clobbering each other) and disarms on drop.
pub struct PlanGuard {
    _scope: MutexGuard<'static, ()>,
}

impl Drop for PlanGuard {
    fn drop(&mut self) {
        clear();
    }
}

/// Install a plan for the lifetime of the returned guard. Tests use
/// this; concurrent callers serialize on a global lock.
pub fn install_scoped(plan: FaultPlan) -> PlanGuard {
    let scope = SCOPE.lock().unwrap_or_else(PoisonError::into_inner);
    install(plan);
    PlanGuard { _scope: scope }
}

/// Exclusive fault-free section: takes the scope lock with no plan
/// armed, so a fault-sensitive integration test can't be perturbed by a
/// concurrently installed plan.
pub fn exclusive() -> PlanGuard {
    install_scoped(FaultPlan::default())
}

/// A fault point firing under construction (name + optional context).
#[must_use = "a fault point does nothing until fire() is called"]
pub struct Point {
    name: &'static str,
    part: Option<u32>,
    attempt: Option<u32>,
}

/// Mark a fault point. Returns a builder; attach context with
/// [`Point::part`] / [`Point::attempt`], then call [`Point::fire`].
#[inline]
pub fn point(name: &'static str) -> Point {
    Point { name, part: None, attempt: None }
}

impl Point {
    #[inline]
    pub fn part(mut self, part: u32) -> Point {
        self.part = Some(part);
        self
    }

    #[inline]
    pub fn attempt(mut self, attempt: u32) -> Point {
        self.attempt = Some(attempt);
        self
    }

    /// Evaluate this firing against the installed plan. Disabled path:
    /// one relaxed atomic load. `delay` actions are served here
    /// (transparent to the caller); `fail`/`corrupt` are returned.
    #[inline]
    pub fn fire(self) -> Option<Injection> {
        if !ENABLED.load(Ordering::Relaxed) {
            return None;
        }
        self.fire_slow()
    }

    #[cold]
    fn fire_slow(self) -> Option<Injection> {
        let outcome = plan_slot()
            .as_mut()
            .and_then(|p| p.evaluate(self.name, self.part, self.attempt));
        let (action, salt) = outcome?;
        obs::registry().counter("fault.injected").inc();
        obs::event(
            "fault",
            "injected",
            vec![
                ("point", s(self.name)),
                ("action", s(match action {
                    Action::Fail => "fail",
                    Action::Delay(_) => "delay",
                    Action::Corrupt => "corrupt",
                })),
                ("part", num(self.part.map(|p| p as f64).unwrap_or(-1.0))),
                ("attempt", num(self.attempt.map(|a| a as f64).unwrap_or(-1.0))),
            ],
        );
        log::warn!(
            "fault injected at {} (part {:?}, attempt {:?}): {:?}",
            self.name,
            self.part,
            self.attempt,
            action
        );
        match action {
            Action::Delay(ms) => {
                // served here so every instrumented site gets delay
                // support for free; the lock is already released
                std::thread::sleep(Duration::from_millis(ms));
                None
            }
            Action::Fail => Some(Injection { point: self.name, action: Action::Fail, salt }),
            Action::Corrupt => {
                Some(Injection { point: self.name, action: Action::Corrupt, salt })
            }
        }
    }
}

/// A fired `fail` or `corrupt` injection, handed to the call site.
#[derive(Clone, Copy, Debug)]
pub struct Injection {
    pub point: &'static str,
    pub action: Action,
    /// Deterministic per-hit salt for corruption offsets.
    pub salt: u64,
}

impl Injection {
    /// The error an injected failure surfaces as (classified transient —
    /// injected faults model recoverable machine failures).
    pub fn error(&self) -> crate::error::Error {
        crate::error::Error::Fault(format!("injected fault at {}", self.point))
    }

    /// Whether this injection asks the site to damage data (sites with
    /// no corruptible payload treat `corrupt` as `fail`).
    pub fn is_corrupt(&self) -> bool {
        self.action == Action::Corrupt
    }

    /// Deterministic offset in `[0, n)` derived from the salt — used to
    /// pick the damaged byte/bit. Returns 0 for `n == 0`.
    pub fn offset(&self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        let mut state = self.salt;
        (crate::util::rng::splitmix64(&mut state) % n as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_points_are_inert() {
        // no scoped plan held here: whatever other tests do, this
        // synthetic name is never armed by them
        assert!(point("test.mod.inert").fire().is_none());
    }

    #[test]
    fn scoped_install_fires_and_disarms() {
        let salt_offset;
        {
            let _g = install_scoped(FaultPlan::new(vec![
                PlanEntry::new("test.mod.scoped", Action::Corrupt).times(1),
            ]));
            assert!(enabled());
            let inj = point("test.mod.scoped").part(2).fire().unwrap();
            assert!(inj.is_corrupt());
            salt_offset = inj.offset(1000);
            assert!(point("test.mod.scoped").part(2).fire().is_none(), "times=1");
        }
        assert!(!enabled(), "guard drop must disarm");
        assert!(point("test.mod.scoped").part(2).fire().is_none());
        assert!(salt_offset < 1000);
    }

    #[test]
    fn injected_error_is_transient() {
        let _g = install_scoped(FaultPlan::new(vec![PlanEntry::new(
            "test.mod.transient",
            Action::Fail,
        )]));
        let err = point("test.mod.transient").fire().unwrap().error();
        assert!(err.is_transient());
        assert!(err.to_string().contains("test.mod.transient"));
    }

    #[test]
    fn delay_is_served_internally() {
        let _g = install_scoped(FaultPlan::new(vec![
            PlanEntry::new("test.mod.delay", Action::Delay(1)).times(1),
        ]));
        let sw = crate::util::Stopwatch::start();
        assert!(point("test.mod.delay").fire().is_none(), "delay is transparent");
        assert!(sw.millis() >= 1.0);
    }

    #[test]
    fn empty_plan_does_not_enable() {
        let _g = install_scoped(FaultPlan::default());
        assert!(!enabled());
    }

    #[test]
    fn registered_points_parse() {
        for p in FAULT_POINTS {
            assert!(
                FaultPlan::parse(&format!("{p}:fail")).is_ok(),
                "{p} must be armable"
            );
        }
    }
}
