//! Random partitioner — the simplest baseline (§3.1): uniform assignment.
//! Perfect expected load balance, terrible locality.

use super::{Partitioner, Partitioning};
use crate::error::Result;
use crate::graph::CsrGraph;
use crate::util::rng::Rng;

pub struct RandomPartitioner {
    pub seed: u64,
}

impl RandomPartitioner {
    pub fn new(seed: u64) -> Self {
        RandomPartitioner { seed }
    }
}

impl Partitioner for RandomPartitioner {
    fn name(&self) -> &'static str {
        "random"
    }

    fn partition(&self, g: &CsrGraph, k: usize) -> Result<Partitioning> {
        let mut rng = Rng::new(self.seed);
        // round-robin over a shuffled order: uniform *and* exactly balanced
        let n = g.num_nodes();
        let mut order: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut order);
        let mut assign = vec![0u32; n];
        for (i, &v) in order.iter().enumerate() {
            assign[v as usize] = (i % k) as u32;
        }
        Partitioning::new(assign, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate::karate_graph;

    #[test]
    fn produces_k_balanced_parts() {
        let g = karate_graph();
        let p = RandomPartitioner::new(3).partition(&g, 4).unwrap();
        assert_eq!(p.k(), 4);
        let sizes = p.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 34);
        assert!(sizes.iter().all(|&s| s == 8 || s == 9), "{sizes:?}");
    }

    #[test]
    fn deterministic_per_seed() {
        let g = karate_graph();
        let a = RandomPartitioner::new(1).partition(&g, 2).unwrap();
        let b = RandomPartitioner::new(1).partition(&g, 2).unwrap();
        let c = RandomPartitioner::new(2).partition(&g, 2).unwrap();
        assert_eq!(a.assignments(), b.assignments());
        assert_ne!(a.assignments(), c.assignments());
    }

    #[test]
    fn k_one_is_trivial() {
        let g = karate_graph();
        let p = RandomPartitioner::new(0).partition(&g, 1).unwrap();
        assert!(p.assignments().iter().all(|&x| x == 0));
    }
}
