//! `PartitionPipeline` — the staged executor behind every partitioning
//! run: `detect → [fuse] → [balance] → validate`, built from a
//! [`PartitionSpec`].
//!
//! Each stage is a trait object with a name and its own wall-clock
//! timing; an observer callback streams per-stage progress events (the
//! same pattern the coordinator reuses for training progress). The
//! pipeline returns a [`PartitionReport`] bundling the final
//! [`Partitioning`], per-stage timings, and a lazily-computed
//! [`PartitionQuality`], so call sites stop recomputing metrics ad-hoc.

use super::fusion::{fuse_communities_threaded, split_into_components, FusionConfig};
use super::leiden::{leiden, LeidenConfig};
use super::louvain::{louvain, LouvainConfig};
use super::lpa::LpaPartitioner;
use super::metis::MetisPartitioner;
use super::quality::PartitionQuality;
use super::random::RandomPartitioner;
use super::spec::{
    PartitionSpec, StageSpec, DEFAULT_ALPHA, DEFAULT_BALANCE_SLACK, DEFAULT_GAMMA,
    DEFAULT_IMBALANCE, DEFAULT_LPA_ITERS, DEFAULT_LPA_SLACK, DEFAULT_THETA,
};
use super::{Partitioner, Partitioning};
use crate::error::{Error, Result};
use crate::graph::{is_connected, CsrGraph};
use crate::obs;
use crate::util::json;
use crate::util::{fmt_duration, Stopwatch};
use std::cell::OnceCell;

/// Context shared by every stage of one pipeline run.
pub struct StageCtx<'a> {
    pub graph: &'a CsrGraph,
    /// Target partition count.
    pub k: usize,
    pub seed: u64,
    /// Worker threads available to parallel-capable stages (≥ 1). The
    /// determinism contract (DESIGN.md "Performance") guarantees the
    /// partitioning is identical for every value.
    pub threads: usize,
}

/// One pipeline stage. Detection stages ignore `input`; transform stages
/// require it.
pub trait Stage {
    /// Stage name (appears in progress events and `PartitionReport`).
    fn name(&self) -> &'static str;

    /// Produce or refine a partitioning.
    fn run(&self, ctx: &StageCtx, input: Option<Partitioning>) -> Result<Partitioning>;
}

/// Progress event streamed to the pipeline observer.
#[derive(Debug)]
pub enum PipelineEvent<'a> {
    PipelineStarted {
        spec: &'a PartitionSpec,
        k: usize,
        num_stages: usize,
    },
    StageStarted {
        index: usize,
        name: &'a str,
    },
    StageFinished {
        index: usize,
        name: &'a str,
        secs: f64,
        /// Partition/community count of the stage's output.
        parts: usize,
        /// The stage's output — lets observers inspect intermediate
        /// results (e.g. the pre-fusion partitioning) without a second
        /// pipeline run.
        output: &'a Partitioning,
    },
}

/// Wall time and output size of one executed stage.
#[derive(Clone, Debug)]
pub struct StageTiming {
    pub name: String,
    pub secs: f64,
    pub parts: usize,
}

/// The pipeline's return value: the partitioning plus everything a bench
/// or subcommand usually recomputes by hand.
#[derive(Clone, Debug)]
pub struct PartitionReport {
    pub spec: PartitionSpec,
    pub partitioning: Partitioning,
    /// Per-stage wall times in execution order.
    pub stages: Vec<StageTiming>,
    quality: OnceCell<PartitionQuality>,
}

impl PartitionReport {
    /// Total partitioning wall time (sum of stage times).
    pub fn total_secs(&self) -> f64 {
        self.stages.iter().map(|s| s.secs).sum()
    }

    /// Wall time of the algorithmic stages only (validation excluded) —
    /// what timing benches should report, since validation cost depends
    /// on the spec's strictness, not the method under test.
    pub fn algorithm_secs(&self) -> f64 {
        self.stages
            .iter()
            .filter(|s| s.name != "validate")
            .map(|s| s.secs)
            .sum()
    }

    /// §5.1 quality metrics, computed on first use and cached. `g` must
    /// be the graph the pipeline ran on — later calls return the cached
    /// metrics regardless of the graph passed.
    pub fn quality(&self, g: &CsrGraph) -> &PartitionQuality {
        debug_assert_eq!(
            g.num_nodes(),
            self.partitioning.num_nodes(),
            "quality() called with a different graph than the pipeline ran on"
        );
        self.quality
            .get_or_init(|| PartitionQuality::measure(g, &self.partitioning))
    }

    pub fn into_partitioning(self) -> Partitioning {
        self.partitioning
    }

    /// One-line human summary, e.g. `leiden 41.2ms + fusion 2.1ms`.
    pub fn stage_summary(&self) -> String {
        let parts: Vec<String> = self
            .stages
            .iter()
            .map(|s| format!("{} {}", s.name, fmt_duration(s.secs)))
            .collect();
        parts.join(" + ")
    }
}

/// The staged partitioning executor.
pub struct PartitionPipeline {
    spec: PartitionSpec,
    seed: u64,
    threads: usize,
    stages: Vec<Box<dyn Stage>>,
}

impl PartitionPipeline {
    /// Build the stage list for `spec`. The spec is already validated by
    /// its parser, so construction cannot fail. Stages run sequentially
    /// within one thread unless [`Self::with_threads`] raises the knob.
    pub fn new(spec: PartitionSpec, seed: u64) -> Self {
        let stages = build_stages(&spec);
        PartitionPipeline { spec, seed, threads: 1, stages }
    }

    /// Parse `spec` (grammar or legacy name) and build the pipeline.
    pub fn parse(spec: &str, seed: u64) -> Result<Self> {
        Ok(Self::new(spec.parse()?, seed))
    }

    /// Set the worker-thread count for parallel-capable stages (Leiden
    /// refinement/aggregation, fusion's boundary scan). `0` is treated as
    /// `1`. Same seed ⇒ byte-identical partitionings for every value.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn spec(&self) -> &PartitionSpec {
        &self.spec
    }

    pub fn stage_names(&self) -> Vec<&'static str> {
        self.stages.iter().map(|s| s.name()).collect()
    }

    /// Run without an observer.
    pub fn run(&self, g: &CsrGraph, k: usize) -> Result<PartitionReport> {
        self.run_observed(g, k, &mut |_| {})
    }

    /// Run, streaming a [`PipelineEvent`] to `observer` around each stage.
    pub fn run_observed(
        &self,
        g: &CsrGraph,
        k: usize,
        observer: &mut dyn FnMut(&PipelineEvent),
    ) -> Result<PartitionReport> {
        if k == 0 {
            return Err(Error::Partition("k must be positive".into()));
        }
        let mut run_span = obs::span("partition", "pipeline");
        if obs::tracing_enabled() {
            run_span.attr("spec", json::s(&self.spec.to_string()));
            run_span.attr("k", json::num(k as f64));
            run_span.attr("nodes", json::num(g.num_nodes() as f64));
            run_span.attr("edges", json::num(g.num_edges() as f64));
            run_span.attr("threads", json::num(self.threads as f64));
        }
        obs::registry().counter("partition.runs").inc();
        let stage_hist = obs::registry().histogram("partition.stage_secs");
        observer(&PipelineEvent::PipelineStarted {
            spec: &self.spec,
            k,
            num_stages: self.stages.len(),
        });
        let ctx = StageCtx { graph: g, k, seed: self.seed, threads: self.threads };
        let mut current: Option<Partitioning> = None;
        let mut timings = Vec::with_capacity(self.stages.len());
        for (index, stage) in self.stages.iter().enumerate() {
            observer(&PipelineEvent::StageStarted { index, name: stage.name() });
            let mut sp = obs::span("partition", stage.name());
            sp.attr("index", json::num(index as f64));
            let sw = Stopwatch::start();
            let next = stage.run(&ctx, current.take())?;
            let secs = sw.secs();
            sp.attr("parts", json::num(next.k() as f64));
            drop(sp);
            stage_hist.record(secs);
            observer(&PipelineEvent::StageFinished {
                index,
                name: stage.name(),
                secs,
                parts: next.k(),
                output: &next,
            });
            timings.push(StageTiming {
                name: stage.name().to_string(),
                secs,
                parts: next.k(),
            });
            current = Some(next);
        }
        let partitioning = current
            .ok_or_else(|| Error::Partition("pipeline has no stages".into()))?;
        Ok(PartitionReport {
            spec: self.spec.clone(),
            partitioning,
            stages: timings,
            quality: OnceCell::new(),
        })
    }
}

/// [`Partitioner`] adapter over a pipeline — what the deprecated
/// [`super::by_name`] shim hands out, and a drop-in for code that still
/// passes trait objects around.
pub struct SpecPartitioner {
    label: String,
    pipeline: PartitionPipeline,
}

impl SpecPartitioner {
    pub fn new(spec: PartitionSpec, seed: u64) -> Self {
        SpecPartitioner {
            label: spec.to_string(),
            pipeline: PartitionPipeline::new(spec, seed),
        }
    }
}

impl Partitioner for SpecPartitioner {
    fn name(&self) -> &str {
        &self.label
    }

    fn partition(&self, g: &CsrGraph, k: usize) -> Result<Partitioning> {
        Ok(self.pipeline.run(g, k)?.into_partitioning())
    }
}

// ---------------------------------------------------------------------------
// stage construction
// ---------------------------------------------------------------------------

fn build_stages(spec: &PartitionSpec) -> Vec<Box<dyn Stage>> {
    // Leiden/Louvain's size cap S = β·max_part_size depends on the fusion
    // stage's α, so wire it across stages here.
    let fusion_alpha = spec.stages().iter().find_map(|s| match s {
        StageSpec::Fusion { alpha } => Some(alpha.unwrap_or(DEFAULT_ALPHA)),
        _ => None,
    });
    // Leiden communities are connected by construction; every other
    // detector needs the component-split pass before fusion (§5.4).
    let detect_is_leiden =
        matches!(spec.stages().first(), Some(StageSpec::Leiden { .. }));

    let mut out: Vec<Box<dyn Stage>> = Vec::new();
    for st in spec.stages() {
        match st {
            StageSpec::Leiden { gamma, beta, theta } => out.push(Box::new(LeidenStage {
                gamma: gamma.unwrap_or(DEFAULT_GAMMA),
                theta: theta.unwrap_or(DEFAULT_THETA),
                cap_beta: *beta,
                cap_alpha: fusion_alpha,
            })),
            StageSpec::Louvain { gamma, beta } => out.push(Box::new(LouvainStage {
                gamma: gamma.unwrap_or(DEFAULT_GAMMA),
                cap_beta: *beta,
                cap_alpha: fusion_alpha,
            })),
            StageSpec::Metis { imbalance } => out.push(Box::new(MetisStage {
                imbalance: imbalance.unwrap_or(DEFAULT_IMBALANCE),
            })),
            StageSpec::Lpa { iters, slack } => out.push(Box::new(LpaStage {
                iters: iters.unwrap_or(DEFAULT_LPA_ITERS),
                slack: slack.unwrap_or(DEFAULT_LPA_SLACK),
            })),
            StageSpec::Random => out.push(Box::new(RandomStage)),
            StageSpec::Fusion { alpha } => out.push(Box::new(FusionStage {
                alpha: alpha.unwrap_or(DEFAULT_ALPHA),
                split: !detect_is_leiden,
            })),
            StageSpec::Balance { slack } => out.push(Box::new(BalanceStage {
                slack: slack.unwrap_or(DEFAULT_BALANCE_SLACK),
            })),
        }
    }
    if spec.validate_enabled() {
        out.push(Box::new(ValidateStage { strict: spec.is_fused() }));
    }
    out
}

/// The paper's α balance bound — delegates to [`FusionConfig::with_alpha`]
/// so the detect-stage cap and fusion's bound can never drift apart.
fn max_part_size(g: &CsrGraph, k: usize, alpha: f64) -> usize {
    FusionConfig::with_alpha(g, k, alpha).max_part_size
}

/// Definition 1's community-size cap `S = β·max_part_size`, shared by the
/// Leiden and Louvain stages. Both parameters `None` means bare community
/// detection: uncapped.
fn community_size_cap(g: &CsrGraph, k: usize, beta: Option<f64>, alpha: Option<f64>) -> usize {
    if beta.is_none() && alpha.is_none() {
        return usize::MAX;
    }
    let beta = beta.unwrap_or(super::spec::DEFAULT_BETA);
    let alpha = alpha.unwrap_or(DEFAULT_ALPHA);
    ((beta * max_part_size(g, k, alpha) as f64).ceil() as usize).max(1)
}

fn require_input(input: Option<Partitioning>, stage: &str) -> Result<Partitioning> {
    input.ok_or_else(|| {
        Error::Partition(format!("stage {stage:?} needs an upstream partitioning"))
    })
}

// ---------------------------------------------------------------------------
// stage implementations (thin adapters over the existing algorithms)
// ---------------------------------------------------------------------------

struct LeidenStage {
    gamma: f64,
    theta: f64,
    /// Explicit β, if set in the spec.
    cap_beta: Option<f64>,
    /// Downstream fusion α (None when the spec has no fusion stage).
    cap_alpha: Option<f64>,
}

impl Stage for LeidenStage {
    fn name(&self) -> &'static str {
        "leiden"
    }

    fn run(&self, ctx: &StageCtx, _input: Option<Partitioning>) -> Result<Partitioning> {
        let cfg = LeidenConfig {
            gamma: self.gamma,
            max_community_size: community_size_cap(
                ctx.graph,
                ctx.k,
                self.cap_beta,
                self.cap_alpha,
            ),
            theta: self.theta,
            seed: ctx.seed,
            threads: ctx.threads,
            ..LeidenConfig::default()
        };
        Ok(leiden(ctx.graph, &cfg))
    }
}

struct LouvainStage {
    gamma: f64,
    cap_beta: Option<f64>,
    cap_alpha: Option<f64>,
}

impl Stage for LouvainStage {
    fn name(&self) -> &'static str {
        "louvain"
    }

    fn run(&self, ctx: &StageCtx, _input: Option<Partitioning>) -> Result<Partitioning> {
        let cfg = LouvainConfig {
            gamma: self.gamma,
            max_community_size: community_size_cap(
                ctx.graph,
                ctx.k,
                self.cap_beta,
                self.cap_alpha,
            ),
            seed: ctx.seed,
            threads: ctx.threads,
            ..LouvainConfig::default()
        };
        Ok(louvain(ctx.graph, &cfg))
    }
}

struct MetisStage {
    imbalance: f64,
}

impl Stage for MetisStage {
    fn name(&self) -> &'static str {
        "metis"
    }

    fn run(&self, ctx: &StageCtx, _input: Option<Partitioning>) -> Result<Partitioning> {
        let mut p = MetisPartitioner::new(ctx.seed);
        p.imbalance = self.imbalance;
        p.partition(ctx.graph, ctx.k)
    }
}

struct LpaStage {
    iters: usize,
    slack: f64,
}

impl Stage for LpaStage {
    fn name(&self) -> &'static str {
        "lpa"
    }

    fn run(&self, ctx: &StageCtx, _input: Option<Partitioning>) -> Result<Partitioning> {
        let mut p = LpaPartitioner::new(ctx.seed);
        p.max_iters = self.iters;
        p.capacity_slack = self.slack;
        p.partition(ctx.graph, ctx.k)
    }
}

struct RandomStage;

impl Stage for RandomStage {
    fn name(&self) -> &'static str {
        "random"
    }

    fn run(&self, ctx: &StageCtx, _input: Option<Partitioning>) -> Result<Partitioning> {
        RandomPartitioner::new(ctx.seed).partition(ctx.graph, ctx.k)
    }
}

struct FusionStage {
    alpha: f64,
    /// Split input partitions into connected components first (needed for
    /// every detector except Leiden).
    split: bool,
}

impl Stage for FusionStage {
    fn name(&self) -> &'static str {
        "fusion"
    }

    fn run(&self, ctx: &StageCtx, input: Option<Partitioning>) -> Result<Partitioning> {
        let p = require_input(input, "fusion")?;
        let cfg = FusionConfig::with_alpha(ctx.graph, ctx.k, self.alpha);
        let communities = if self.split {
            split_into_components(ctx.graph, &p)
        } else {
            p
        };
        fuse_communities_threaded(ctx.graph, &communities, &cfg, ctx.threads)
    }
}

struct BalanceStage {
    slack: f64,
}

impl Stage for BalanceStage {
    fn name(&self) -> &'static str {
        "balance"
    }

    fn run(&self, ctx: &StageCtx, input: Option<Partitioning>) -> Result<Partitioning> {
        let p = require_input(input, "balance")?;
        let g = ctx.graph;
        let n = g.num_nodes();
        let k = p.k();
        if k <= 1 {
            return Ok(p);
        }
        let cap = max_part_size(g, k, self.slack);
        let mut assign = p.assignments().to_vec();
        let mut sizes = p.sizes().to_vec();
        // generation-stamped scratch so the per-move BFS never reallocates
        let mut visited = vec![0u32; n];
        let mut gen = 0u32;
        // Bounded sweeps: move boundary nodes out of over-capacity
        // partitions into their smallest under-capacity neighbour, but
        // only when the move keeps the source partition in one piece (the
        // fusion invariant must survive rebalancing).
        for _pass in 0..8 {
            let mut moved = false;
            for v in 0..n as u32 {
                let src = assign[v as usize];
                if sizes[src as usize] <= cap {
                    continue;
                }
                let mut best: Option<(usize, u32)> = None;
                for &u in g.neighbors(v) {
                    let q = assign[u as usize];
                    if q != src && sizes[q as usize] < cap {
                        let cand = (sizes[q as usize], q);
                        if best.map_or(true, |b| cand < b) {
                            best = Some(cand);
                        }
                    }
                }
                let Some((_, dst)) = best else { continue };
                if !connected_without(
                    g,
                    &assign,
                    src,
                    v,
                    sizes[src as usize],
                    &mut visited,
                    &mut gen,
                ) {
                    continue;
                }
                assign[v as usize] = dst;
                sizes[src as usize] -= 1;
                sizes[dst as usize] += 1;
                moved = true;
            }
            if !moved {
                break;
            }
        }
        Partitioning::new(assign, k)
    }
}

/// Is partition `part` minus node `v` still one connected component?
/// BFS restricted to the partition's induced subgraph, so the cost is
/// bounded by the partition's internal edges, not the whole graph.
fn connected_without(
    g: &CsrGraph,
    assign: &[u32],
    part: u32,
    v: u32,
    part_size: usize,
    visited: &mut [u32],
    gen: &mut u32,
) -> bool {
    if part_size <= 1 {
        return false; // the move would empty the partition
    }
    let start = match g
        .neighbors(v)
        .iter()
        .find(|&&u| assign[u as usize] == part)
    {
        Some(&u) => u,
        // v has no in-partition neighbour: it is already isolated there,
        // so moving it out strictly improves structure
        None => return true,
    };
    *gen += 1;
    let tag = *gen;
    visited[start as usize] = tag;
    let mut stack = vec![start];
    let mut seen = 1usize;
    while let Some(u) = stack.pop() {
        for &w in g.neighbors(u) {
            if w == v || assign[w as usize] != part || visited[w as usize] == tag {
                continue;
            }
            visited[w as usize] = tag;
            seen += 1;
            stack.push(w);
        }
    }
    seen == part_size - 1
}

struct ValidateStage {
    /// Enforce the paper's structural guarantee (only meaningful for
    /// fusion-terminated specs on connected graphs).
    strict: bool,
}

impl Stage for ValidateStage {
    fn name(&self) -> &'static str {
        "validate"
    }

    fn run(&self, ctx: &StageCtx, input: Option<Partitioning>) -> Result<Partitioning> {
        let p = require_input(input, "validate")?;
        // Exact cover with in-range ids is enforced by `Partitioning::new`;
        // re-check the graph/partitioning pairing here.
        if p.num_nodes() != ctx.graph.num_nodes() {
            return Err(Error::Partition(format!(
                "validate: partitioning covers {} nodes, graph has {}",
                p.num_nodes(),
                ctx.graph.num_nodes()
            )));
        }
        if self.strict && is_connected(ctx.graph) {
            // One union-find pass over the edge list checks every
            // partition at once (components + isolation), instead of a
            // mask allocation and graph traversal per partition.
            let n = ctx.graph.num_nodes();
            let mut parent: Vec<u32> = (0..n as u32).collect();
            let mut has_internal_nbr = vec![false; n];
            for (u, v, _) in ctx.graph.edges() {
                if p.part_of(u) != p.part_of(v) {
                    continue;
                }
                has_internal_nbr[u as usize] = true;
                has_internal_nbr[v as usize] = true;
                let (ru, rv) = (uf_find(&mut parent, u), uf_find(&mut parent, v));
                if ru != rv {
                    parent[ru as usize] = rv;
                }
            }
            let mut components = vec![0usize; p.k()];
            for v in 0..n as u32 {
                if !has_internal_nbr[v as usize] {
                    return Err(Error::Partition(format!(
                        "validate: node {v} is isolated in partition {}",
                        p.part_of(v)
                    )));
                }
                if uf_find(&mut parent, v) == v {
                    components[p.part_of(v) as usize] += 1;
                }
            }
            for (part, &comps) in components.iter().enumerate() {
                if p.sizes()[part] == 0 {
                    return Err(Error::Partition(format!(
                        "validate: partition {part} is empty"
                    )));
                }
                if comps != 1 {
                    return Err(Error::Partition(format!(
                        "validate: partition {part} has {comps} components"
                    )));
                }
            }
        }
        Ok(p)
    }
}

/// Union-find root with path halving.
fn uf_find(parent: &mut [u32], mut x: u32) -> u32 {
    while parent[x as usize] != x {
        parent[x as usize] = parent[parent[x as usize] as usize];
        x = parent[x as usize];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate::karate_graph;
    use crate::partition::leiden::leiden_fusion;

    fn pipeline(spec: &str, seed: u64) -> PartitionPipeline {
        PartitionPipeline::parse(spec, seed).unwrap()
    }

    #[test]
    fn lf_pipeline_matches_legacy_leiden_fusion() {
        let g = karate_graph();
        for seed in [1u64, 7, 42] {
            let report = pipeline("lf", seed).run(&g, 2).unwrap();
            let legacy = leiden_fusion(&g, 2, 0.05, 0.5, seed).unwrap();
            assert_eq!(
                report.partitioning.assignments(),
                legacy.assignments(),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn stage_timings_cover_every_stage() {
        let g = karate_graph();
        let report = pipeline("lf", 1).run(&g, 2).unwrap();
        let names: Vec<&str> = report.stages.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["leiden", "fusion", "validate"]);
        assert!(report.total_secs() >= 0.0);
        assert_eq!(report.stages.last().unwrap().parts, 2);
    }

    #[test]
    fn observer_sees_start_and_finish_per_stage() {
        let g = karate_graph();
        let p = pipeline("metis+f", 3);
        let mut started = 0usize;
        let mut finished = 0usize;
        p.run_observed(&g, 2, &mut |ev| match ev {
            PipelineEvent::StageStarted { .. } => started += 1,
            PipelineEvent::StageFinished { .. } => finished += 1,
            PipelineEvent::PipelineStarted { num_stages, .. } => {
                assert_eq!(*num_stages, 3);
            }
        })
        .unwrap();
        assert_eq!(started, 3);
        assert_eq!(finished, 3);
    }

    #[test]
    fn bare_leiden_is_community_detection() {
        let g = karate_graph();
        let report = pipeline("leiden", 1).run(&g, 2).unwrap();
        // no fusion: output is the community structure, not k parts
        assert!(report.partitioning.k() >= 2);
        assert_eq!(report.stages.len(), 2); // leiden + validate (lenient)
    }

    #[test]
    fn novalidate_skips_the_validation_stage() {
        let g = karate_graph();
        let report = pipeline("lf!novalidate", 1).run(&g, 2).unwrap();
        let names: Vec<&str> = report.stages.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["leiden", "fusion"]);
    }

    #[test]
    fn lazy_quality_is_computed_once() {
        let g = karate_graph();
        let report = pipeline("lf", 1).run(&g, 2).unwrap();
        let q1 = report.quality(&g) as *const _;
        let q2 = report.quality(&g) as *const _;
        assert_eq!(q1, q2);
        assert!(report.quality(&g).is_structurally_ideal());
    }

    #[test]
    fn balance_stage_respects_connectivity() {
        let g = karate_graph();
        let report = pipeline("leiden+fusion+balance(slack=0.05)", 1)
            .run(&g, 2)
            .unwrap();
        assert!(report.quality(&g).is_structurally_ideal());
    }

    #[test]
    fn spec_partitioner_adapts_the_trait() {
        let g = karate_graph();
        let p = SpecPartitioner::new("lf".parse().unwrap(), 1);
        assert_eq!(p.name(), "leiden+fusion");
        let out = p.partition(&g, 2).unwrap();
        assert_eq!(out.k(), 2);
    }

    #[test]
    fn same_seed_same_labels_for_every_thread_count() {
        use crate::graph::gen::{generate_sbm, SbmConfig};
        let g = generate_sbm(&SbmConfig::arxiv_like(1500, 2)).unwrap().graph;
        let reference = pipeline("lf", 7).run(&g, 4).unwrap().into_partitioning();
        for threads in [2, 4] {
            let p = PartitionPipeline::parse("lf", 7)
                .unwrap()
                .with_threads(threads)
                .run(&g, 4)
                .unwrap()
                .into_partitioning();
            assert_eq!(
                reference.assignments(),
                p.assignments(),
                "threads={threads} changed the partitioning"
            );
        }
    }

    #[test]
    fn pipeline_rejects_k_zero() {
        let g = karate_graph();
        assert!(pipeline("lf", 1).run(&g, 0).is_err());
    }

    #[test]
    fn stage_names_include_validate() {
        assert_eq!(
            pipeline("lf", 0).stage_names(),
            vec!["leiden", "fusion", "validate"]
        );
    }
}
