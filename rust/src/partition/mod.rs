//! Graph partitioning: the paper's Leiden-Fusion method plus every baseline
//! it compares against (METIS-like multilevel, LPA, Random), the "+F"
//! fusion adapter, and the §5.1 quality metrics.

pub mod fusion;
pub mod leiden;
pub mod louvain;
pub mod lpa;
pub mod metis;
pub mod quality;
pub mod random;

pub use fusion::{fuse_communities, fuse_partitioning, FusionConfig};
pub use leiden::{leiden, leiden_fusion, LeidenConfig};
pub use quality::PartitionQuality;

use crate::error::{Error, Result};
use crate::graph::{CsrGraph, NodeId};

/// A partitioning of a graph's nodes into `k` parts.
///
/// Invariant: `assign` is an exact cover — every node has exactly one
/// partition id in `0..k` (enforced by [`Partitioning::new`], relied on by
/// property tests).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partitioning {
    assign: Vec<u32>,
    k: usize,
}

impl Partitioning {
    /// Validate and wrap an assignment vector.
    pub fn new(assign: Vec<u32>, k: usize) -> Result<Self> {
        if k == 0 {
            return Err(Error::Partition("k must be positive".into()));
        }
        if let Some(&bad) = assign.iter().find(|&&p| p as usize >= k) {
            return Err(Error::Partition(format!("partition id {bad} out of range (k={k})")));
        }
        Ok(Partitioning { assign, k })
    }

    /// Compact arbitrary (possibly sparse) labels to dense `0..k`.
    pub fn from_labels(labels: &[u32]) -> Self {
        let mut remap = std::collections::HashMap::new();
        let assign: Vec<u32> = labels
            .iter()
            .map(|&l| {
                let next = remap.len() as u32;
                *remap.entry(l).or_insert(next)
            })
            .collect();
        Partitioning { assign, k: remap.len().max(1) }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.assign.len()
    }

    /// Partition of node `v`.
    #[inline]
    pub fn part_of(&self, v: NodeId) -> u32 {
        self.assign[v as usize]
    }

    pub fn assignments(&self) -> &[u32] {
        &self.assign
    }

    /// Node count per partition.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.k];
        for &p in &self.assign {
            s[p as usize] += 1;
        }
        s
    }

    /// Members of each partition, in node order.
    pub fn members(&self) -> Vec<Vec<NodeId>> {
        let mut m = vec![Vec::new(); self.k];
        for (v, &p) in self.assign.iter().enumerate() {
            m[p as usize].push(v as NodeId);
        }
        m
    }

    /// Boolean membership mask for one partition.
    pub fn mask(&self, part: u32) -> Vec<bool> {
        self.assign.iter().map(|&p| p == part).collect()
    }
}

/// Common interface so benches/CLI can switch methods by name.
pub trait Partitioner {
    /// Human-readable method name (appears in bench tables).
    fn name(&self) -> &'static str;

    /// Partition `g` into `k` parts.
    fn partition(&self, g: &CsrGraph, k: usize) -> Result<Partitioning>;
}

/// Count edges crossing partitions (each undirected edge once).
pub fn cut_edges(g: &CsrGraph, p: &Partitioning) -> usize {
    g.edges()
        .filter(|&(u, v, _)| p.part_of(u) != p.part_of(v))
        .count()
}

/// Resolve a partitioner by name: `lf`, `leiden`, `metis`, `lpa`, `random`.
pub fn by_name(name: &str, seed: u64) -> Result<Box<dyn Partitioner>> {
    match name {
        "lf" | "leiden-fusion" => Ok(Box::new(leiden::LeidenFusionPartitioner::new(seed))),
        "metis" => Ok(Box::new(metis::MetisPartitioner::new(seed))),
        "lpa" => Ok(Box::new(lpa::LpaPartitioner::new(seed))),
        "random" => Ok(Box::new(random::RandomPartitioner::new(seed))),
        "metis+f" => Ok(Box::new(fusion::FusedPartitioner::new(
            Box::new(metis::MetisPartitioner::new(seed)),
        ))),
        "lpa+f" => Ok(Box::new(fusion::FusedPartitioner::new(
            Box::new(lpa::LpaPartitioner::new(seed)),
        ))),
        "louvain+f" => Ok(Box::new(louvain::LouvainFusionPartitioner { seed })),
        _ => Err(Error::Partition(format!("unknown partitioner {name:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate::karate_graph;

    #[test]
    fn new_validates_range() {
        assert!(Partitioning::new(vec![0, 1, 2], 3).is_ok());
        assert!(Partitioning::new(vec![0, 3], 3).is_err());
        assert!(Partitioning::new(vec![], 0).is_err());
    }

    #[test]
    fn from_labels_compacts() {
        let p = Partitioning::from_labels(&[7, 7, 3, 9, 3]);
        assert_eq!(p.k(), 3);
        assert_eq!(p.part_of(0), p.part_of(1));
        assert_eq!(p.part_of(2), p.part_of(4));
        assert_ne!(p.part_of(0), p.part_of(3));
    }

    #[test]
    fn sizes_and_members_consistent() {
        let p = Partitioning::new(vec![0, 1, 0, 1, 1], 2).unwrap();
        assert_eq!(p.sizes(), vec![2, 3]);
        let m = p.members();
        assert_eq!(m[0], vec![0, 2]);
        assert_eq!(m[1], vec![1, 3, 4]);
        assert_eq!(p.mask(0), vec![true, false, true, false, false]);
    }

    #[test]
    fn cut_edges_on_karate_split() {
        let g = karate_graph();
        // everything in one partition → no cuts
        let p = Partitioning::new(vec![0; 34], 1).unwrap();
        assert_eq!(cut_edges(&g, &p), 0);
        // split by faction: the post-fission club labels cut 11 edges
        let assign: Vec<u32> = crate::graph::karate::KARATE_FACTIONS
            .iter()
            .map(|&f| f as u32)
            .collect();
        let p = Partitioning::new(assign, 2).unwrap();
        assert_eq!(cut_edges(&g, &p), 11);
    }

    #[test]
    fn by_name_resolves_all() {
        for name in ["lf", "metis", "lpa", "random", "metis+f", "lpa+f"] {
            assert!(by_name(name, 0).is_ok(), "{name}");
        }
        assert!(by_name("nope", 0).is_err());
    }
}
