//! Graph partitioning: the paper's Leiden-Fusion method plus every baseline
//! it compares against (METIS-like multilevel, LPA, Random), the "+F"
//! fusion adapter, and the §5.1 quality metrics.
//!
//! The public API is built around three types (see DESIGN.md
//! "Partitioning"):
//!
//! * [`PartitionSpec`] — a parsed, validated strategy description with a
//!   string grammar, e.g. `leiden(gamma=0.7)+fusion(alpha=0.05)`. Every
//!   legacy method name (`lf`, `leiden`, `metis`, `lpa`, `random`,
//!   `metis+f`, `lpa+f`, `louvain+f`) parses as a degenerate spec.
//! * [`PartitionPipeline`] — the staged executor
//!   (`detect → [fuse] → [balance] → validate`) with per-stage timing and
//!   an observer callback for progress events. The validate stage enforces
//!   the paper's invariants (exact cover, connectivity, no isolated nodes)
//!   for fused specs and is skippable via `!novalidate`.
//! * [`PartitionReport`] — the pipeline's return value: the
//!   [`Partitioning`], per-stage wall times, and lazily-computed
//!   [`PartitionQuality`].
//!
//! The free functions [`by_name`] and [`fusion::fuse_partitioning`] are
//! deprecated shims over this API, kept for one release.
//!
//! Hot paths run on the epoch-stamped scratch kernel in [`scratch`]
//! (shared by the Leiden/Louvain local-move routine in `level`, Leiden
//! refinement, and fusion's incremental cut map) and aggregate levels
//! through the sort-based `CsrGraph::coarsen` builder. The pipeline's
//! `with_threads` knob parallelises refinement, coarsening, and the
//! fusion boundary scan with a byte-identical-output guarantee — see
//! DESIGN.md "Performance".

pub mod fusion;
pub mod leiden;
pub(crate) mod level;
pub mod louvain;
pub mod lpa;
pub mod metis;
pub mod pipeline;
pub mod quality;
pub mod random;
pub mod scratch;
pub mod spec;

pub use fusion::{fuse_communities, fuse_communities_threaded, FusionConfig};
#[allow(deprecated)]
pub use fusion::fuse_partitioning;
pub use leiden::{leiden, leiden_fusion, LeidenConfig};
pub use pipeline::{
    PartitionPipeline, PartitionReport, PipelineEvent, SpecPartitioner, Stage,
    StageCtx, StageTiming,
};
pub use quality::PartitionQuality;
pub use spec::{registered_specs, PartitionSpec, StageSpec};

use crate::error::{Error, Result};
use crate::graph::{CsrGraph, NodeId};

/// A partitioning of a graph's nodes into `k` parts.
///
/// Invariant: `assign` is an exact cover — every node has exactly one
/// partition id in `0..k` (enforced by [`Partitioning::new`], relied on by
/// property tests). Per-partition node counts are computed once at
/// construction, so [`Partitioning::sizes`] is free on the hot paths
/// (fusion's merge loop, [`PartitionQuality`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partitioning {
    assign: Vec<u32>,
    k: usize,
    sizes: Vec<usize>,
}

impl Partitioning {
    /// Validate and wrap an assignment vector.
    pub fn new(assign: Vec<u32>, k: usize) -> Result<Self> {
        if k == 0 {
            return Err(Error::Partition("k must be positive".into()));
        }
        if let Some(&bad) = assign.iter().find(|&&p| p as usize >= k) {
            return Err(Error::Partition(format!("partition id {bad} out of range (k={k})")));
        }
        let sizes = count_sizes(&assign, k);
        Ok(Partitioning { assign, k, sizes })
    }

    /// Compact arbitrary (possibly sparse) labels to dense `0..k`.
    pub fn from_labels(labels: &[u32]) -> Self {
        // lint: allow(nondet_iter) — keyed entry() only, never iterated; dense ids follow first-encounter order of the labels slice
        let mut remap = std::collections::HashMap::new();
        let assign: Vec<u32> = labels
            .iter()
            .map(|&l| {
                let next = remap.len() as u32;
                *remap.entry(l).or_insert(next)
            })
            .collect();
        let k = remap.len().max(1);
        let sizes = count_sizes(&assign, k);
        Partitioning { assign, k, sizes }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.assign.len()
    }

    /// Partition of node `v`.
    #[inline]
    pub fn part_of(&self, v: NodeId) -> u32 {
        self.assign[v as usize]
    }

    pub fn assignments(&self) -> &[u32] {
        &self.assign
    }

    /// Node count per partition (cached at construction — O(1)).
    #[inline]
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Members of each partition, in node order.
    pub fn members(&self) -> Vec<Vec<NodeId>> {
        let mut m: Vec<Vec<NodeId>> =
            self.sizes.iter().map(|&s| Vec::with_capacity(s)).collect();
        for (v, &p) in self.assign.iter().enumerate() {
            m[p as usize].push(v as NodeId);
        }
        m
    }

    /// Boolean membership mask for one partition.
    pub fn mask(&self, part: u32) -> Vec<bool> {
        self.assign.iter().map(|&p| p == part).collect()
    }
}

fn count_sizes(assign: &[u32], k: usize) -> Vec<usize> {
    let mut s = vec![0usize; k];
    for &p in assign {
        s[p as usize] += 1;
    }
    s
}

/// Common interface so benches/CLI can switch methods by name.
pub trait Partitioner {
    /// Human-readable method name (appears in bench tables).
    fn name(&self) -> &str;

    /// Partition `g` into `k` parts.
    fn partition(&self, g: &CsrGraph, k: usize) -> Result<Partitioning>;
}

/// Count edges crossing partitions (each undirected edge once).
pub fn cut_edges(g: &CsrGraph, p: &Partitioning) -> usize {
    g.edges()
        .filter(|&(u, v, _)| p.part_of(u) != p.part_of(v))
        .count()
}

/// Resolve a partitioner by name: any [`PartitionSpec`] string, including
/// the legacy names `lf`, `leiden`, `metis`, `lpa`, `random`, `metis+f`,
/// `lpa+f`, `louvain+f`.
#[deprecated(note = "parse a `PartitionSpec` and run a `PartitionPipeline` instead")]
pub fn by_name(name: &str, seed: u64) -> Result<Box<dyn Partitioner>> {
    let spec: PartitionSpec = name.parse()?;
    Ok(Box::new(SpecPartitioner::new(spec, seed)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate::karate_graph;

    #[test]
    fn new_validates_range() {
        assert!(Partitioning::new(vec![0, 1, 2], 3).is_ok());
        assert!(Partitioning::new(vec![0, 3], 3).is_err());
        assert!(Partitioning::new(vec![], 0).is_err());
    }

    #[test]
    fn from_labels_compacts() {
        let p = Partitioning::from_labels(&[7, 7, 3, 9, 3]);
        assert_eq!(p.k(), 3);
        assert_eq!(p.part_of(0), p.part_of(1));
        assert_eq!(p.part_of(2), p.part_of(4));
        assert_ne!(p.part_of(0), p.part_of(3));
    }

    #[test]
    fn sizes_and_members_consistent() {
        let p = Partitioning::new(vec![0, 1, 0, 1, 1], 2).unwrap();
        assert_eq!(p.sizes(), vec![2, 3]);
        let m = p.members();
        assert_eq!(m[0], vec![0, 2]);
        assert_eq!(m[1], vec![1, 3, 4]);
        assert_eq!(p.mask(0), vec![true, false, true, false, false]);
    }

    #[test]
    fn cached_sizes_match_a_rescan() {
        let p = Partitioning::from_labels(&[5, 5, 2, 9, 2, 2, 9]);
        let mut rescan = vec![0usize; p.k()];
        for &x in p.assignments() {
            rescan[x as usize] += 1;
        }
        assert_eq!(p.sizes(), rescan);
        assert_eq!(p.sizes().iter().sum::<usize>(), p.num_nodes());
    }

    #[test]
    fn cut_edges_on_karate_split() {
        let g = karate_graph();
        // everything in one partition → no cuts
        let p = Partitioning::new(vec![0; 34], 1).unwrap();
        assert_eq!(cut_edges(&g, &p), 0);
        // split by faction: the post-fission club labels cut 11 edges
        let assign: Vec<u32> = crate::graph::karate::KARATE_FACTIONS
            .iter()
            .map(|&f| f as u32)
            .collect();
        let p = Partitioning::new(assign, 2).unwrap();
        assert_eq!(cut_edges(&g, &p), 11);
    }

    #[test]
    #[allow(deprecated)]
    fn by_name_resolves_all() {
        // the doc comment advertises `leiden`; the shim must accept it,
        // along with every other legacy name (including `louvain+f`)
        for name in [
            "lf", "leiden", "louvain", "metis", "lpa", "random", "metis+f",
            "lpa+f", "louvain+f",
        ] {
            assert!(by_name(name, 0).is_ok(), "{name}");
        }
        assert!(by_name("nope", 0).is_err());
    }

    #[test]
    #[allow(deprecated)]
    fn by_name_shim_matches_pipeline_output() {
        let g = karate_graph();
        let shim = by_name("lf", 1).unwrap().partition(&g, 2).unwrap();
        let direct = PartitionPipeline::parse("lf", 1)
            .unwrap()
            .run(&g, 2)
            .unwrap()
            .into_partitioning();
        assert_eq!(shim.assignments(), direct.assignments());
    }
}
