//! Partition quality metrics — paper §5.1, equations (5)–(7).
//!
//! These six metrics are what Figure 4/5 and Table 1 report:
//! edge-cut fraction τ, per-partition connected components, per-partition
//! isolated nodes, node balance ρ, edge balance, and replication factor RF.

use super::Partitioning;
use crate::graph::{components_within, CsrGraph};
// lint: allow(nondet_iter) — membership + len() only (replication-factor counting); the set is never iterated
use std::collections::HashSet;

/// Full §5.1 metric set for one (graph, partitioning) pair.
#[derive(Clone, Debug)]
pub struct PartitionQuality {
    pub k: usize,
    /// τ = cut edges / m (eq. 5).
    pub edge_cut_fraction: f64,
    /// Connected components of each partition.
    pub components: Vec<usize>,
    /// Isolated nodes of each partition.
    pub isolated: Vec<usize>,
    /// Node count of each partition.
    pub node_counts: Vec<usize>,
    /// Internal edge count of each partition.
    pub edge_counts: Vec<usize>,
    /// ρ = max |Pᵢ| / (n/k) (eq. 6).
    pub node_balance: f64,
    /// Edge analogue of ρ.
    pub edge_balance: f64,
    /// RF = (1/n) Σᵢ |Pᵢ(v)| — average copies per node under 1-hop
    /// replication (eq. 7): 1 owner copy plus one replica per foreign
    /// partition adjacent to the node.
    pub replication_factor: f64,
}

impl PartitionQuality {
    /// Compute all metrics. Cost: O(n + m + k·components).
    pub fn measure(g: &CsrGraph, p: &Partitioning) -> Self {
        let n = g.num_nodes();
        let m = g.num_edges().max(1);
        let k = p.k();

        let mut cut = 0usize;
        let mut edge_counts = vec![0usize; k];
        for (u, v, _) in g.edges() {
            let (pu, pv) = (p.part_of(u), p.part_of(v));
            if pu == pv {
                edge_counts[pu as usize] += 1;
            } else {
                cut += 1;
            }
        }

        let node_counts = p.sizes().to_vec();

        let mut components = Vec::with_capacity(k);
        let mut isolated = Vec::with_capacity(k);
        for part in 0..k as u32 {
            let mask = p.mask(part);
            if mask.iter().any(|&b| b) {
                let info = components_within(g, &mask);
                components.push(info.num_components());
                isolated.push(info.isolated);
            } else {
                components.push(0);
                isolated.push(0);
            }
        }

        // Replication factor: copies of v = 1 + #distinct foreign partitions
        // among its neighbours.
        let mut total_copies = 0usize;
        // lint: allow(nondet_iter) — distinct-count scratch: insert + len(), never iterated
        let mut seen: HashSet<u32> = HashSet::new();
        for v in 0..n as u32 {
            seen.clear();
            let home = p.part_of(v);
            for &u in g.neighbors(v) {
                let q = p.part_of(u);
                if q != home {
                    seen.insert(q);
                }
            }
            total_copies += 1 + seen.len();
        }

        let avg_nodes = n as f64 / k as f64;
        let avg_edges = g.num_edges() as f64 / k as f64;
        PartitionQuality {
            k,
            edge_cut_fraction: cut as f64 / m as f64,
            node_balance: node_counts.iter().copied().max().unwrap_or(0) as f64
                / avg_nodes.max(f64::MIN_POSITIVE),
            edge_balance: edge_counts.iter().copied().max().unwrap_or(0) as f64
                / avg_edges.max(f64::MIN_POSITIVE),
            replication_factor: total_copies as f64 / n.max(1) as f64,
            components,
            isolated,
            node_counts,
            edge_counts,
        }
    }

    pub fn total_components(&self) -> usize {
        self.components.iter().sum()
    }

    pub fn total_isolated(&self) -> usize {
        self.isolated.iter().sum()
    }

    /// One-per-partition components and zero isolated nodes — the paper's
    /// structural-integrity criterion (§4.1).
    pub fn is_structurally_ideal(&self) -> bool {
        self.components.iter().all(|&c| c == 1) && self.total_isolated() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate::{karate_graph, KARATE_FACTIONS};
    use crate::partition::leiden::leiden_fusion;
    use crate::partition::Partitioning;

    fn faction_partitioning() -> Partitioning {
        Partitioning::new(KARATE_FACTIONS.iter().map(|&f| f as u32).collect(), 2)
            .unwrap()
    }

    #[test]
    fn faction_split_metrics() {
        let g = karate_graph();
        let q = PartitionQuality::measure(&g, &faction_partitioning());
        assert!((q.edge_cut_fraction - 11.0 / 78.0).abs() < 1e-9);
        assert_eq!(q.node_counts, vec![17, 17]);
        assert_eq!(q.node_balance, 1.0);
        assert!(q.is_structurally_ideal());
    }

    #[test]
    fn trivial_partition_is_ideal() {
        let g = karate_graph();
        let p = Partitioning::new(vec![0; 34], 1).unwrap();
        let q = PartitionQuality::measure(&g, &p);
        assert_eq!(q.edge_cut_fraction, 0.0);
        assert_eq!(q.replication_factor, 1.0);
        assert!(q.is_structurally_ideal());
    }

    #[test]
    fn detects_disconnection_and_isolation() {
        // path 0-1-2-3; partition {0,3} is 2 comps, both isolated
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        let p = Partitioning::new(vec![0, 1, 1, 0], 2).unwrap();
        let q = PartitionQuality::measure(&g, &p);
        assert_eq!(q.components, vec![2, 1]);
        assert_eq!(q.isolated, vec![2, 0]);
        assert!(!q.is_structurally_ideal());
    }

    #[test]
    fn replication_factor_counts_foreign_partitions() {
        // star: center 0 with leaves 1,2,3 in three different partitions
        let g = CsrGraph::from_edges(4, &[(0, 1), (0, 2), (0, 3)]).unwrap();
        let p = Partitioning::new(vec![0, 1, 2, 0], 3).unwrap();
        let q = PartitionQuality::measure(&g, &p);
        // node 0: home 0, foreign {1,2} → 3 copies; node 1: 1+1; node 2: 1+1;
        // node 3: 1+0 → total 8 / 4 nodes = 2.0
        assert!((q.replication_factor - 2.0).abs() < 1e-9);
    }

    #[test]
    fn lf_partitions_are_ideal_on_karate() {
        let g = karate_graph();
        for k in [2, 3, 4] {
            let p = leiden_fusion(&g, k, 0.05, 0.5, 1).unwrap();
            let q = PartitionQuality::measure(&g, &p);
            assert!(q.is_structurally_ideal(), "k={k}: {:?}", q.components);
        }
    }

    #[test]
    fn edge_balance_counts_internal_edges() {
        let g = karate_graph();
        let q = PartitionQuality::measure(&g, &faction_partitioning());
        assert_eq!(q.edge_counts.iter().sum::<usize>() + 11, 78);
        // cut edges belong to no partition, so edge balance may dip below 1
        assert!(q.edge_balance > 0.0 && q.edge_balance <= q.k as f64);
    }
}
