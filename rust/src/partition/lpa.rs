//! Label-Propagation partitioner (§3.1) — the Spark-Local / Spinner-style
//! K-label variant: every node starts with a random label in `0..k`, then
//! repeatedly adopts the most frequent label among its neighbours, with a
//! capacity penalty that keeps the k partitions loosely balanced
//! (Martella et al., "Spinner", ICDE'17).
//!
//! The paper (§3.1, Fig. 3) highlights LPA's failure mode: identical labels
//! seeded at distant positions propagate into many disconnected islands per
//! partition. This implementation intentionally reproduces that behaviour —
//! it is the baseline being measured, not a strawman: the balance penalty
//! and asynchronous sweeps match the production Spinner design.

use super::{Partitioner, Partitioning};
use crate::error::Result;
use crate::graph::CsrGraph;
use crate::util::rng::Rng;

pub struct LpaPartitioner {
    pub seed: u64,
    /// Maximum sweeps over all nodes.
    pub max_iters: usize,
    /// Stop when fewer than this fraction of nodes change per sweep.
    pub min_change_fraction: f64,
    /// Capacity slack: partition capacity = n/k · (1 + slack).
    pub capacity_slack: f64,
}

impl LpaPartitioner {
    pub fn new(seed: u64) -> Self {
        LpaPartitioner {
            seed,
            max_iters: 30,
            min_change_fraction: 0.001,
            capacity_slack: 0.10,
        }
    }
}

impl Partitioner for LpaPartitioner {
    fn name(&self) -> &'static str {
        "lpa"
    }

    fn partition(&self, g: &CsrGraph, k: usize) -> Result<Partitioning> {
        let n = g.num_nodes();
        let mut rng = Rng::new(self.seed);
        let mut label: Vec<u32> = (0..n).map(|_| rng.index(k) as u32).collect();
        let mut load = vec![0usize; k];
        for &l in &label {
            load[l as usize] += 1;
        }
        let capacity = ((n as f64 / k as f64) * (1.0 + self.capacity_slack)).ceil();

        let mut order: Vec<u32> = (0..n as u32).collect();
        let mut counts: Vec<f64> = vec![0.0; k];
        for _ in 0..self.max_iters {
            rng.shuffle(&mut order);
            let mut changed = 0usize;
            for &v in &order {
                let nbrs = g.neighbors(v);
                if nbrs.is_empty() {
                    continue;
                }
                for c in counts.iter_mut() {
                    *c = 0.0;
                }
                for (i, &u) in nbrs.iter().enumerate() {
                    counts[label[u as usize] as usize] += g.weight_at(v, i) as f64;
                }
                let cur = label[v as usize];
                // Spinner score: neighbour frequency × remaining capacity
                let mut best = cur;
                let mut best_score = f64::NEG_INFINITY;
                for (c, &cnt) in counts.iter().enumerate() {
                    if cnt <= 0.0 && c as u32 != cur {
                        continue;
                    }
                    let penalty = 1.0 - load[c] as f64 / capacity;
                    let score = cnt * penalty.max(0.0)
                        + if c as u32 == cur { 1e-9 } else { 0.0 }; // sticky ties
                    if score > best_score {
                        best_score = score;
                        best = c as u32;
                    }
                }
                if best != cur {
                    load[cur as usize] -= 1;
                    load[best as usize] += 1;
                    label[v as usize] = best;
                    changed += 1;
                }
            }
            if (changed as f64) < self.min_change_fraction * n as f64 {
                break;
            }
        }
        // Labels are fixed 0..k (empty partitions are possible — that is
        // LPA's documented weakness, surfaced by the quality metrics).
        Partitioning::new(label, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_sbm, SbmConfig};
    use crate::graph::karate::karate_graph;
    use crate::partition::cut_edges;

    #[test]
    fn produces_k_parts_with_reasonable_balance() {
        let g = generate_sbm(&SbmConfig::arxiv_like(1000, 3)).unwrap().graph;
        let p = LpaPartitioner::new(1).partition(&g, 4).unwrap();
        assert_eq!(p.k(), 4);
        let sizes = p.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 1000);
        let max = *sizes.iter().max().unwrap();
        assert!(max as f64 <= 1000.0 / 4.0 * 1.6, "sizes {sizes:?}");
    }

    #[test]
    fn cuts_fewer_edges_than_random() {
        let g = generate_sbm(&SbmConfig::arxiv_like(1500, 5)).unwrap().graph;
        let lpa = LpaPartitioner::new(2).partition(&g, 4).unwrap();
        let rnd = crate::partition::random::RandomPartitioner::new(2)
            .partition(&g, 4)
            .unwrap();
        assert!(cut_edges(&g, &lpa) < cut_edges(&g, &rnd));
    }

    #[test]
    fn deterministic_per_seed() {
        let g = karate_graph();
        let a = LpaPartitioner::new(9).partition(&g, 2).unwrap();
        let b = LpaPartitioner::new(9).partition(&g, 2).unwrap();
        assert_eq!(a.assignments(), b.assignments());
    }

    #[test]
    fn karate_k2_runs() {
        let g = karate_graph();
        let p = LpaPartitioner::new(4).partition(&g, 2).unwrap();
        assert_eq!(p.k(), 2);
        assert_eq!(p.num_nodes(), 34);
    }
}
