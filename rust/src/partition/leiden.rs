//! Leiden community detection (Traag et al. 2019) with the paper's
//! community-size cap, plus the combined Leiden-Fusion partitioner.
//!
//! Implements the full three-phase algorithm:
//!  1. **Fast local moving** — queue-driven modularity-maximising moves
//!     (`MovePolicy::Queue` over the shared `super::level` routine).
//!  2. **Refinement** — communities are re-partitioned from singletons by
//!     randomised merges restricted to the community, which is what gives
//!     Leiden its well-connectedness guarantee over Louvain. Communities
//!     are independent, so refinement fans out over them when
//!     `threads > 1`; each community draws from its own RNG stream seeded
//!     by `(seed, level, community)`, so the output is byte-identical for
//!     every thread count.
//!  3. **Aggregation** — the refined partition becomes a super-node graph
//!     (sort-based [`crate::graph::CsrGraph::coarsen`]) whose communities
//!     seed the next level.
//!
//! Definition 1 of the paper adds a max community size `S`; any move or
//! merge that would exceed `S` (counted in *original* nodes) is rejected.
//!
//! All inner loops run on the epoch-stamped [`NeighborWeights`] scratch
//! kernel — no per-node-visit allocation, and neighbour-community
//! enumeration order is first-touch order, deterministic by construction.

use super::fusion::{fuse_communities, FusionConfig};
use super::level::{compact, local_move, Level, MovePolicy};
use super::scratch::NeighborWeights;
use super::{Partitioner, Partitioning};
use crate::error::Result;
use crate::graph::CsrGraph;
use crate::util::parallel::map_chunks;
use crate::util::rng::{splitmix64, Rng};

/// Leiden parameters.
#[derive(Clone, Debug)]
pub struct LeidenConfig {
    /// Modularity resolution γ (paper eq. 4).
    pub gamma: f64,
    /// Max community size in original nodes (`usize::MAX` = uncapped);
    /// the paper's Definition 1 `S = β · max_part_size`.
    pub max_community_size: usize,
    /// Randomness of refinement merges (θ in the Leiden paper).
    pub theta: f64,
    /// Max aggregation levels (safety bound; convergence is usually < 6).
    pub max_levels: usize,
    /// RNG seed.
    pub seed: u64,
    /// Worker threads for refinement and aggregation (1 = sequential).
    /// The partitioning is identical for every value — see DESIGN.md
    /// "Performance" for the determinism contract.
    pub threads: usize,
}

impl Default for LeidenConfig {
    fn default() -> Self {
        LeidenConfig {
            gamma: 1.0,
            max_community_size: usize::MAX,
            theta: 0.01,
            max_levels: 10,
            seed: 0,
            threads: 1,
        }
    }
}

/// Run Leiden; returns community labels (dense `0..n_comms`) per node.
pub fn leiden(g: &CsrGraph, cfg: &LeidenConfig) -> Partitioning {
    let n = g.num_nodes();
    if n == 0 {
        return Partitioning::from_labels(&[]);
    }
    let total_weight = g.total_weight().max(f64::MIN_POSITIVE);
    let mut rng = Rng::new(cfg.seed);
    let mut scratch = NeighborWeights::new();

    // assignment of original nodes, refined level by level
    let mut global_comm: Vec<u32> = (0..n as u32).collect();
    let mut level = Level::singleton(g.clone());

    for level_idx in 0..cfg.max_levels {
        let moved = local_move(
            &mut level,
            MovePolicy::Queue,
            cfg.gamma,
            cfg.max_community_size,
            total_weight,
            &mut rng,
            &mut scratch,
        );
        let n_comms = compact(&mut level.comm);
        if !moved && n_comms == level.graph.num_nodes() {
            break; // converged: every super-node is its own community
        }

        // Refinement: sub-partition each community from singletons.
        let mut refined_dense = refine(&level, cfg, total_weight, level_idx, n_comms);
        let n_refined = compact(&mut refined_dense);

        if n_refined == level.graph.num_nodes() {
            // Refinement kept every super-node separate → aggregation would
            // not shrink the graph; the local-move communities are final.
            break;
        }

        // Map original nodes onto next level's super-nodes.
        for gc in global_comm.iter_mut() {
            *gc = refined_dense[*gc as usize];
        }

        // Aggregate refined communities into super-nodes; seed their
        // community from the local-move partition.
        level = level.aggregate(&refined_dense, n_refined, true, cfg.threads);
        if level.graph.num_nodes() <= 1 {
            break;
        }
    }

    // Final labels: community of each super-node at the last level.
    let mut final_comm = level.comm.clone();
    compact(&mut final_comm);
    let labels: Vec<u32> = global_comm
        .iter()
        .map(|&sc| final_comm[sc as usize])
        .collect();
    Partitioning::from_labels(&labels)
}

/// Independent RNG stream per `(seed, level, community)` — what keeps the
/// parallel refinement's output invariant under the thread count.
fn refine_stream_seed(seed: u64, level: usize, comm: usize) -> u64 {
    let mut s = seed
        ^ (level as u64).wrapping_mul(0xA076_1D64_78BD_642F)
        ^ (comm as u64).wrapping_mul(0xE703_7ED1_A0B4_28DB);
    splitmix64(&mut s)
}

/// Refinement phase: within each local-move community, re-partition from
/// singletons by randomised positive-gain merges (θ-weighted), keeping
/// the size cap. Returns refined labels, sparse: the label of a refined
/// community is the node id of one of its members, so labels are globally
/// unique without cross-community coordination. `level.comm` must be
/// dense (`0..n_comms`).
fn refine(
    level: &Level,
    cfg: &LeidenConfig,
    m: f64,
    level_idx: usize,
    n_comms: usize,
) -> Vec<u32> {
    let n = level.graph.num_nodes();

    // Group nodes by community (counting sort → contiguous member slices
    // in ascending node order) and record each node's index in its slice.
    let mut start = vec![0usize; n_comms + 1];
    for &c in &level.comm {
        start[c as usize + 1] += 1;
    }
    for i in 0..n_comms {
        start[i + 1] += start[i];
    }
    let mut members = vec![0u32; n];
    let mut local_idx = vec![0u32; n];
    let mut cursor = start.clone();
    for v in 0..n {
        let c = level.comm[v] as usize;
        local_idx[v] = (cursor[c] - start[c]) as u32;
        members[cursor[c]] = v as u32;
        cursor[c] += 1;
    }

    // Communities are independent: fan out over them, balancing chunks by
    // *member* count, not community count — one huge community must not
    // serialise the level onto a single worker. `start` is already the
    // member-count prefix sum, so the boundary scan is O(n_comms). The
    // grouping does not affect the output (each community's work is
    // self-contained), so the determinism contract survives any chunking.
    let threads = crate::util::parallel::effective_threads(cfg.threads, n, 4096);
    let mut bounds: Vec<std::ops::Range<usize>> = Vec::with_capacity(threads);
    let mut lo = 0usize;
    for i in 1..=threads {
        let target = n * i / threads;
        let mut hi = lo;
        while hi < n_comms && start[hi] < target {
            hi += 1;
        }
        bounds.push(lo..hi);
        lo = hi;
    }
    debug_assert_eq!(lo, n_comms, "refinement chunking must cover every community");

    // Each chunk returns `(node, refined_label)` pairs for its
    // communities; node sets are disjoint, so the ordered merge below is
    // race-free by construction. All per-community state is hoisted and
    // reused — the loop is allocation-free in steady state.
    let chunks = map_chunks(bounds.len(), bounds.len(), 1, |_, bound_range| {
        let mut out: Vec<(u32, u32)> = Vec::new();
        let mut scratch = NeighborWeights::new();
        let mut order: Vec<u32> = Vec::new();
        let mut cands: Vec<(u32, f64)> = Vec::new();
        let mut weights: Vec<f64> = Vec::new();
        let mut refined_l: Vec<u32> = Vec::new();
        let mut r_degree: Vec<f64> = Vec::new();
        let mut r_size: Vec<usize> = Vec::new();
        let mut r_members: Vec<usize> = Vec::new();
        for c in bound_range.flat_map(|b| bounds[b].clone()) {
            let ms = &members[start[c]..start[c + 1]];
            if ms.len() <= 1 {
                continue; // singleton community: nothing to refine
            }
            let mut rng = Rng::new(refine_stream_seed(cfg.seed, level_idx, c));
            order.clear();
            order.extend_from_slice(ms);
            rng.shuffle(&mut order);

            // per-community aggregates, indexed by local member position
            let len = ms.len();
            refined_l.clear();
            refined_l.extend(0..len as u32);
            r_degree.clear();
            r_degree.extend(ms.iter().map(|&v| level.degree(v)));
            r_size.clear();
            r_size.extend(ms.iter().map(|&v| level.node_count[v as usize]));
            r_members.clear();
            r_members.resize(len, 1);
            scratch.reset(len);

            for &v in &order {
                let lv = local_idx[v as usize] as usize;
                // only singleton refined communities may merge (Leiden
                // invariant)
                if r_members[refined_l[lv] as usize] != 1 {
                    continue;
                }
                let k_v = level.degree(v);
                let size_v = level.node_count[v as usize];
                scratch.begin();
                for (i, &u) in level.graph.neighbors(v).iter().enumerate() {
                    if level.comm[u as usize] as usize != c {
                        continue; // refinement stays inside the community
                    }
                    let rc = refined_l[local_idx[u as usize] as usize];
                    if rc == refined_l[lv] {
                        continue;
                    }
                    scratch.add(rc, level.graph.weight_at(v, i) as f64);
                }
                cands.clear();
                for &rc in scratch.touched() {
                    if r_size[rc as usize] + size_v > cfg.max_community_size {
                        continue;
                    }
                    let gain = scratch.get(rc)
                        - cfg.gamma * k_v * r_degree[rc as usize] / (2.0 * m);
                    if gain > 0.0 {
                        cands.push((rc, gain));
                    }
                }
                if cands.is_empty() {
                    continue;
                }
                // θ-randomised selection among positive-gain candidates
                weights.clear();
                weights.extend(
                    cands
                        .iter()
                        .map(|&(_, g)| (g / cfg.theta.max(1e-9)).min(500.0).exp()),
                );
                let pick = cands[rng.weighted_index(&weights)].0;
                let old = refined_l[lv];
                refined_l[lv] = pick;
                r_degree[pick as usize] += k_v;
                r_size[pick as usize] += size_v;
                r_members[pick as usize] += 1;
                r_degree[old as usize] -= k_v;
                r_size[old as usize] -= size_v;
                r_members[old as usize] -= 1;
            }
            for (i, &v) in ms.iter().enumerate() {
                let rl = refined_l[i] as usize;
                if rl != i {
                    out.push((v, ms[rl]));
                }
            }
        }
        out
    });

    // default: every node its own refined community (covers singleton
    // communities and unmoved nodes)
    let mut refined: Vec<u32> = (0..n as u32).collect();
    for chunk in chunks {
        for (v, label) in chunk {
            refined[v as usize] = label;
        }
    }
    refined
}

/// Modularity of a partitioning (paper eq. 4) — used by tests and benches.
pub fn modularity(g: &CsrGraph, p: &Partitioning, gamma: f64) -> f64 {
    let m = g.total_weight();
    if m <= 0.0 {
        return 0.0;
    }
    let mut e_c = vec![0.0f64; p.k()];
    let mut k_c = vec![0.0f64; p.k()];
    for (u, v, w) in g.edges() {
        if p.part_of(u) == p.part_of(v) {
            e_c[p.part_of(u) as usize] += w as f64;
        }
    }
    for v in 0..g.num_nodes() as crate::graph::NodeId {
        k_c[p.part_of(v) as usize] += g.weighted_degree(v);
    }
    let mut q = 0.0;
    for c in 0..p.k() {
        q += e_c[c] / m - gamma * (k_c[c] / (2.0 * m)).powi(2);
    }
    q
}

// ---------------------------------------------------------------------------
// Leiden-Fusion: the paper's Algorithm 1 end-to-end.
// ---------------------------------------------------------------------------

/// Run the paper's full two-step method: Leiden with size cap
/// `β · max_part_size`, then greedy fusion down to `k` partitions.
/// Single-threaded legacy entry point — a `PartitionPipeline` with
/// `with_threads` is the parallel path.
pub fn leiden_fusion(
    g: &CsrGraph,
    k: usize,
    alpha: f64,
    beta: f64,
    seed: u64,
) -> Result<Partitioning> {
    let max_part_size =
        ((g.num_nodes() as f64 / k as f64) * (1.0 + alpha)).ceil() as usize;
    let cap = ((beta * max_part_size as f64).ceil() as usize).max(1);
    let cfg = LeidenConfig {
        max_community_size: cap,
        seed,
        ..LeidenConfig::default()
    };
    let communities = leiden(g, &cfg);
    fuse_communities(g, &communities, &FusionConfig { k, max_part_size })
}

/// [`Partitioner`] wrapper with the paper's hyper-parameters
/// (α = 0.05, β = 0.5 — §5 "Hyperparameter Settings").
pub struct LeidenFusionPartitioner {
    pub alpha: f64,
    pub beta: f64,
    pub seed: u64,
}

impl LeidenFusionPartitioner {
    pub fn new(seed: u64) -> Self {
        LeidenFusionPartitioner { alpha: 0.05, beta: 0.5, seed }
    }
}

impl Partitioner for LeidenFusionPartitioner {
    fn name(&self) -> &'static str {
        "lf"
    }

    fn partition(&self, g: &CsrGraph, k: usize) -> Result<Partitioning> {
        leiden_fusion(g, k, self.alpha, self.beta, self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components_within;
    use crate::graph::gen::{generate_sbm, SbmConfig};
    use crate::graph::karate::karate_graph;

    #[test]
    fn karate_communities_are_sane() {
        let g = karate_graph();
        let p = leiden(&g, &LeidenConfig { seed: 1, ..Default::default() });
        // canonical Leiden/Louvain output is ~4 communities at γ=1
        assert!((2..=6).contains(&p.k()), "got {} communities", p.k());
        let q = modularity(&g, &p, 1.0);
        assert!(q > 0.35, "modularity {q}"); // optimum ≈ 0.42
    }

    #[test]
    fn communities_are_connected() {
        let g = karate_graph();
        let p = leiden(&g, &LeidenConfig { seed: 3, ..Default::default() });
        for part in 0..p.k() as u32 {
            let info = components_within(&g, &p.mask(part));
            assert_eq!(info.num_components(), 1, "community {part} disconnected");
            assert_eq!(info.isolated, 0);
        }
    }

    #[test]
    fn size_cap_is_respected() {
        let g = karate_graph();
        let cap = 10;
        let p = leiden(
            &g,
            &LeidenConfig { max_community_size: cap, seed: 5, ..Default::default() },
        );
        for (i, &s) in p.sizes().iter().enumerate() {
            assert!(s <= cap, "community {i} has {s} > cap {cap}");
        }
    }

    #[test]
    fn improves_modularity_over_singletons() {
        let g = generate_sbm(&SbmConfig::arxiv_like(800, 2)).unwrap().graph;
        let p = leiden(&g, &LeidenConfig { seed: 2, ..Default::default() });
        let q = modularity(&g, &p, 1.0);
        assert!(q > 0.3, "modularity {q}");
        assert!(p.k() < g.num_nodes() / 4);
    }

    #[test]
    fn recovers_planted_structure_roughly() {
        let sbm = generate_sbm(&SbmConfig {
            n: 600,
            communities: 4,
            avg_degree: 12.0,
            p_in: 0.9,
            degree_exponent: 3.0,
            weight_range: None,
            seed: 9,
        })
        .unwrap();
        let p = leiden(&sbm.graph, &LeidenConfig { seed: 4, ..Default::default() });
        // most planted communities should map to a dominant detected one
        let mut agree = 0usize;
        for planted in 0..4u32 {
            let nodes: Vec<usize> = (0..600)
                .filter(|&v| sbm.community[v] == planted)
                .collect();
            let mut counts = std::collections::HashMap::new();
            for &v in &nodes {
                *counts.entry(p.part_of(v as u32)).or_insert(0usize) += 1;
            }
            let dominant = counts.values().max().copied().unwrap_or(0);
            if dominant * 2 > nodes.len() {
                agree += 1;
            }
        }
        assert!(agree >= 3, "only {agree}/4 planted communities recovered");
    }

    #[test]
    fn deterministic_per_seed() {
        let g = karate_graph();
        let cfg = LeidenConfig { seed: 7, ..Default::default() };
        assert_eq!(leiden(&g, &cfg).assignments(), leiden(&g, &cfg).assignments());
    }

    /// Regression for the pre-overhaul nondeterminism workaround: with the
    /// scratch kernel, neighbour-community order is first-touch order by
    /// construction, so a fixed seed must give byte-identical labels — on
    /// a graph big enough to take several refinement levels, and for
    /// every thread count.
    #[test]
    fn fixed_seed_is_byte_identical_across_runs_and_threads() {
        let g = generate_sbm(&SbmConfig::arxiv_like(1500, 6)).unwrap().graph;
        let cap = g.num_nodes() / 7;
        let base = LeidenConfig {
            max_community_size: cap,
            seed: 11,
            ..Default::default()
        };
        let reference = leiden(&g, &base);
        let rerun = leiden(&g, &base);
        assert_eq!(reference.assignments(), rerun.assignments(), "rerun drifted");
        for threads in [2, 4] {
            let cfg = LeidenConfig { threads, ..base.clone() };
            assert_eq!(
                reference.assignments(),
                leiden(&g, &cfg).assignments(),
                "threads={threads} drifted"
            );
        }
    }

    #[test]
    fn modularity_of_trivial_partition_is_nonpositive() {
        let g = karate_graph();
        let p = Partitioning::new(vec![0; 34], 1).unwrap();
        let q = modularity(&g, &p, 1.0);
        assert!(q.abs() < 1e-9, "single community modularity must be 0, got {q}");
    }

    #[test]
    fn leiden_fusion_end_to_end_karate() {
        let g = karate_graph();
        let p = leiden_fusion(&g, 2, 0.05, 0.5, 1).unwrap();
        assert_eq!(p.k(), 2);
        for part in 0..2u32 {
            let info = components_within(&g, &p.mask(part));
            assert_eq!(info.num_components(), 1);
            assert_eq!(info.isolated, 0);
        }
    }
}
