//! Leiden community detection (Traag et al. 2019) with the paper's
//! community-size cap, plus the combined Leiden-Fusion partitioner.
//!
//! Implements the full three-phase algorithm:
//!  1. **Fast local moving** — queue-driven modularity-maximising moves.
//!  2. **Refinement** — communities are re-partitioned from singletons by
//!     randomised merges restricted to the community, which is what gives
//!     Leiden its well-connectedness guarantee over Louvain.
//!  3. **Aggregation** — the refined partition becomes a super-node graph
//!     whose communities seed the next level.
//!
//! Definition 1 of the paper adds a max community size `S`; any move or
//! merge that would exceed `S` (counted in *original* nodes) is rejected.

use super::fusion::{fuse_communities, FusionConfig};
use super::{Partitioner, Partitioning};
use crate::error::Result;
use crate::graph::{CsrGraph, NodeId};
use crate::util::rng::Rng;

/// Leiden parameters.
#[derive(Clone, Debug)]
pub struct LeidenConfig {
    /// Modularity resolution γ (paper eq. 4).
    pub gamma: f64,
    /// Max community size in original nodes (`usize::MAX` = uncapped);
    /// the paper's Definition 1 `S = β · max_part_size`.
    pub max_community_size: usize,
    /// Randomness of refinement merges (θ in the Leiden paper).
    pub theta: f64,
    /// Max aggregation levels (safety bound; convergence is usually < 6).
    pub max_levels: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for LeidenConfig {
    fn default() -> Self {
        LeidenConfig {
            gamma: 1.0,
            max_community_size: usize::MAX,
            theta: 0.01,
            max_levels: 10,
            seed: 0,
        }
    }
}

/// One level of the algorithm operates on a (possibly aggregated) graph.
struct Level {
    graph: CsrGraph,
    /// Original-node count carried by each super-node.
    node_count: Vec<usize>,
    /// Community of each super-node.
    comm: Vec<u32>,
    /// Self-loop weight of each super-node (edges internal to the refined
    /// community it was contracted from). CSR forbids literal self-loops,
    /// so the weight is carried here; it contributes 2w to the node degree
    /// in the modularity null model.
    self_weight: Vec<f64>,
}

impl Level {
    /// Modularity degree: weighted degree + twice the self-loop weight.
    #[inline]
    fn degree(&self, v: NodeId) -> f64 {
        self.graph.weighted_degree(v) + 2.0 * self.self_weight[v as usize]
    }
}

/// Community-level aggregates maintained incrementally.
struct CommStats {
    /// Sum of weighted degrees of members.
    degree: Vec<f64>,
    /// Sum of original-node counts of members.
    size: Vec<usize>,
    /// Number of super-node members (0 ⇒ dead community).
    members: Vec<usize>,
}

impl CommStats {
    fn init(level: &Level) -> Self {
        let n = level.graph.num_nodes();
        let mut s = CommStats {
            degree: vec![0.0; n],
            size: vec![0; n],
            members: vec![0; n],
        };
        for v in 0..n {
            let c = level.comm[v] as usize;
            s.degree[c] += level.degree(v as NodeId);
            s.size[c] += level.node_count[v];
            s.members[c] += 1;
        }
        s
    }

    fn remove(&mut self, c: usize, deg: f64, size: usize) {
        self.degree[c] -= deg;
        self.size[c] -= size;
        self.members[c] -= 1;
    }

    fn insert(&mut self, c: usize, deg: f64, size: usize) {
        self.degree[c] += deg;
        self.size[c] += size;
        self.members[c] += 1;
    }
}

/// Run Leiden; returns community labels (dense `0..n_comms`) per node.
pub fn leiden(g: &CsrGraph, cfg: &LeidenConfig) -> Partitioning {
    let n = g.num_nodes();
    if n == 0 {
        return Partitioning::from_labels(&[]);
    }
    let total_weight = g.total_weight().max(f64::MIN_POSITIVE);
    let mut rng = Rng::new(cfg.seed);

    // assignment of original nodes, refined level by level
    let mut global_comm: Vec<u32> = (0..n as u32).collect();
    let mut level = Level {
        graph: g.clone(),
        node_count: vec![1; n],
        comm: (0..n as u32).collect(),
        self_weight: vec![0.0; n],
    };

    for _ in 0..cfg.max_levels {
        let moved = local_move(&mut level, cfg, total_weight, &mut rng);
        let n_comms = compact(&mut level.comm);
        if !moved && n_comms == level.graph.num_nodes() {
            break; // converged: every super-node is its own community
        }

        // Refinement: sub-partition each community from singletons.
        let mut refined_dense = refine(&level, cfg, total_weight, &mut rng);
        let n_refined = compact(&mut refined_dense);

        if n_refined == level.graph.num_nodes() {
            // Refinement kept every super-node separate → aggregation would
            // not shrink the graph; the local-move communities are final.
            break;
        }

        // Map original nodes onto next level's super-nodes.
        for gc in global_comm.iter_mut() {
            *gc = refined_dense[*gc as usize];
        }

        // Aggregate refined communities into super-nodes; seed their
        // community from the local-move partition.
        level = aggregate(&level, &refined_dense, n_refined);
        if level.graph.num_nodes() <= 1 {
            break;
        }
    }

    // Final labels: community of each super-node at the last level.
    let mut final_comm = level.comm.clone();
    compact(&mut final_comm);
    let labels: Vec<u32> = global_comm
        .iter()
        .map(|&sc| final_comm[sc as usize])
        .collect();
    Partitioning::from_labels(&labels)
}

/// Queue-driven local moving phase. Returns whether any node moved.
fn local_move(level: &mut Level, cfg: &LeidenConfig, m: f64, rng: &mut Rng) -> bool {
    let n = level.graph.num_nodes();
    let mut stats = CommStats::init(level);
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut in_queue = vec![true; n];
    let mut queue: std::collections::VecDeque<u32> = order.into_iter().collect();
    let mut moved_any = false;

    // scratch: neighbour-community edge weights
    let mut nbr_comms: Vec<u32> = Vec::new();
    let mut w_to: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();

    while let Some(v) = queue.pop_front() {
        in_queue[v as usize] = false;
        let vc = level.comm[v as usize];
        let k_v = level.degree(v);
        let size_v = level.node_count[v as usize];

        nbr_comms.clear();
        w_to.clear();
        for (i, &u) in level.graph.neighbors(v).iter().enumerate() {
            let c = level.comm[u as usize];
            let w = level.graph.weight_at(v, i) as f64;
            let e = w_to.entry(c).or_insert(0.0);
            if *e == 0.0 {
                nbr_comms.push(c);
            }
            *e += w;
        }

        // Gain of joining community c (after removing v from its own):
        //   ΔQ ∝ w(v→c) − γ·k_v·K_c / (2m)
        stats.remove(vc as usize, k_v, size_v);
        let w_stay = w_to.get(&vc).copied().unwrap_or(0.0);
        let gain_stay = w_stay - cfg.gamma * k_v * stats.degree[vc as usize] / (2.0 * m);
        let mut best_c = vc;
        let mut best_gain = gain_stay;
        for &c in &nbr_comms {
            if c == vc {
                continue;
            }
            if stats.size[c as usize] + size_v > cfg.max_community_size {
                continue; // Definition 1: size cap
            }
            let gain = w_to[&c] - cfg.gamma * k_v * stats.degree[c as usize] / (2.0 * m);
            if gain > best_gain + 1e-12 {
                best_gain = gain;
                best_c = c;
            }
        }
        stats.insert(best_c as usize, k_v, size_v);
        if best_c != vc {
            level.comm[v as usize] = best_c;
            moved_any = true;
            // re-queue neighbours now outside v's new community
            for &u in level.graph.neighbors(v) {
                if level.comm[u as usize] != best_c && !in_queue[u as usize] {
                    in_queue[u as usize] = true;
                    queue.push_back(u);
                }
            }
        }
    }
    moved_any
}

/// Refinement phase: within each local-move community, re-partition from
/// singletons by randomised positive-gain merges (θ-weighted), keeping the
/// size cap. Returns refined community labels (sparse).
fn refine(level: &Level, cfg: &LeidenConfig, m: f64, rng: &mut Rng) -> Vec<u32> {
    let n = level.graph.num_nodes();
    let mut refined: Vec<u32> = (0..n as u32).collect();
    // aggregates for refined communities
    let mut r_degree: Vec<f64> = (0..n).map(|v| level.degree(v as NodeId)).collect();
    let mut r_size: Vec<usize> = level.node_count.clone();
    let mut r_members: Vec<usize> = vec![1; n];

    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);

    let mut cands: Vec<(u32, f64)> = Vec::new();
    // first-seen-ordered neighbour refined communities (HashMap iteration
    // order is per-instance random — iterating it would break determinism)
    let mut seen_rcs: Vec<u32> = Vec::new();
    let mut w_to: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();

    for &v in &order {
        // only singleton refined communities may merge (Leiden invariant)
        if r_members[refined[v as usize] as usize] != 1 {
            continue;
        }
        let vc = level.comm[v as usize];
        let k_v = level.degree(v);
        let size_v = level.node_count[v as usize];
        w_to.clear();
        seen_rcs.clear();
        for (i, &u) in level.graph.neighbors(v).iter().enumerate() {
            if level.comm[u as usize] != vc {
                continue; // refinement stays inside the community
            }
            let rc = refined[u as usize];
            if rc == refined[v as usize] {
                continue;
            }
            let e = w_to.entry(rc).or_insert(0.0);
            if *e == 0.0 {
                seen_rcs.push(rc);
            }
            *e += level.graph.weight_at(v, i) as f64;
        }
        cands.clear();
        for &rc in &seen_rcs {
            if r_size[rc as usize] + size_v > cfg.max_community_size {
                continue;
            }
            let gain = w_to[&rc] - cfg.gamma * k_v * r_degree[rc as usize] / (2.0 * m);
            if gain > 0.0 {
                cands.push((rc, gain));
            }
        }
        if cands.is_empty() {
            continue;
        }
        // θ-randomised selection among positive-gain candidates
        let weights: Vec<f64> = cands
            .iter()
            .map(|&(_, g)| (g / cfg.theta.max(1e-9)).min(500.0).exp())
            .collect();
        let pick = cands[rng.weighted_index(&weights)].0;
        let old = refined[v as usize];
        refined[v as usize] = pick;
        r_degree[pick as usize] += k_v;
        r_size[pick as usize] += size_v;
        r_members[pick as usize] += 1;
        r_degree[old as usize] -= k_v;
        r_size[old as usize] -= size_v;
        r_members[old as usize] -= 1;
    }
    refined
}

/// Build the next level: super-nodes = refined communities (dense ids),
/// each seeded with the local-move community of its members.
fn aggregate(level: &Level, refined_dense: &[u32], n_refined: usize) -> Level {
    let mut node_count = vec![0usize; n_refined];
    let mut seed_comm = vec![0u32; n_refined];
    let mut self_weight = vec![0.0f64; n_refined];
    for v in 0..level.graph.num_nodes() {
        let r = refined_dense[v] as usize;
        node_count[r] += level.node_count[v];
        seed_comm[r] = level.comm[v]; // all members share one community
        self_weight[r] += level.self_weight[v];
    }
    // sum edge weights between refined communities; internal edges become
    // super-node self-loop weight (kept out of CSR, carried separately)
    let mut agg: std::collections::HashMap<(u32, u32), f64> = std::collections::HashMap::new();
    for (u, v, w) in level.graph.edges() {
        let (ru, rv) = (refined_dense[u as usize], refined_dense[v as usize]);
        if ru == rv {
            self_weight[ru as usize] += w as f64;
            continue;
        }
        let key = if ru < rv { (ru, rv) } else { (rv, ru) };
        *agg.entry(key).or_insert(0.0) += w as f64;
    }
    let edges: Vec<(NodeId, NodeId)> = agg.keys().copied().collect();
    let weights: Vec<f32> = edges.iter().map(|k| agg[k] as f32).collect();
    let graph = CsrGraph::from_weighted_edges(n_refined, &edges, Some(&weights))
        .expect("aggregate edges are valid");
    // densify seed communities
    let mut comm = seed_comm;
    compact(&mut comm);
    Level { graph, node_count, comm, self_weight }
}

/// Relabel to dense `0..k`; returns k.
fn compact(labels: &mut [u32]) -> usize {
    let mut remap: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    for l in labels.iter_mut() {
        let next = remap.len() as u32;
        *l = *remap.entry(*l).or_insert(next);
    }
    remap.len()
}

/// Modularity of a partitioning (paper eq. 4) — used by tests and benches.
pub fn modularity(g: &CsrGraph, p: &Partitioning, gamma: f64) -> f64 {
    let m = g.total_weight();
    if m <= 0.0 {
        return 0.0;
    }
    let mut e_c = vec![0.0f64; p.k()];
    let mut k_c = vec![0.0f64; p.k()];
    for (u, v, w) in g.edges() {
        if p.part_of(u) == p.part_of(v) {
            e_c[p.part_of(u) as usize] += w as f64;
        }
    }
    for v in 0..g.num_nodes() as NodeId {
        k_c[p.part_of(v) as usize] += g.weighted_degree(v);
    }
    let mut q = 0.0;
    for c in 0..p.k() {
        q += e_c[c] / m - gamma * (k_c[c] / (2.0 * m)).powi(2);
    }
    q
}

// ---------------------------------------------------------------------------
// Leiden-Fusion: the paper's Algorithm 1 end-to-end.
// ---------------------------------------------------------------------------

/// Run the paper's full two-step method: Leiden with size cap
/// `β · max_part_size`, then greedy fusion down to `k` partitions.
pub fn leiden_fusion(
    g: &CsrGraph,
    k: usize,
    alpha: f64,
    beta: f64,
    seed: u64,
) -> Result<Partitioning> {
    let max_part_size =
        ((g.num_nodes() as f64 / k as f64) * (1.0 + alpha)).ceil() as usize;
    let cap = ((beta * max_part_size as f64).ceil() as usize).max(1);
    let cfg = LeidenConfig {
        max_community_size: cap,
        seed,
        ..LeidenConfig::default()
    };
    let communities = leiden(g, &cfg);
    fuse_communities(g, &communities, &FusionConfig { k, max_part_size })
}

/// [`Partitioner`] wrapper with the paper's hyper-parameters
/// (α = 0.05, β = 0.5 — §5 "Hyperparameter Settings").
pub struct LeidenFusionPartitioner {
    pub alpha: f64,
    pub beta: f64,
    pub seed: u64,
}

impl LeidenFusionPartitioner {
    pub fn new(seed: u64) -> Self {
        LeidenFusionPartitioner { alpha: 0.05, beta: 0.5, seed }
    }
}

impl Partitioner for LeidenFusionPartitioner {
    fn name(&self) -> &'static str {
        "lf"
    }

    fn partition(&self, g: &CsrGraph, k: usize) -> Result<Partitioning> {
        leiden_fusion(g, k, self.alpha, self.beta, self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_sbm, SbmConfig};
    use crate::graph::karate::karate_graph;
    use crate::graph::components_within;

    #[test]
    fn karate_communities_are_sane() {
        let g = karate_graph();
        let p = leiden(&g, &LeidenConfig { seed: 1, ..Default::default() });
        // canonical Leiden/Louvain output is ~4 communities at γ=1
        assert!((2..=6).contains(&p.k()), "got {} communities", p.k());
        let q = modularity(&g, &p, 1.0);
        assert!(q > 0.35, "modularity {q}"); // optimum ≈ 0.42
    }

    #[test]
    fn communities_are_connected() {
        let g = karate_graph();
        let p = leiden(&g, &LeidenConfig { seed: 3, ..Default::default() });
        for part in 0..p.k() as u32 {
            let info = components_within(&g, &p.mask(part));
            assert_eq!(info.num_components(), 1, "community {part} disconnected");
            assert_eq!(info.isolated, 0);
        }
    }

    #[test]
    fn size_cap_is_respected() {
        let g = karate_graph();
        let cap = 10;
        let p = leiden(
            &g,
            &LeidenConfig { max_community_size: cap, seed: 5, ..Default::default() },
        );
        for (i, &s) in p.sizes().iter().enumerate() {
            assert!(s <= cap, "community {i} has {s} > cap {cap}");
        }
    }

    #[test]
    fn improves_modularity_over_singletons() {
        let g = generate_sbm(&SbmConfig::arxiv_like(800, 2)).unwrap().graph;
        let p = leiden(&g, &LeidenConfig { seed: 2, ..Default::default() });
        let q = modularity(&g, &p, 1.0);
        assert!(q > 0.3, "modularity {q}");
        assert!(p.k() < g.num_nodes() / 4);
    }

    #[test]
    fn recovers_planted_structure_roughly() {
        let sbm = generate_sbm(&SbmConfig {
            n: 600,
            communities: 4,
            avg_degree: 12.0,
            p_in: 0.9,
            degree_exponent: 3.0,
            weight_range: None,
            seed: 9,
        })
        .unwrap();
        let p = leiden(&sbm.graph, &LeidenConfig { seed: 4, ..Default::default() });
        // most planted communities should map to a dominant detected one
        let mut agree = 0usize;
        for planted in 0..4u32 {
            let nodes: Vec<usize> = (0..600)
                .filter(|&v| sbm.community[v] == planted)
                .collect();
            let mut counts = std::collections::HashMap::new();
            for &v in &nodes {
                *counts.entry(p.part_of(v as u32)).or_insert(0usize) += 1;
            }
            let dominant = counts.values().max().copied().unwrap_or(0);
            if dominant * 2 > nodes.len() {
                agree += 1;
            }
        }
        assert!(agree >= 3, "only {agree}/4 planted communities recovered");
    }

    #[test]
    fn deterministic_per_seed() {
        let g = karate_graph();
        let cfg = LeidenConfig { seed: 7, ..Default::default() };
        assert_eq!(leiden(&g, &cfg).assignments(), leiden(&g, &cfg).assignments());
    }

    #[test]
    fn modularity_of_trivial_partition_is_nonpositive() {
        let g = karate_graph();
        let p = Partitioning::new(vec![0; 34], 1).unwrap();
        let q = modularity(&g, &p, 1.0);
        assert!(q.abs() < 1e-9, "single community modularity must be 0, got {q}");
    }

    #[test]
    fn leiden_fusion_end_to_end_karate() {
        let g = karate_graph();
        let p = leiden_fusion(&g, 2, 0.05, 0.5, 1).unwrap();
        assert_eq!(p.k(), 2);
        for part in 0..2u32 {
            let info = components_within(&g, &p.mask(part));
            assert_eq!(info.num_components(), 1);
            assert_eq!(info.isolated, 0);
        }
    }
}
