//! Epoch-stamped dense scratch buffers — the shared kernel under every
//! partitioning hot path.
//!
//! The Leiden/Louvain local-move loops, Leiden refinement, and the fusion
//! cut computation all need the same primitive: accumulate edge weights
//! from one node to each neighbouring community, inspect the few
//! communities actually touched, and move on. A `HashMap` per node visit
//! (the pre-overhaul implementation) pays hashing plus an allocation per
//! visit; [`NeighborWeights`] replaces it with dense arrays cleared in
//! O(touched) via an epoch stamp:
//!
//! * `w_to[key]` holds the accumulated weight, valid only when
//!   `stamp[key]` equals the current epoch;
//! * [`NeighborWeights::begin`] bumps the epoch — an O(1) "clear";
//! * [`NeighborWeights::touched`] lists the keys hit since `begin` in
//!   **first-touch order**, which is fully determined by the caller's
//!   neighbour iteration order. This is what makes candidate enumeration
//!   deterministic by construction — the first-seen side list the old
//!   code kept to paper over `HashMap` iteration order is gone.

/// Dense `u32 key → f64 weight` accumulator with O(1) epoch clears.
#[derive(Debug, Default)]
pub struct NeighborWeights {
    w_to: Vec<f64>,
    stamp: Vec<u32>,
    touched: Vec<u32>,
    epoch: u32,
}

impl NeighborWeights {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure capacity for keys `0..n` and invalidate every entry.
    /// Reusing one buffer across calls keeps the hot loops allocation-free
    /// once the high-water mark is reached.
    pub fn reset(&mut self, n: usize) {
        if self.w_to.len() < n {
            self.w_to.resize(n, 0.0);
            self.stamp.resize(n, 0);
        }
        self.touched.clear();
        self.bump_epoch();
    }

    /// Start a fresh accumulation: previous entries are invalidated by the
    /// epoch stamp, not by touching the dense arrays — O(1) plus the
    /// truncation of the touched list.
    #[inline]
    pub fn begin(&mut self) {
        self.touched.clear();
        self.bump_epoch();
    }

    fn bump_epoch(&mut self) {
        // On wrap, stale stamps could alias the new epoch — do the one
        // full clear every 2^32 - 1 epochs that correctness needs.
        if self.epoch == u32::MAX {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 1;
        } else {
            self.epoch += 1;
        }
    }

    /// Add `w` to `key`'s accumulator. The first touch of a key registers
    /// it in [`Self::touched`].
    #[inline]
    pub fn add(&mut self, key: u32, w: f64) {
        let i = key as usize;
        if self.stamp[i] == self.epoch {
            self.w_to[i] += w;
        } else {
            self.stamp[i] = self.epoch;
            self.w_to[i] = w;
            self.touched.push(key);
        }
    }

    /// Accumulated weight for `key`; 0.0 when untouched since `begin`.
    #[inline]
    pub fn get(&self, key: u32) -> f64 {
        let i = key as usize;
        if self.stamp[i] == self.epoch {
            self.w_to[i]
        } else {
            0.0
        }
    }

    /// Keys touched since `begin`, in first-touch order.
    #[inline]
    pub fn touched(&self) -> &[u32] {
        &self.touched
    }

    pub fn len(&self) -> usize {
        self.touched.len()
    }

    pub fn is_empty(&self) -> bool {
        self.touched.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_lists_first_touch_order() {
        let mut nw = NeighborWeights::new();
        nw.reset(10);
        nw.begin();
        nw.add(3, 1.0);
        nw.add(7, 2.0);
        nw.add(3, 0.5);
        nw.add(0, 4.0);
        assert_eq!(nw.touched(), &[3, 7, 0]);
        assert_eq!(nw.get(3), 1.5);
        assert_eq!(nw.get(7), 2.0);
        assert_eq!(nw.get(0), 4.0);
        assert_eq!(nw.get(5), 0.0);
        assert_eq!(nw.len(), 3);
    }

    #[test]
    fn begin_clears_in_o1() {
        let mut nw = NeighborWeights::new();
        nw.reset(4);
        nw.begin();
        nw.add(2, 1.0);
        nw.begin();
        assert!(nw.is_empty());
        assert_eq!(nw.get(2), 0.0);
        nw.add(2, 3.0);
        assert_eq!(nw.get(2), 3.0);
        assert_eq!(nw.touched(), &[2]);
    }

    #[test]
    fn reset_grows_and_invalidates() {
        let mut nw = NeighborWeights::new();
        nw.reset(2);
        nw.begin();
        nw.add(1, 9.0);
        nw.reset(8);
        assert_eq!(nw.get(1), 0.0);
        nw.begin();
        nw.add(7, 1.0);
        assert_eq!(nw.get(7), 1.0);
    }

    #[test]
    fn epoch_wrap_does_not_resurrect_entries() {
        let mut nw = NeighborWeights::new();
        nw.reset(3);
        nw.epoch = u32::MAX - 1;
        nw.begin(); // epoch = MAX
        nw.add(1, 5.0);
        nw.begin(); // wraps: full stamp clear, epoch = 1
        assert_eq!(nw.get(1), 0.0);
        assert!(nw.is_empty());
        nw.add(1, 2.0);
        assert_eq!(nw.get(1), 2.0);
    }

    #[test]
    fn matches_hashmap_reference_on_random_streams() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        let mut nw = NeighborWeights::new();
        nw.reset(64);
        for _ in 0..50 {
            nw.begin();
            let mut reference: std::collections::HashMap<u32, f64> =
                std::collections::HashMap::new();
            for _ in 0..rng.index(40) {
                let key = rng.index(64) as u32;
                let w = rng.f64();
                nw.add(key, w);
                *reference.entry(key).or_insert(0.0) += w;
            }
            assert_eq!(nw.len(), reference.len());
            for (&k, &w) in &reference {
                assert!((nw.get(k) - w).abs() < 1e-12);
            }
        }
    }
}
