//! Shared machinery of the multilevel community detectors: the per-level
//! graph state, community aggregates, the **single** modularity
//! local-move routine both Leiden and Louvain run (they differ only in
//! scheduling policy), and aggregation onto the next level via the
//! sort-based [`CsrGraph::coarsen`] builder.
//!
//! Before the hot-path overhaul, `leiden.rs` and `louvain.rs` each
//! carried a near-identical copy of this code with a `HashMap` allocated
//! per node visit; the shared routine runs on an epoch-stamped
//! [`NeighborWeights`] scratch buffer instead (O(degree) per visit, zero
//! allocation in steady state).

use super::scratch::NeighborWeights;
use crate::graph::{CsrGraph, NodeId};
use crate::util::rng::Rng;
use std::collections::VecDeque;

/// One level of a multilevel community detector: a (possibly aggregated)
/// graph plus per-super-node carry data.
pub struct Level {
    pub graph: CsrGraph,
    /// Original-node count carried by each super-node.
    pub node_count: Vec<usize>,
    /// Community of each super-node.
    pub comm: Vec<u32>,
    /// Self-loop weight of each super-node (edges internal to the
    /// community it was contracted from). CSR forbids literal self-loops,
    /// so the weight is carried here; it contributes 2w to the node degree
    /// in the modularity null model.
    pub self_weight: Vec<f64>,
}

impl Level {
    /// The finest level: every node is its own super-node and community.
    pub fn singleton(graph: CsrGraph) -> Level {
        let n = graph.num_nodes();
        Level {
            graph,
            node_count: vec![1; n],
            comm: (0..n as u32).collect(),
            self_weight: vec![0.0; n],
        }
    }

    /// Modularity degree: weighted degree + twice the self-loop weight.
    #[inline]
    pub fn degree(&self, v: NodeId) -> f64 {
        self.graph.weighted_degree(v) + 2.0 * self.self_weight[v as usize]
    }

    /// Build the next level by contracting dense labels `0..n_coarse`.
    /// With `seed_from_comm` each super-node's community is seeded from
    /// its members' current community, compacted (Leiden: the refined
    /// partition aggregates, the local-move partition seeds); otherwise
    /// every super-node starts as its own community (Louvain).
    pub fn aggregate(
        &self,
        dense: &[u32],
        n_coarse: usize,
        seed_from_comm: bool,
        threads: usize,
    ) -> Level {
        let mut node_count = vec![0usize; n_coarse];
        let mut self_weight = vec![0.0f64; n_coarse];
        for v in 0..self.graph.num_nodes() {
            let c = dense[v] as usize;
            node_count[c] += self.node_count[v];
            self_weight[c] += self.self_weight[v];
        }
        let (graph, internal) = self.graph.coarsen(dense, n_coarse, threads);
        for (sw, w) in self_weight.iter_mut().zip(&internal) {
            *sw += w;
        }
        let comm = if seed_from_comm {
            let mut seed = vec![0u32; n_coarse];
            for v in 0..self.graph.num_nodes() {
                // all members of a refined community share one community
                seed[dense[v] as usize] = self.comm[v];
            }
            compact(&mut seed);
            seed
        } else {
            (0..n_coarse as u32).collect()
        };
        Level { graph, node_count, comm, self_weight }
    }
}

/// Community-level aggregates maintained incrementally during local moves.
pub struct CommStats {
    /// Sum of modularity degrees of members.
    pub degree: Vec<f64>,
    /// Sum of original-node counts of members (Definition 1's size).
    pub size: Vec<usize>,
}

impl CommStats {
    pub fn init(level: &Level) -> Self {
        let n = level.graph.num_nodes();
        let mut s = CommStats { degree: vec![0.0; n], size: vec![0; n] };
        for v in 0..n {
            let c = level.comm[v] as usize;
            s.degree[c] += level.degree(v as NodeId);
            s.size[c] += level.node_count[v];
        }
        s
    }

    #[inline]
    fn remove(&mut self, c: usize, deg: f64, size: usize) {
        self.degree[c] -= deg;
        self.size[c] -= size;
    }

    #[inline]
    fn insert(&mut self, c: usize, deg: f64, size: usize) {
        self.degree[c] += deg;
        self.size[c] += size;
    }
}

/// Scheduling policy of the shared local-move routine. The modularity
/// objective, size cap, and candidate evaluation are identical; only the
/// visit order differs — which is exactly the published difference
/// between the two algorithms' moving phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MovePolicy {
    /// Leiden's fast local moving: a work queue seeded with a shuffled
    /// node order; a successful move re-queues the neighbours it affects.
    Queue,
    /// Louvain's classic sweep: full shuffled passes until a pass makes
    /// no move.
    Sweep,
}

/// Greedy modularity local moving over one level. Returns whether any
/// node moved. `m` is the graph's total edge weight, `cap` the
/// Definition 1 community-size bound in original nodes.
pub fn local_move(
    level: &mut Level,
    policy: MovePolicy,
    gamma: f64,
    cap: usize,
    m: f64,
    rng: &mut Rng,
    scratch: &mut NeighborWeights,
) -> bool {
    let n = level.graph.num_nodes();
    if n == 0 {
        return false;
    }
    scratch.reset(n); // community ids live in 0..n at every level
    let mut stats = CommStats::init(level);
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut moved_any = false;

    match policy {
        MovePolicy::Queue => {
            let mut in_queue = vec![true; n];
            let mut queue: VecDeque<u32> = order.into_iter().collect();
            while let Some(v) = queue.pop_front() {
                in_queue[v as usize] = false;
                let vc = level.comm[v as usize];
                let best = best_move(level, &mut stats, scratch, v, gamma, cap, m);
                if best != vc {
                    level.comm[v as usize] = best;
                    moved_any = true;
                    // re-queue neighbours now outside v's new community
                    for &u in level.graph.neighbors(v) {
                        if level.comm[u as usize] != best && !in_queue[u as usize] {
                            in_queue[u as usize] = true;
                            queue.push_back(u);
                        }
                    }
                }
            }
        }
        MovePolicy::Sweep => loop {
            let mut moved = false;
            for &v in &order {
                let vc = level.comm[v as usize];
                let best = best_move(level, &mut stats, scratch, v, gamma, cap, m);
                if best != vc {
                    level.comm[v as usize] = best;
                    moved = true;
                    moved_any = true;
                }
            }
            if !moved {
                break;
            }
        },
    }
    moved_any
}

/// Evaluate `v`'s best community under the modularity gain
/// `ΔQ ∝ w(v→c) − γ·k_v·K_c / (2m)` and update `stats` as if the move
/// were applied (staying put re-inserts into the old community). The
/// caller applies the label change.
#[inline]
fn best_move(
    level: &Level,
    stats: &mut CommStats,
    scratch: &mut NeighborWeights,
    v: u32,
    gamma: f64,
    cap: usize,
    m: f64,
) -> u32 {
    let vc = level.comm[v as usize];
    let k_v = level.degree(v);
    let size_v = level.node_count[v as usize];

    scratch.begin();
    for (i, &u) in level.graph.neighbors(v).iter().enumerate() {
        scratch.add(level.comm[u as usize], level.graph.weight_at(v, i) as f64);
    }

    stats.remove(vc as usize, k_v, size_v);
    let mut best_c = vc;
    let mut best_gain =
        scratch.get(vc) - gamma * k_v * stats.degree[vc as usize] / (2.0 * m);
    for &c in scratch.touched() {
        if c == vc {
            continue;
        }
        if stats.size[c as usize] + size_v > cap {
            continue; // Definition 1: size cap
        }
        let gain = scratch.get(c) - gamma * k_v * stats.degree[c as usize] / (2.0 * m);
        if gain > best_gain + 1e-12 {
            best_gain = gain;
            best_c = c;
        }
    }
    stats.insert(best_c as usize, k_v, size_v);
    best_c
}

/// Relabel to dense `0..k` in first-seen order; returns `k`. Labels are
/// near-dense on every caller (community ids are node ids at each level),
/// so the remap is a flat array instead of a hash map.
pub fn compact(labels: &mut [u32]) -> usize {
    let cap = labels.iter().map(|&l| l as usize + 1).max().unwrap_or(0);
    let mut remap = vec![u32::MAX; cap];
    let mut next = 0u32;
    for l in labels.iter_mut() {
        let slot = &mut remap[*l as usize];
        if *slot == u32::MAX {
            *slot = next;
            next += 1;
        }
        *l = *slot;
    }
    next as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate::karate_graph;

    #[test]
    fn compact_is_first_seen_dense() {
        let mut labels = vec![4u32, 4, 1, 3, 1, 0];
        let k = compact(&mut labels);
        assert_eq!(k, 4);
        assert_eq!(labels, vec![0, 0, 1, 2, 1, 3]);
        let mut empty: Vec<u32> = vec![];
        assert_eq!(compact(&mut empty), 0);
    }

    #[test]
    fn singleton_level_degrees_match_graph() {
        let g = karate_graph();
        let level = Level::singleton(g.clone());
        for v in 0..g.num_nodes() as NodeId {
            assert_eq!(level.degree(v), g.weighted_degree(v));
        }
        assert_eq!(level.node_count, vec![1; g.num_nodes()]);
    }

    #[test]
    fn local_move_policies_improve_modularity() {
        use crate::partition::leiden::modularity;
        use crate::partition::Partitioning;
        let g = karate_graph();
        let m = g.total_weight();
        for policy in [MovePolicy::Queue, MovePolicy::Sweep] {
            let mut level = Level::singleton(g.clone());
            let mut rng = Rng::new(3);
            let mut scratch = NeighborWeights::new();
            let moved =
                local_move(&mut level, policy, 1.0, usize::MAX, m, &mut rng, &mut scratch);
            assert!(moved, "{policy:?} moved nothing");
            let p = Partitioning::from_labels(&level.comm);
            assert!(p.k() < g.num_nodes());
            assert!(modularity(&g, &p, 1.0) > 0.3, "{policy:?}");
        }
    }

    #[test]
    fn aggregate_conserves_node_count_and_weight() {
        let g = karate_graph();
        let m = g.total_weight();
        let mut level = Level::singleton(g.clone());
        let mut rng = Rng::new(5);
        let mut scratch = NeighborWeights::new();
        local_move(&mut level, MovePolicy::Queue, 1.0, usize::MAX, m, &mut rng, &mut scratch);
        let mut dense = level.comm.clone();
        let k = compact(&mut dense);
        let agg = level.aggregate(&dense, k, false, 1);
        assert_eq!(agg.graph.num_nodes(), k);
        assert_eq!(agg.node_count.iter().sum::<usize>(), g.num_nodes());
        // total weight (edges + self loops) is conserved by contraction
        let total = agg.graph.total_weight() + agg.self_weight.iter().sum::<f64>();
        assert!((total - m).abs() < 1e-6, "{total} vs {m}");
    }

    #[test]
    fn aggregate_seeds_communities_from_members() {
        let g = karate_graph();
        let m = g.total_weight();
        let mut level = Level::singleton(g.clone());
        let mut rng = Rng::new(7);
        let mut scratch = NeighborWeights::new();
        local_move(&mut level, MovePolicy::Queue, 1.0, usize::MAX, m, &mut rng, &mut scratch);
        let n_comms = compact(&mut level.comm);
        // refine-as-identity: every super-node keeps its community
        let dense: Vec<u32> = (0..g.num_nodes() as u32).collect();
        let agg = level.aggregate(&dense, g.num_nodes(), true, 1);
        let mut expect = level.comm.clone();
        compact(&mut expect);
        assert_eq!(agg.comm, expect);
        assert!(n_comms >= 2);
    }
}
