//! Louvain community detection (Blondel et al. 2008) — the predecessor the
//! Leiden paper improves on, implemented as an ablation baseline.
//!
//! Since the hot-path overhaul this is a thin configuration over the
//! shared `super::level` machinery: the same modularity local-move
//! routine as Leiden under `MovePolicy::Sweep` instead of
//! `MovePolicy::Queue`, and **no refinement phase** — communities move
//! as whole blocks between levels, which is exactly what lets Louvain
//! produce internally-disconnected communities (Traag et al. 2019,
//! Fig. 1 — the defect that motivates Leiden, and transitively
//! Leiden-Fusion). The `ablation_fusion` bench quantifies the difference
//! on our workloads.

use super::fusion::{fuse_communities, split_into_components, FusionConfig};
use super::level::{compact, local_move, Level, MovePolicy};
use super::scratch::NeighborWeights;
use super::{Partitioner, Partitioning};
use crate::error::Result;
use crate::graph::CsrGraph;
use crate::util::rng::Rng;

/// Louvain parameters (subset of Leiden's — no θ, no refinement).
#[derive(Clone, Debug)]
pub struct LouvainConfig {
    pub gamma: f64,
    /// Max community size in original nodes (Definition 1's S).
    pub max_community_size: usize,
    pub max_levels: usize,
    pub seed: u64,
    /// Worker threads for aggregation (the sweep itself is sequential).
    pub threads: usize,
}

impl Default for LouvainConfig {
    fn default() -> Self {
        LouvainConfig {
            gamma: 1.0,
            max_community_size: usize::MAX,
            max_levels: 10,
            seed: 0,
            threads: 1,
        }
    }
}

/// Run Louvain; returns dense community labels.
pub fn louvain(g: &CsrGraph, cfg: &LouvainConfig) -> Partitioning {
    let n = g.num_nodes();
    if n == 0 {
        return Partitioning::from_labels(&[]);
    }
    let m = g.total_weight().max(f64::MIN_POSITIVE);
    let mut rng = Rng::new(cfg.seed);
    let mut scratch = NeighborWeights::new();
    let mut global: Vec<u32> = (0..n as u32).collect();
    let mut level = Level::singleton(g.clone());

    for _ in 0..cfg.max_levels {
        let moved = local_move(
            &mut level,
            MovePolicy::Sweep,
            cfg.gamma,
            cfg.max_community_size,
            m,
            &mut rng,
            &mut scratch,
        );
        let mut dense = level.comm.clone();
        let n_comms = compact(&mut dense);
        if !moved || n_comms == level.graph.num_nodes() {
            break;
        }
        // aggregate directly on the local-move communities (no refinement)
        for gcv in global.iter_mut() {
            *gcv = dense[*gcv as usize];
        }
        level = level.aggregate(&dense, n_comms, false, cfg.threads);
        if level.graph.num_nodes() <= 1 {
            break;
        }
    }
    let mut final_comm = level.comm.clone();
    compact(&mut final_comm);
    let labels: Vec<u32> = global.iter().map(|&sc| final_comm[sc as usize]).collect();
    Partitioning::from_labels(&labels)
}

/// Louvain-Fusion: the ablation counterpart of [`super::leiden::leiden_fusion`].
/// Louvain communities may be disconnected, so (unlike Leiden) a
/// component-split pass is required before fusing — the extra cost the
/// paper's §5.4 attributes to METIS/LPA applies to Louvain too.
pub fn louvain_fusion(
    g: &CsrGraph,
    k: usize,
    alpha: f64,
    beta: f64,
    seed: u64,
) -> Result<Partitioning> {
    let max_part_size =
        ((g.num_nodes() as f64 / k as f64) * (1.0 + alpha)).ceil() as usize;
    let cap = ((beta * max_part_size as f64).ceil() as usize).max(1);
    let cfg = LouvainConfig { max_community_size: cap, seed, ..Default::default() };
    let communities = louvain(g, &cfg);
    // split potential disconnected communities before fusion
    let split = split_into_components(g, &communities);
    fuse_communities(g, &split, &FusionConfig { k, max_part_size })
}

/// [`Partitioner`] wrapper for the ablation bench.
pub struct LouvainFusionPartitioner {
    pub seed: u64,
}

impl Partitioner for LouvainFusionPartitioner {
    fn name(&self) -> &'static str {
        "louvain+f"
    }

    fn partition(&self, g: &CsrGraph, k: usize) -> Result<Partitioning> {
        louvain_fusion(g, k, 0.05, 0.5, self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components_within;
    use crate::graph::gen::{generate_sbm, SbmConfig};
    use crate::graph::karate::karate_graph;
    use crate::partition::leiden::modularity;

    #[test]
    fn finds_sane_communities_on_karate() {
        let g = karate_graph();
        let p = louvain(&g, &LouvainConfig { seed: 1, ..Default::default() });
        assert!((2..=6).contains(&p.k()), "{} communities", p.k());
        assert!(modularity(&g, &p, 1.0) > 0.3);
    }

    #[test]
    fn respects_size_cap() {
        let g = karate_graph();
        let p = louvain(&g, &LouvainConfig { max_community_size: 8, seed: 2, ..Default::default() });
        assert!(p.sizes().iter().all(|&s| s <= 8), "{:?}", p.sizes());
    }

    #[test]
    fn louvain_fusion_is_structurally_ideal() {
        let g = generate_sbm(&SbmConfig::arxiv_like(1500, 4)).unwrap().graph;
        let p = louvain_fusion(&g, 4, 0.05, 0.5, 1).unwrap();
        assert_eq!(p.k(), 4);
        for part in 0..4u32 {
            let info = components_within(&g, &p.mask(part));
            assert_eq!(info.num_components(), 1);
            assert_eq!(info.isolated, 0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let g = karate_graph();
        let cfg = LouvainConfig { seed: 5, ..Default::default() };
        assert_eq!(louvain(&g, &cfg).assignments(), louvain(&g, &cfg).assignments());
    }

    #[test]
    fn thread_count_does_not_change_labels() {
        let g = generate_sbm(&SbmConfig::arxiv_like(900, 3)).unwrap().graph;
        let base = LouvainConfig { seed: 8, ..Default::default() };
        let reference = louvain(&g, &base);
        let par = louvain(&g, &LouvainConfig { threads: 4, ..base });
        assert_eq!(reference.assignments(), par.assignments());
    }

    #[test]
    fn comparable_modularity_to_leiden() {
        let g = generate_sbm(&SbmConfig::arxiv_like(1200, 8)).unwrap().graph;
        let ql = modularity(&g, &louvain(&g, &LouvainConfig { seed: 3, ..Default::default() }), 1.0);
        let qe = modularity(
            &g,
            &crate::partition::leiden::leiden(
                &g,
                &crate::partition::leiden::LeidenConfig { seed: 3, ..Default::default() },
            ),
            1.0,
        );
        // Louvain is usually close; Leiden must not be dramatically worse
        assert!(ql > 0.3 && qe > 0.3, "louvain {ql}, leiden {qe}");
    }
}
