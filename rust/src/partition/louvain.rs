//! Louvain community detection (Blondel et al. 2008) — the predecessor the
//! Leiden paper improves on, implemented as an ablation baseline.
//!
//! Identical modularity objective and aggregation scheme as
//! [`super::leiden`], but **no refinement phase**: communities move as
//! whole blocks between levels, which is exactly what lets Louvain produce
//! internally-disconnected communities (Traag et al. 2019, Fig. 1 — the
//! defect that motivates Leiden, and transitively Leiden-Fusion). The
//! `ablation_fusion` bench quantifies the difference on our workloads.

use super::fusion::{fuse_communities, split_into_components, FusionConfig};
use super::{Partitioner, Partitioning};
use crate::error::Result;
use crate::graph::{CsrGraph, NodeId};
use crate::util::rng::Rng;

/// Louvain parameters (subset of Leiden's — no θ, no refinement).
#[derive(Clone, Debug)]
pub struct LouvainConfig {
    pub gamma: f64,
    /// Max community size in original nodes (Definition 1's S).
    pub max_community_size: usize,
    pub max_levels: usize,
    pub seed: u64,
}

impl Default for LouvainConfig {
    fn default() -> Self {
        LouvainConfig {
            gamma: 1.0,
            max_community_size: usize::MAX,
            max_levels: 10,
            seed: 0,
        }
    }
}

struct Level {
    graph: CsrGraph,
    node_count: Vec<usize>,
    self_weight: Vec<f64>,
    comm: Vec<u32>,
}

impl Level {
    #[inline]
    fn degree(&self, v: NodeId) -> f64 {
        self.graph.weighted_degree(v) + 2.0 * self.self_weight[v as usize]
    }
}

/// Run Louvain; returns dense community labels.
pub fn louvain(g: &CsrGraph, cfg: &LouvainConfig) -> Partitioning {
    let n = g.num_nodes();
    if n == 0 {
        return Partitioning::from_labels(&[]);
    }
    let m = g.total_weight().max(f64::MIN_POSITIVE);
    let mut rng = Rng::new(cfg.seed);
    let mut global: Vec<u32> = (0..n as u32).collect();
    let mut level = Level {
        graph: g.clone(),
        node_count: vec![1; n],
        self_weight: vec![0.0; n],
        comm: (0..n as u32).collect(),
    };

    for _ in 0..cfg.max_levels {
        let moved = local_move(&mut level, cfg, m, &mut rng);
        let mut dense = level.comm.clone();
        let n_comms = compact(&mut dense);
        if !moved || n_comms == level.graph.num_nodes() {
            break;
        }
        // aggregate directly on the local-move communities (no refinement)
        for gcv in global.iter_mut() {
            *gcv = dense[*gcv as usize];
        }
        level = aggregate(&level, &dense, n_comms);
        if level.graph.num_nodes() <= 1 {
            break;
        }
    }
    let mut final_comm = level.comm.clone();
    compact(&mut final_comm);
    let labels: Vec<u32> = global.iter().map(|&sc| final_comm[sc as usize]).collect();
    Partitioning::from_labels(&labels)
}

fn local_move(level: &mut Level, cfg: &LouvainConfig, m: f64, rng: &mut Rng) -> bool {
    let n = level.graph.num_nodes();
    let mut deg_c = vec![0.0f64; n];
    let mut size_c = vec![0usize; n];
    for v in 0..n {
        deg_c[level.comm[v] as usize] += level.degree(v as NodeId);
        size_c[level.comm[v] as usize] += level.node_count[v];
    }
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut moved_any = false;
    let mut nbr_comms: Vec<u32> = Vec::new();
    let mut w_to: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();

    // classic Louvain: sweep until a full pass makes no move
    loop {
        let mut moved = false;
        for &v in &order {
            let vc = level.comm[v as usize];
            let k_v = level.degree(v);
            let size_v = level.node_count[v as usize];
            nbr_comms.clear();
            w_to.clear();
            for (i, &u) in level.graph.neighbors(v).iter().enumerate() {
                let c = level.comm[u as usize];
                let e = w_to.entry(c).or_insert(0.0);
                if *e == 0.0 {
                    nbr_comms.push(c);
                }
                *e += level.graph.weight_at(v, i) as f64;
            }
            deg_c[vc as usize] -= k_v;
            size_c[vc as usize] -= size_v;
            let w_stay = w_to.get(&vc).copied().unwrap_or(0.0);
            let mut best = vc;
            let mut best_gain = w_stay - cfg.gamma * k_v * deg_c[vc as usize] / (2.0 * m);
            for &c in &nbr_comms {
                if c == vc || size_c[c as usize] + size_v > cfg.max_community_size {
                    continue;
                }
                let gain = w_to[&c] - cfg.gamma * k_v * deg_c[c as usize] / (2.0 * m);
                if gain > best_gain + 1e-12 {
                    best_gain = gain;
                    best = c;
                }
            }
            deg_c[best as usize] += k_v;
            size_c[best as usize] += size_v;
            if best != vc {
                level.comm[v as usize] = best;
                moved = true;
                moved_any = true;
            }
        }
        if !moved {
            break;
        }
    }
    moved_any
}

fn aggregate(level: &Level, dense: &[u32], n_comms: usize) -> Level {
    let mut node_count = vec![0usize; n_comms];
    let mut self_weight = vec![0.0f64; n_comms];
    for v in 0..level.graph.num_nodes() {
        let c = dense[v] as usize;
        node_count[c] += level.node_count[v];
        self_weight[c] += level.self_weight[v];
    }
    let mut agg: std::collections::HashMap<(u32, u32), f64> = std::collections::HashMap::new();
    for (u, v, w) in level.graph.edges() {
        let (cu, cv) = (dense[u as usize], dense[v as usize]);
        if cu == cv {
            self_weight[cu as usize] += w as f64;
            continue;
        }
        let key = if cu < cv { (cu, cv) } else { (cv, cu) };
        *agg.entry(key).or_insert(0.0) += w as f64;
    }
    let edges: Vec<(NodeId, NodeId)> = agg.keys().copied().collect();
    let weights: Vec<f32> = edges.iter().map(|k| agg[k] as f32).collect();
    let graph = CsrGraph::from_weighted_edges(n_comms, &edges, Some(&weights))
        .expect("aggregate edges valid");
    Level {
        graph,
        node_count,
        self_weight,
        comm: (0..n_comms as u32).collect(),
    }
}

fn compact(labels: &mut [u32]) -> usize {
    let mut remap: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    for l in labels.iter_mut() {
        let next = remap.len() as u32;
        *l = *remap.entry(*l).or_insert(next);
    }
    remap.len()
}

/// Louvain-Fusion: the ablation counterpart of [`super::leiden::leiden_fusion`].
/// Louvain communities may be disconnected, so (unlike Leiden) a
/// component-split pass is required before fusing — the extra cost the
/// paper's §5.4 attributes to METIS/LPA applies to Louvain too.
pub fn louvain_fusion(
    g: &CsrGraph,
    k: usize,
    alpha: f64,
    beta: f64,
    seed: u64,
) -> Result<Partitioning> {
    let max_part_size =
        ((g.num_nodes() as f64 / k as f64) * (1.0 + alpha)).ceil() as usize;
    let cap = ((beta * max_part_size as f64).ceil() as usize).max(1);
    let cfg = LouvainConfig { max_community_size: cap, seed, ..Default::default() };
    let communities = louvain(g, &cfg);
    // split potential disconnected communities before fusion
    let split = split_into_components(g, &communities);
    fuse_communities(g, &split, &FusionConfig { k, max_part_size })
}

/// [`Partitioner`] wrapper for the ablation bench.
pub struct LouvainFusionPartitioner {
    pub seed: u64,
}

impl Partitioner for LouvainFusionPartitioner {
    fn name(&self) -> &'static str {
        "louvain+f"
    }

    fn partition(&self, g: &CsrGraph, k: usize) -> Result<Partitioning> {
        louvain_fusion(g, k, 0.05, 0.5, self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_sbm, SbmConfig};
    use crate::graph::karate::karate_graph;
    use crate::graph::components_within;
    use crate::partition::leiden::modularity;

    #[test]
    fn finds_sane_communities_on_karate() {
        let g = karate_graph();
        let p = louvain(&g, &LouvainConfig { seed: 1, ..Default::default() });
        assert!((2..=6).contains(&p.k()), "{} communities", p.k());
        assert!(modularity(&g, &p, 1.0) > 0.3);
    }

    #[test]
    fn respects_size_cap() {
        let g = karate_graph();
        let p = louvain(&g, &LouvainConfig { max_community_size: 8, seed: 2, ..Default::default() });
        assert!(p.sizes().iter().all(|&s| s <= 8), "{:?}", p.sizes());
    }

    #[test]
    fn louvain_fusion_is_structurally_ideal() {
        let g = generate_sbm(&SbmConfig::arxiv_like(1500, 4)).unwrap().graph;
        let p = louvain_fusion(&g, 4, 0.05, 0.5, 1).unwrap();
        assert_eq!(p.k(), 4);
        for part in 0..4u32 {
            let info = components_within(&g, &p.mask(part));
            assert_eq!(info.num_components(), 1);
            assert_eq!(info.isolated, 0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let g = karate_graph();
        let cfg = LouvainConfig { seed: 5, ..Default::default() };
        assert_eq!(louvain(&g, &cfg).assignments(), louvain(&g, &cfg).assignments());
    }

    #[test]
    fn comparable_modularity_to_leiden() {
        let g = generate_sbm(&SbmConfig::arxiv_like(1200, 8)).unwrap().graph;
        let ql = modularity(&g, &louvain(&g, &LouvainConfig { seed: 3, ..Default::default() }), 1.0);
        let qe = modularity(
            &g,
            &crate::partition::leiden::leiden(
                &g,
                &crate::partition::leiden::LeidenConfig { seed: 3, ..Default::default() },
            ),
            1.0,
        );
        // Louvain is usually close; Leiden must not be dramatically worse
        assert!(ql > 0.3 && qe > 0.3, "louvain {ql}, leiden {qe}");
    }
}
