//! Community fusion — the paper's Algorithm 1 (Leiden-Fusion) and
//! Algorithm 2 (LargestEdgeCutNeighbor), plus the "+F" adapter of §5.4
//! that applies fusion to the output of *any* partitioner by first
//! splitting its partitions into connected components.
//!
//! Invariant: if the input communities are each connected and the graph is
//! connected, every output partition is connected with no isolated nodes —
//! merging two communities joined by a cut edge preserves connectivity.

use super::{Partitioner, Partitioning};
use crate::error::{Error, Result};
use crate::graph::{components_within, CsrGraph, NodeId};
use crate::util::parallel::map_chunks;
use std::cmp::Reverse;
// lint: allow(nondet_iter) — CutMap values are u64 counts folded with commutative sums, and every min/max over it uses a total-order key; see the field note on CutMap::per
use std::collections::{BinaryHeap, HashMap};

/// Fusion parameters (Algorithm 1 inputs).
#[derive(Clone, Debug)]
pub struct FusionConfig {
    /// Target number of partitions (≙ machines).
    pub k: usize,
    /// `size(G)/k · (1+α)` — the balance bound (Algorithm 1 line 3).
    pub max_part_size: usize,
}

impl FusionConfig {
    /// From the paper's α parameter.
    pub fn with_alpha(g: &CsrGraph, k: usize, alpha: f64) -> Self {
        let max_part_size =
            ((g.num_nodes() as f64 / k as f64) * (1.0 + alpha)).ceil() as usize;
        FusionConfig { k, max_part_size }
    }
}

/// Mutable community state during fusion.
struct FusionState {
    /// Community id per node (community ids are *not* dense during fusion).
    assign: Vec<u32>,
    /// Members per live community (dead communities have empty vecs).
    members: Vec<Vec<NodeId>>,
    /// Live community count.
    live: usize,
}

impl FusionState {
    fn from_partitioning(p: &Partitioning) -> Self {
        FusionState {
            assign: p.assignments().to_vec(),
            // cached size counts: no rescan of the member lists
            live: p.sizes().iter().filter(|&&s| s > 0).count(),
            members: p.members(),
        }
    }

    fn size(&self, c: u32) -> usize {
        self.members[c as usize].len()
    }

    /// Merge community `from` into `into`.
    fn merge(&mut self, from: u32, into: u32) {
        debug_assert_ne!(from, into);
        let moved = std::mem::take(&mut self.members[from as usize]);
        for &v in &moved {
            self.assign[v as usize] = into;
        }
        self.members[into as usize].extend(moved);
        self.live -= 1;
    }
}

/// Inter-community cut-edge counts, maintained **incrementally** across
/// merges. `per[c]` maps each neighbouring community of `c` to the number
/// of cut edges between them (symmetric: `per[a][b] == per[b][a]`).
///
/// The pre-overhaul implementation recomputed the popped community's cut
/// from scratch on every merge — O(cut edges of that community) per
/// iteration. Folding `from`'s map into `into`'s on merge makes each
/// query O(neighbouring communities) and each merge O(degree of `from`
/// in the community graph).
struct CutMap {
    /// Iteration order never leaks: merges fold commutative u64 sums and
    /// both selection sites key on a total order over (count, community).
    // lint: allow(nondet_iter) — order-independent by the argument above, asserted against a from-scratch recomputation under debug_assertions
    per: Vec<HashMap<u32, u64>>,
}

impl CutMap {
    /// One boundary scan over the graph, fanned out over node chunks.
    /// Each chunk run-length-encodes its sorted directed boundary pairs;
    /// the ordered reduction sums integer counts, so the result is
    /// identical for every thread count.
    fn build(g: &CsrGraph, assign: &[u32], n_comms: usize, threads: usize) -> CutMap {
        let chunks = map_chunks(threads, g.num_nodes(), 4096, |_, range| {
            let mut pairs: Vec<(u32, u32)> = Vec::new();
            for u in range {
                let cu = assign[u];
                for &v in g.neighbors(u as NodeId) {
                    let cv = assign[v as usize];
                    if cu != cv {
                        pairs.push((cu, cv));
                    }
                }
            }
            pairs.sort_unstable();
            let mut enc: Vec<(u32, u32, u64)> = Vec::new();
            for &(a, b) in &pairs {
                match enc.last_mut() {
                    Some(last) if last.0 == a && last.1 == b => last.2 += 1,
                    _ => enc.push((a, b, 1)),
                }
            }
            enc
        });
        // lint: allow(nondet_iter) — see the CutMap::per note: commutative counts, total-order selection
        let mut per: Vec<HashMap<u32, u64>> = vec![HashMap::new(); n_comms];
        for enc in chunks {
            for (a, b, cnt) in enc {
                *per[a as usize].entry(b).or_insert(0) += cnt;
            }
        }
        CutMap { per }
    }

    /// Fold community `from` into `into`, rewriting every neighbour's
    /// back-reference. Edges between `from` and `into` become internal
    /// and leave the map.
    fn merge(&mut self, from: u32, into: u32) {
        debug_assert_ne!(from, into);
        let from_map = std::mem::take(&mut self.per[from as usize]);
        for (c, w) in from_map {
            if c == into {
                continue;
            }
            let back = self.per[c as usize].remove(&from).unwrap_or(0);
            debug_assert_eq!(back, w, "cut map asymmetric between {c} and {from}");
            *self.per[c as usize].entry(into).or_insert(0) += w;
            *self.per[into as usize].entry(c).or_insert(0) += w;
        }
        self.per[into as usize].remove(&from);
    }
}

/// Algorithm 2: the most-connected neighbour of `v_comm` whose merged size
/// stays under `max_part_size`; if none qualifies, the smallest neighbour.
/// Returns `None` only if `v_comm` has no neighbouring community at all
/// (impossible for a connected graph with ≥ 2 communities).
fn largest_edge_cut_neighbor(
    _g: &CsrGraph,
    st: &FusionState,
    cuts: &CutMap,
    v_comm: u32,
    max_part_size: usize,
) -> Option<u32> {
    let cut = &cuts.per[v_comm as usize];
    // The incremental map must always equal a from-scratch recomputation
    // of the queried community's cut (the pre-overhaul code path).
    #[cfg(debug_assertions)]
    {
        // lint: allow(nondet_iter) — debug-only oracle compared for set equality, never iterated into an ordered result
        let mut reference: HashMap<u32, u64> = HashMap::new();
        for &v in &st.members[v_comm as usize] {
            for &u in _g.neighbors(v) {
                let c = st.assign[u as usize];
                if c != v_comm {
                    *reference.entry(c).or_insert(0) += 1;
                }
            }
        }
        debug_assert_eq!(
            cut, &reference,
            "incremental cut map drifted for community {v_comm}"
        );
    }
    if cut.is_empty() {
        return None;
    }
    let v_size = st.size(v_comm);
    // N = neighbours within the size bound (Algorithm 2 line 3)
    let best_within = cut
        .iter()
        .filter(|&(&c, _)| st.size(c) + v_size < max_part_size)
        // deterministic tie-break on community id
        .max_by_key(|&(&c, &w)| (w, Reverse(c)))
        .map(|(&c, _)| c);
    best_within.or_else(|| {
        // fallback: smallest neighbour (Algorithm 2 line 7)
        cut.keys()
            .copied()
            .min_by_key(|&c| (st.size(c), c))
    })
}

/// Algorithm 1: iteratively merge the smallest community into its largest
/// edge-cut neighbour until exactly `k` communities remain.
pub fn fuse_communities(
    g: &CsrGraph,
    communities: &Partitioning,
    cfg: &FusionConfig,
) -> Result<Partitioning> {
    fuse_communities_threaded(g, communities, cfg, 1)
}

/// [`fuse_communities`] with an explicit thread count for the initial
/// boundary-cut scan (the merge loop itself is inherently sequential).
/// The result is identical for every thread count.
pub fn fuse_communities_threaded(
    g: &CsrGraph,
    communities: &Partitioning,
    cfg: &FusionConfig,
    threads: usize,
) -> Result<Partitioning> {
    if cfg.k == 0 {
        return Err(Error::Partition("k must be positive".into()));
    }
    if communities.num_nodes() != g.num_nodes() {
        return Err(Error::Partition(format!(
            "partitioning covers {} nodes, graph has {}",
            communities.num_nodes(),
            g.num_nodes()
        )));
    }
    let mut st = FusionState::from_partitioning(communities);
    if st.live < cfg.k {
        return Err(Error::Partition(format!(
            "cannot fuse {} communities up to k={} (need k ≤ communities)",
            st.live, cfg.k
        )));
    }
    let mut cuts = CutMap::build(g, &st.assign, st.members.len(), threads);

    // Min-heap of (size, community) with lazy invalidation.
    let mut heap: BinaryHeap<Reverse<(usize, u32)>> = BinaryHeap::new();
    for (c, m) in st.members.iter().enumerate() {
        if !m.is_empty() {
            heap.push(Reverse((m.len(), c as u32)));
        }
    }

    while st.live > cfg.k {
        let Reverse((size, c_min)) = heap.pop().ok_or_else(|| {
            Error::Partition("fusion heap exhausted before reaching k".into())
        })?;
        // stale entry? (community merged away or grew)
        if st.members[c_min as usize].len() != size || size == 0 {
            continue;
        }
        let target = match largest_edge_cut_neighbor(g, &st, &cuts, c_min, cfg.max_part_size)
        {
            Some(t) => t,
            None => {
                // disconnected community (can only happen on disconnected
                // inputs): merge with the globally smallest other community
                let other = st
                    .members
                    .iter()
                    .enumerate()
                    .filter(|&(c, m)| c as u32 != c_min && !m.is_empty())
                    .min_by_key(|&(_, m)| m.len())
                    .map(|(c, _)| c as u32)
                    .ok_or_else(|| Error::Partition("no community to merge with".into()))?;
                other
            }
        };
        st.merge(c_min, target);
        cuts.merge(c_min, target);
        heap.push(Reverse((st.size(target), target)));
    }

    Ok(Partitioning::from_labels(&st.assign))
}

/// The "+F" adapter (§5.4): split an arbitrary partitioning into its
/// connected components (treating each component as a community — this is
/// the extra, costly step METIS/LPA need), then fuse down to `p.k()`.
///
/// Isolated nodes become singleton communities and are absorbed by fusion,
/// so the output has no isolated nodes on a connected graph.
#[deprecated(note = "run a `PartitionPipeline` with a `<detect>+fusion` spec instead")]
pub fn fuse_partitioning(g: &CsrGraph, p: &Partitioning) -> Result<Partitioning> {
    let cfg = FusionConfig::with_alpha(g, p.k(), 0.05);
    let components = split_into_components(g, p);
    fuse_communities(g, &components, &cfg)
}

/// Wraps a base partitioner with the +F pass. Deprecated alongside
/// [`super::by_name`]: a `<detect>+fusion` spec run through
/// `PartitionPipeline` replaces it.
#[deprecated(note = "run a `PartitionPipeline` with a `<detect>+fusion` spec instead")]
pub struct FusedPartitioner {
    base: Box<dyn Partitioner>,
}

#[allow(deprecated)]
impl FusedPartitioner {
    pub fn new(base: Box<dyn Partitioner>) -> Self {
        FusedPartitioner { base }
    }
}

#[allow(deprecated)]
impl Partitioner for FusedPartitioner {
    fn name(&self) -> &'static str {
        "+f"
    }

    fn partition(&self, g: &CsrGraph, k: usize) -> Result<Partitioning> {
        let p = self.base.partition(g, k)?;
        fuse_partitioning(g, &p)
    }
}

/// Relabel a partitioning so each connected component of each partition is
/// its own community.
pub fn split_into_components(g: &CsrGraph, p: &Partitioning) -> Partitioning {
    let mut labels = vec![0u32; g.num_nodes()];
    let mut next = 0u32;
    for part in 0..p.k() as u32 {
        let mask = p.mask(part);
        if !mask.iter().any(|&b| b) {
            continue;
        }
        let info = components_within(g, &mask);
        for v in 0..g.num_nodes() {
            if mask[v] {
                labels[v] = next + info.labels[v];
            }
        }
        next += info.num_components() as u32;
    }
    Partitioning::from_labels(&labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate::karate_graph;
    use crate::partition::leiden::{leiden, LeidenConfig};

    #[test]
    fn fuses_karate_to_two_connected_partitions() {
        let g = karate_graph();
        let comms = leiden(&g, &LeidenConfig { seed: 1, ..Default::default() });
        let k = 2;
        let cfg = FusionConfig::with_alpha(&g, k, 0.05);
        let p = fuse_communities(&g, &comms, &cfg).unwrap();
        assert_eq!(p.k(), 2);
        for part in 0..2u32 {
            let info = components_within(&g, &p.mask(part));
            assert_eq!(info.num_components(), 1);
            assert_eq!(info.isolated, 0);
        }
    }

    #[test]
    fn fusion_from_singletons_reaches_k() {
        let g = karate_graph();
        let singles = Partitioning::from_labels(&(0..34u32).collect::<Vec<_>>());
        let cfg = FusionConfig::with_alpha(&g, 4, 0.05);
        let p = fuse_communities(&g, &singles, &cfg).unwrap();
        assert_eq!(p.k(), 4);
        let sizes = p.sizes();
        assert!(sizes.iter().all(|&s| s > 0));
    }

    #[test]
    fn respects_size_bound_when_possible() {
        let g = karate_graph();
        let singles = Partitioning::from_labels(&(0..34u32).collect::<Vec<_>>());
        let cfg = FusionConfig { k: 2, max_part_size: 18 }; // 34/2·(1+.05)
        let p = fuse_communities(&g, &singles, &cfg).unwrap();
        let sizes = p.sizes();
        // α-bound: no partition exceeds max_part_size when a valid merge
        // order exists (karate admits one)
        assert!(sizes.iter().all(|&s| s <= 18), "{sizes:?}");
    }

    #[test]
    fn errors_when_k_exceeds_communities() {
        let g = karate_graph();
        let two = Partitioning::new(vec![0; 34], 1).unwrap();
        let cfg = FusionConfig { k: 5, max_part_size: 100 };
        assert!(fuse_communities(&g, &two, &cfg).is_err());
    }

    #[test]
    fn split_into_components_separates() {
        // path 0-1-2-3-4-5; partition {0,1,4,5} is two components
        let g = CsrGraph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)])
            .unwrap();
        let p = Partitioning::new(vec![0, 0, 1, 1, 0, 0], 2).unwrap();
        let split = split_into_components(&g, &p);
        assert_eq!(split.k(), 3);
    }

    #[test]
    #[allow(deprecated)]
    fn plus_f_fixes_disconnected_partitions() {
        let g = CsrGraph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)])
            .unwrap();
        // partition 0 = {0,1,4,5} (two components), partition 1 = {2,3}
        let p = Partitioning::new(vec![0, 0, 1, 1, 0, 0], 2).unwrap();
        let fused = fuse_partitioning(&g, &p).unwrap();
        assert_eq!(fused.k(), 2);
        for part in 0..2u32 {
            let info = components_within(&g, &fused.mask(part));
            assert_eq!(info.num_components(), 1, "partition {part} disconnected");
        }
    }

    #[test]
    fn threaded_fusion_matches_sequential() {
        use crate::graph::gen::{generate_sbm, SbmConfig};
        let g = generate_sbm(&SbmConfig::arxiv_like(1200, 5)).unwrap().graph;
        let comms = leiden(
            &g,
            &LeidenConfig { max_community_size: 80, seed: 3, ..Default::default() },
        );
        let cfg = FusionConfig::with_alpha(&g, 6, 0.05);
        let seq = fuse_communities_threaded(&g, &comms, &cfg, 1).unwrap();
        let par = fuse_communities_threaded(&g, &comms, &cfg, 4).unwrap();
        assert_eq!(seq.assignments(), par.assignments());
    }

    #[test]
    fn fusion_preserves_exact_cover() {
        let g = karate_graph();
        let comms = leiden(&g, &LeidenConfig { seed: 2, ..Default::default() });
        let cfg = FusionConfig::with_alpha(&g, 3, 0.05);
        let p = fuse_communities(&g, &comms, &cfg).unwrap();
        assert_eq!(p.num_nodes(), 34);
        assert_eq!(p.sizes().iter().sum::<usize>(), 34);
    }
}
