//! `PartitionSpec` — a parsed, validated description of a partitioning
//! strategy, shared by the CLI (`--spec`), the `[partition]` config
//! section, and every bench binary.
//!
//! Grammar (stages joined by `+`, optional `key=value` parameters):
//!
//! ```text
//! spec    := stage ('+' stage)* ['!novalidate']
//! stage   := name [ '(' param (',' param)* ')' ]
//! param   := key '=' value
//! ```
//!
//! The first stage must be a *detection* stage (`leiden`, `louvain`,
//! `metis`, `lpa`, `random`); later stages are *transforms* (`fusion`,
//! `balance`). `!novalidate` disables the final validation stage.
//!
//! Examples:
//!
//! ```text
//! leiden(gamma=0.7,beta=0.05)+fusion(alpha=0.05)
//! metis+fusion
//! lpa(iters=10,slack=0.2)
//! random
//! ```
//!
//! Every legacy method name is accepted as a degenerate spec: `lf` and
//! `leiden-fusion` are whole-string aliases for `leiden+fusion`, `f` is a
//! stage alias for `fusion` (so `metis+f`, `lpa+f`, `louvain+f` parse
//! naturally), `cap` is a parameter alias for `leiden`/`louvain`'s
//! `beta`, and `fusion` accepts `beta` as an alias for its `alpha`
//! balance slack (some "+F" literature calls the slack β — note this is
//! *unrelated* to the detect stages' size-cap `beta`). `FromStr` and
//! `Display` round-trip: parsing the canonical printed form yields an
//! equal spec.

use crate::error::{Error, Result};
use std::fmt;

/// Default modularity resolution γ for `leiden`/`louvain`.
pub const DEFAULT_GAMMA: f64 = 1.0;
/// Default community-size factor β (Definition 1: `S = β·max_part_size`).
pub const DEFAULT_BETA: f64 = 0.5;
/// Default Leiden refinement randomness θ.
pub const DEFAULT_THETA: f64 = 0.01;
/// Default balance slack α (`max_part_size = n/k·(1+α)`).
pub const DEFAULT_ALPHA: f64 = 0.05;
/// Default METIS imbalance tolerance.
pub const DEFAULT_IMBALANCE: f64 = 0.05;
/// Default LPA sweep budget.
pub const DEFAULT_LPA_ITERS: usize = 30;
/// Default LPA capacity slack.
pub const DEFAULT_LPA_SLACK: f64 = 0.10;
/// Default balance-stage slack.
pub const DEFAULT_BALANCE_SLACK: f64 = 0.05;

/// One stage of a partitioning strategy. Parameters are `None` when not
/// explicitly set, so `Display` can print only what the user wrote and
/// the pipeline can fill in context-dependent defaults (e.g. Leiden's
/// size cap is derived from the fusion stage's α).
#[derive(Clone, Debug, PartialEq)]
pub enum StageSpec {
    /// Leiden community detection (γ, size-cap factor β, refinement θ).
    Leiden {
        gamma: Option<f64>,
        beta: Option<f64>,
        theta: Option<f64>,
    },
    /// Louvain community detection (ablation baseline).
    Louvain { gamma: Option<f64>, beta: Option<f64> },
    /// METIS-style multilevel k-way partitioner.
    Metis { imbalance: Option<f64> },
    /// Spinner-style label propagation.
    Lpa {
        iters: Option<usize>,
        slack: Option<f64>,
    },
    /// Uniform random assignment.
    Random,
    /// Greedy community fusion down to k partitions (Algorithm 1).
    Fusion { alpha: Option<f64> },
    /// Post-fusion boundary rebalancing under a node-count cap.
    Balance { slack: Option<f64> },
}

impl StageSpec {
    /// Stage name as it appears in the grammar and progress events.
    pub fn name(&self) -> &'static str {
        match self {
            StageSpec::Leiden { .. } => "leiden",
            StageSpec::Louvain { .. } => "louvain",
            StageSpec::Metis { .. } => "metis",
            StageSpec::Lpa { .. } => "lpa",
            StageSpec::Random => "random",
            StageSpec::Fusion { .. } => "fusion",
            StageSpec::Balance { .. } => "balance",
        }
    }

    /// Detection stages produce a partitioning from scratch; transforms
    /// refine an upstream one.
    pub fn is_detect(&self) -> bool {
        matches!(
            self,
            StageSpec::Leiden { .. }
                | StageSpec::Louvain { .. }
                | StageSpec::Metis { .. }
                | StageSpec::Lpa { .. }
                | StageSpec::Random
        )
    }

    /// Explicitly-set parameters in canonical key order, for `Display`.
    fn params(&self) -> Vec<(&'static str, String)> {
        fn push_f(out: &mut Vec<(&'static str, String)>, key: &'static str, v: &Option<f64>) {
            if let Some(v) = v {
                out.push((key, format!("{v}")));
            }
        }
        let mut out = Vec::new();
        match self {
            StageSpec::Leiden { gamma, beta, theta } => {
                push_f(&mut out, "gamma", gamma);
                push_f(&mut out, "beta", beta);
                push_f(&mut out, "theta", theta);
            }
            StageSpec::Louvain { gamma, beta } => {
                push_f(&mut out, "gamma", gamma);
                push_f(&mut out, "beta", beta);
            }
            StageSpec::Metis { imbalance } => push_f(&mut out, "imbalance", imbalance),
            StageSpec::Lpa { iters, slack } => {
                if let Some(i) = iters {
                    out.push(("iters", format!("{i}")));
                }
                push_f(&mut out, "slack", slack);
            }
            StageSpec::Random => {}
            StageSpec::Fusion { alpha } => push_f(&mut out, "alpha", alpha),
            StageSpec::Balance { slack } => push_f(&mut out, "slack", slack),
        }
        out
    }
}

impl fmt::Display for StageSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())?;
        let params = self.params();
        if !params.is_empty() {
            let joined: Vec<String> =
                params.iter().map(|(k, v)| format!("{k}={v}")).collect();
            write!(f, "({})", joined.join(","))?;
        }
        Ok(())
    }
}

/// A full partitioning strategy: an ordered stage list plus whether the
/// final validation stage runs.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionSpec {
    stages: Vec<StageSpec>,
    validate: bool,
}

impl PartitionSpec {
    /// The ordered stage list (always starts with a detection stage).
    pub fn stages(&self) -> &[StageSpec] {
        &self.stages
    }

    /// Whether the strategy ends with the paper's fusion pass (and thus
    /// carries the structural guarantee on connected graphs).
    pub fn is_fused(&self) -> bool {
        self.stages
            .iter()
            .any(|s| matches!(s, StageSpec::Fusion { .. }))
    }

    /// Whether the pipeline appends the validation stage.
    pub fn validate_enabled(&self) -> bool {
        self.validate
    }

    /// Disable the validation stage (`!novalidate` in the grammar).
    pub fn without_validation(mut self) -> Self {
        self.validate = false;
        self
    }

    /// Override the fusion stage's balance slack α. Returns `false` when
    /// the spec has no fusion stage (the override is meaningless).
    pub fn set_fusion_alpha(&mut self, alpha: f64) -> bool {
        for st in &mut self.stages {
            if let StageSpec::Fusion { alpha: a } = st {
                *a = Some(alpha);
                return true;
            }
        }
        false
    }

    /// Override the detection stage's community-size factor β. Returns
    /// `false` for detectors without a size cap (metis/lpa/random).
    pub fn set_detect_beta(&mut self, beta: f64) -> bool {
        match self.stages.first_mut() {
            Some(StageSpec::Leiden { beta: b, .. })
            | Some(StageSpec::Louvain { beta: b, .. }) => {
                *b = Some(beta);
                true
            }
            _ => false,
        }
    }

    /// Structural validation: non-empty, detection first, transforms
    /// after, at most one fusion stage.
    fn check(&self) -> Result<()> {
        let first = self
            .stages
            .first()
            .ok_or_else(|| spec_err("empty spec"))?;
        if !first.is_detect() {
            return Err(spec_err(&format!(
                "spec must start with a detection stage, got {:?}",
                first.name()
            )));
        }
        let mut fusions = 0usize;
        let mut seen_balance = false;
        for st in &self.stages[1..] {
            if st.is_detect() {
                return Err(spec_err(&format!(
                    "detection stage {:?} must come first",
                    st.name()
                )));
            }
            match st {
                StageSpec::Fusion { .. } => {
                    if seen_balance {
                        // the documented order is detect → fuse → balance;
                        // balancing pre-fusion communities is meaningless
                        return Err(spec_err("fusion must come before balance"));
                    }
                    fusions += 1;
                }
                StageSpec::Balance { .. } => {
                    if seen_balance {
                        return Err(spec_err("at most one balance stage is allowed"));
                    }
                    seen_balance = true;
                }
                _ => {}
            }
        }
        if fusions > 1 {
            return Err(spec_err("at most one fusion stage is allowed"));
        }
        Ok(())
    }
}

impl Default for PartitionSpec {
    /// The paper's method: `leiden+fusion` with all-default parameters.
    fn default() -> Self {
        PartitionSpec {
            stages: vec![
                StageSpec::Leiden { gamma: None, beta: None, theta: None },
                StageSpec::Fusion { alpha: None },
            ],
            validate: true,
        }
    }
}

impl fmt::Display for PartitionSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, st) in self.stages.iter().enumerate() {
            if i > 0 {
                write!(f, "+")?;
            }
            write!(f, "{st}")?;
        }
        if !self.validate {
            write!(f, "!novalidate")?;
        }
        Ok(())
    }
}

impl std::str::FromStr for PartitionSpec {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        let raw = s.trim();
        if raw.is_empty() {
            return Err(spec_err("empty spec"));
        }
        let (body, validate) = match raw.strip_suffix("!novalidate") {
            Some(b) => (b.trim_end(), false),
            None => (raw, true),
        };
        // whole-string legacy aliases
        let body = match body {
            "lf" | "leiden-fusion" => "leiden+fusion",
            other => other,
        };
        let mut stages = Vec::new();
        for tok in split_stages(body)? {
            stages.push(parse_stage(tok)?);
        }
        let spec = PartitionSpec { stages, validate };
        spec.check()?;
        Ok(spec)
    }
}

fn spec_err(msg: &str) -> Error {
    Error::Partition(format!("spec: {msg}"))
}

/// Split on `+` outside parentheses; rejects unbalanced parens and empty
/// segments (trailing or doubled `+`).
fn split_stages(body: &str) -> Result<Vec<&str>> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, ch) in body.char_indices() {
        match ch {
            '(' => depth += 1,
            ')' => {
                depth = depth
                    .checked_sub(1)
                    .ok_or_else(|| spec_err("unbalanced ')'"))?;
            }
            '+' if depth == 0 => {
                out.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if depth != 0 {
        return Err(spec_err("unbalanced '('"));
    }
    out.push(&body[start..]);
    for tok in &out {
        if tok.trim().is_empty() {
            return Err(spec_err("empty stage (trailing or doubled '+')"));
        }
    }
    Ok(out)
}

fn parse_stage(tok: &str) -> Result<StageSpec> {
    let tok = tok.trim();
    let (name, params) = match tok.find('(') {
        Some(i) => {
            let inner = tok[i + 1..]
                .strip_suffix(')')
                .ok_or_else(|| spec_err(&format!("stage {tok:?}: missing ')'")))?;
            (tok[..i].trim(), parse_params(inner)?)
        }
        None => (tok, Vec::new()),
    };
    build_stage(name, &params)
}

fn parse_params(inner: &str) -> Result<Vec<(String, String)>> {
    if inner.trim().is_empty() {
        return Err(spec_err("empty parameter list '()'"));
    }
    inner
        .split(',')
        .map(|kv| {
            let (k, v) = kv.split_once('=').ok_or_else(|| {
                spec_err(&format!("parameter {kv:?}: expected key=value"))
            })?;
            Ok((k.trim().to_string(), v.trim().to_string()))
        })
        .collect()
}

fn parse_float(stage: &str, key: &str, v: &str) -> Result<f64> {
    let f: f64 = v.parse().map_err(|_| {
        spec_err(&format!("{stage}({key}=...): bad float {v:?}"))
    })?;
    if !f.is_finite() || f < 0.0 {
        return Err(spec_err(&format!(
            "{stage}({key}=...): value must be finite and non-negative"
        )));
    }
    Ok(f)
}

fn parse_usize(stage: &str, key: &str, v: &str) -> Result<usize> {
    let n: usize = v.parse().map_err(|_| {
        spec_err(&format!("{stage}({key}=...): bad integer {v:?}"))
    })?;
    if n == 0 {
        return Err(spec_err(&format!("{stage}({key}=...): must be positive")));
    }
    Ok(n)
}

/// Assign a parameter slot exactly once; a repeated key (or two aliases
/// of the same slot) is rejected, not silently last-wins.
fn set_once<T>(slot: &mut Option<T>, stage: &str, key: &str, val: T) -> Result<()> {
    if slot.is_some() {
        return Err(spec_err(&format!(
            "stage {stage:?}: parameter {key:?} duplicates or conflicts with an earlier one"
        )));
    }
    *slot = Some(val);
    Ok(())
}

fn build_stage(name: &str, params: &[(String, String)]) -> Result<StageSpec> {
    let unknown = |key: &str| {
        spec_err(&format!("stage {name:?}: unknown parameter {key:?}"))
    };
    match name {
        "leiden" => {
            let (mut gamma, mut beta, mut theta) = (None, None, None);
            for (k, v) in params {
                match k.as_str() {
                    "gamma" => set_once(&mut gamma, name, k, parse_float(name, k, v)?)?,
                    "beta" | "cap" => set_once(&mut beta, name, k, parse_float(name, k, v)?)?,
                    "theta" => set_once(&mut theta, name, k, parse_float(name, k, v)?)?,
                    other => return Err(unknown(other)),
                }
            }
            Ok(StageSpec::Leiden { gamma, beta, theta })
        }
        "louvain" => {
            let (mut gamma, mut beta) = (None, None);
            for (k, v) in params {
                match k.as_str() {
                    "gamma" => set_once(&mut gamma, name, k, parse_float(name, k, v)?)?,
                    "beta" | "cap" => set_once(&mut beta, name, k, parse_float(name, k, v)?)?,
                    other => return Err(unknown(other)),
                }
            }
            Ok(StageSpec::Louvain { gamma, beta })
        }
        "metis" => {
            let mut imbalance = None;
            for (k, v) in params {
                match k.as_str() {
                    "imbalance" => {
                        set_once(&mut imbalance, name, k, parse_float(name, k, v)?)?
                    }
                    other => return Err(unknown(other)),
                }
            }
            Ok(StageSpec::Metis { imbalance })
        }
        "lpa" => {
            let (mut iters, mut slack) = (None, None);
            for (k, v) in params {
                match k.as_str() {
                    "iters" => set_once(&mut iters, name, k, parse_usize(name, k, v)?)?,
                    "slack" => set_once(&mut slack, name, k, parse_float(name, k, v)?)?,
                    other => return Err(unknown(other)),
                }
            }
            Ok(StageSpec::Lpa { iters, slack })
        }
        "random" => {
            if !params.is_empty() {
                return Err(spec_err("stage \"random\" takes no parameters"));
            }
            Ok(StageSpec::Random)
        }
        "fusion" | "f" => {
            let mut alpha = None;
            for (k, v) in params {
                match k.as_str() {
                    "alpha" | "beta" => {
                        set_once(&mut alpha, "fusion", k, parse_float("fusion", k, v)?)?
                    }
                    other => return Err(unknown(other)),
                }
            }
            Ok(StageSpec::Fusion { alpha })
        }
        "balance" => {
            let mut slack = None;
            for (k, v) in params {
                match k.as_str() {
                    "slack" => set_once(&mut slack, name, k, parse_float(name, k, v)?)?,
                    other => return Err(unknown(other)),
                }
            }
            Ok(StageSpec::Balance { slack })
        }
        other => Err(spec_err(&format!("unknown stage {other:?}"))),
    }
}

/// The standard method registry: every legacy name plus the bare
/// community detectors, each resolved to its spec. The property tests
/// assert the paper's structural guarantee for every fused entry; bench
/// binaries keep curated sub-lists (their table layouts mirror the
/// paper's figures) but resolve every name through the same grammar.
pub fn registered_specs() -> Vec<(&'static str, PartitionSpec)> {
    [
        "lf", "leiden", "louvain", "metis", "lpa", "random", "metis+f",
        "lpa+f", "louvain+f",
    ]
    .iter()
    .map(|&name| {
        // lint: allow(panic_in_lib) — static literal registry; the spec round-trip tests parse every entry
        let spec: PartitionSpec = name.parse().expect("registered spec parses");
        (name, spec)
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> PartitionSpec {
        s.parse().unwrap_or_else(|e| panic!("spec {s:?}: {e}"))
    }

    #[test]
    fn legacy_names_parse_and_display() {
        let cases = [
            ("lf", "leiden+fusion"),
            ("leiden-fusion", "leiden+fusion"),
            ("leiden", "leiden"),
            ("louvain", "louvain"),
            ("metis", "metis"),
            ("lpa", "lpa"),
            ("random", "random"),
            ("metis+f", "metis+fusion"),
            ("lpa+f", "lpa+fusion"),
            ("louvain+f", "louvain+fusion"),
        ];
        for (input, canonical) in cases {
            let spec = parse(input);
            assert_eq!(spec.to_string(), canonical, "{input}");
            // canonical form round-trips to an equal spec
            assert_eq!(parse(canonical), spec, "{input}");
        }
    }

    #[test]
    fn parameters_round_trip() {
        let cases = [
            "leiden(gamma=0.7,beta=0.05)+fusion(alpha=0.1)",
            "leiden(theta=0.5)+fusion",
            "metis(imbalance=0.1)+fusion+balance(slack=0.2)",
            "lpa(iters=10,slack=0.2)",
            "louvain(gamma=2)+fusion",
            "random+fusion!novalidate",
        ];
        for s in cases {
            let spec = parse(s);
            let printed = spec.to_string();
            assert_eq!(parse(&printed), spec, "{s} → {printed}");
        }
    }

    #[test]
    fn cap_is_an_alias_for_beta() {
        assert_eq!(
            parse("leiden(cap=0.25)+fusion"),
            parse("leiden(beta=0.25)+fusion"),
        );
    }

    #[test]
    fn novalidate_suffix_disables_validation() {
        assert!(parse("lf").validate_enabled());
        assert!(!parse("lf!novalidate").validate_enabled());
        assert_eq!(parse("lf").without_validation(), parse("lf!novalidate"));
    }

    #[test]
    fn rejects_malformed_specs() {
        let bad = [
            "",
            "nope",
            "leiden+",
            "+fusion",
            "leiden++fusion",
            "fusion",
            "balance",
            "leiden+leiden",
            "leiden+fusion+fusion",
            "leiden+balance+fusion",
            "leiden+fusion+balance+balance",
            "leiden(gamma=1,gamma=2)+fusion",
            "leiden(beta=0.5,cap=0.5)+fusion",
            "leiden+fusion(alpha=0.02,beta=0.5)",
            "leiden(gamma=abc)+fusion",
            "leiden(gamma=-1)+fusion",
            "leiden()",
            "leiden(gamma=1",
            "leiden(cap)",
            "lpa(iters=0)",
            "random(x=1)",
            "leiden(wat=1)+fusion",
            "metis+unknown",
        ];
        for s in bad {
            assert!(s.parse::<PartitionSpec>().is_err(), "{s:?} should be rejected");
        }
    }

    #[test]
    fn overrides_target_the_right_stages() {
        let mut spec = parse("lf");
        assert!(spec.set_fusion_alpha(0.2));
        assert!(spec.set_detect_beta(0.3));
        assert_eq!(spec.to_string(), "leiden(beta=0.3)+fusion(alpha=0.2)");
        let mut bare = parse("metis");
        assert!(!bare.set_fusion_alpha(0.2));
        assert!(!bare.set_detect_beta(0.3));
    }

    #[test]
    fn registry_contains_all_legacy_names() {
        let reg = registered_specs();
        for name in ["lf", "leiden", "metis", "lpa", "random", "metis+f", "lpa+f", "louvain+f"] {
            assert!(reg.iter().any(|(n, _)| *n == name), "{name} missing");
        }
        let fused = reg.iter().filter(|(_, s)| s.is_fused()).count();
        assert_eq!(fused, 4, "lf, metis+f, lpa+f, louvain+f");
    }

    #[test]
    fn default_is_the_paper_method() {
        assert_eq!(PartitionSpec::default(), parse("lf"));
    }
}
