//! METIS-style multilevel k-way partitioner (§3.1 baseline) — built from
//! scratch (the real METIS is C and unavailable offline):
//!
//!  1. **Coarsening** — heavy-edge matching (HEM) contracts the graph until
//!     it is small, summing edge weights and node weights.
//!  2. **Initial partitioning** — greedy graph growing (GGP) on the
//!     coarsest graph: grow each region by absorbing the boundary node
//!     with the highest internal-edge gain until it reaches its share.
//!  3. **Uncoarsening** — project the assignment up each level and refine
//!     with Fiduccia–Mattheyses-style boundary passes under a balance
//!     constraint.
//!
//! Like METIS, it optimises edge-cut + node balance and is oblivious to
//! per-partition connectivity — exactly the weakness the paper exploits.

use super::{Partitioner, Partitioning};
use crate::error::Result;
use crate::graph::{CsrGraph, GraphBuilder, NodeId};
use crate::util::rng::Rng;

pub struct MetisPartitioner {
    pub seed: u64,
    /// Allowed imbalance: max part weight ≤ (1 + imbalance) · n/k.
    pub imbalance: f64,
    /// Stop coarsening below this many nodes (scaled by k).
    pub coarsen_until_per_part: usize,
    /// FM refinement passes per level.
    pub refine_passes: usize,
}

impl MetisPartitioner {
    pub fn new(seed: u64) -> Self {
        MetisPartitioner {
            seed,
            imbalance: 0.05,
            coarsen_until_per_part: 30,
            refine_passes: 4,
        }
    }
}

/// One level of the multilevel hierarchy.
struct CoarseLevel {
    graph: CsrGraph,
    /// Original-node weight of each coarse node.
    node_weight: Vec<usize>,
    /// Mapping fine node → coarse node in the *next* (coarser) level.
    fine_to_coarse: Vec<u32>,
}

impl Partitioner for MetisPartitioner {
    fn name(&self) -> &'static str {
        "metis"
    }

    fn partition(&self, g: &CsrGraph, k: usize) -> Result<Partitioning> {
        let n = g.num_nodes();
        if k <= 1 || n <= k {
            return Partitioning::new(
                (0..n).map(|v| (v % k.max(1)) as u32).collect(),
                k.max(1),
            );
        }
        let mut rng = Rng::new(self.seed);

        // ---- 1. coarsening ------------------------------------------------
        let mut levels: Vec<CoarseLevel> = Vec::new();
        let mut current = g.clone();
        let mut weights: Vec<usize> = vec![1; n];
        let target = (self.coarsen_until_per_part * k).max(64);
        while current.num_nodes() > target {
            let (coarse, cweights, mapping) =
                coarsen_hem(&current, &weights, &mut rng)?;
            // diminishing returns → stop
            if coarse.num_nodes() as f64 > 0.95 * current.num_nodes() as f64 {
                break;
            }
            levels.push(CoarseLevel {
                graph: std::mem::replace(&mut current, coarse),
                node_weight: std::mem::replace(&mut weights, cweights),
                fine_to_coarse: mapping,
            });
        }

        // ---- 2. initial partitioning on the coarsest graph ---------------
        let total_weight: usize = weights.iter().sum();
        let mut assign = greedy_growing(&current, &weights, k, total_weight, &mut rng);
        let cap = ((total_weight as f64 / k as f64) * (1.0 + self.imbalance)).ceil() as usize;
        fm_refine(&current, &weights, &mut assign, k, cap, self.refine_passes);

        // ---- 3. uncoarsen + refine ----------------------------------------
        while let Some(level) = levels.pop() {
            let mut fine_assign = vec![0u32; level.graph.num_nodes()];
            for v in 0..level.graph.num_nodes() {
                fine_assign[v] = assign[level.fine_to_coarse[v] as usize];
            }
            assign = fine_assign;
            fm_refine(
                &level.graph,
                &level.node_weight,
                &mut assign,
                k,
                cap,
                self.refine_passes,
            );
        }

        Partitioning::new(assign, k)
    }
}

/// Heavy-edge matching contraction. Returns (coarse graph, coarse node
/// weights, fine→coarse mapping).
fn coarsen_hem(
    g: &CsrGraph,
    weights: &[usize],
    rng: &mut Rng,
) -> Result<(CsrGraph, Vec<usize>, Vec<u32>)> {
    let n = g.num_nodes();
    let mut matched = vec![u32::MAX; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut next_coarse = 0u32;
    for &v in &order {
        if matched[v as usize] != u32::MAX {
            continue;
        }
        // heaviest unmatched neighbour
        let mut best: Option<(f32, NodeId)> = None;
        for (i, &u) in g.neighbors(v).iter().enumerate() {
            if matched[u as usize] == u32::MAX {
                let w = g.weight_at(v, i);
                if best.map_or(true, |(bw, _)| w > bw) {
                    best = Some((w, u));
                }
            }
        }
        match best {
            Some((_, u)) => {
                matched[v as usize] = next_coarse;
                matched[u as usize] = next_coarse;
            }
            None => {
                matched[v as usize] = next_coarse;
            }
        }
        next_coarse += 1;
    }
    let nc = next_coarse as usize;
    let mut cweights = vec![0usize; nc];
    for v in 0..n {
        cweights[matched[v] as usize] += weights[v];
    }
    let mut b = GraphBuilder::new(nc);
    for (u, v, w) in g.edges() {
        let (cu, cv) = (matched[u as usize], matched[v as usize]);
        if cu != cv {
            b.add_weighted(cu, cv, w);
        }
    }
    Ok((b.build()?, cweights, matched))
}

/// Greedy graph growing: regions 0..k-1 grow from random seeds by absorbing
/// the boundary node with max internal connectivity; leftovers go to the
/// lightest region.
fn greedy_growing(
    g: &CsrGraph,
    weights: &[usize],
    k: usize,
    total_weight: usize,
    rng: &mut Rng,
) -> Vec<u32> {
    let n = g.num_nodes();
    let share = total_weight / k;
    let mut assign = vec![u32::MAX; n];
    let mut remaining = n;

    for part in 0..k as u32 {
        if remaining == 0 {
            break;
        }
        // random unassigned seed
        let seed = loop {
            let v = rng.index(n) as u32;
            if assign[v as usize] == u32::MAX {
                break v;
            }
        };
        let mut grown = 0usize;
        let mut frontier: Vec<u32> = vec![seed];
        assign[seed as usize] = part;
        grown += weights[seed as usize];
        remaining -= 1;
        while grown < share && remaining > 0 {
            // pick the frontier-adjacent unassigned node with max gain
            let mut best: Option<(f64, u32)> = None;
            for &f in &frontier {
                for (i, &u) in g.neighbors(f).iter().enumerate() {
                    if assign[u as usize] == u32::MAX {
                        let w = g.weight_at(f, i) as f64;
                        if best.map_or(true, |(bw, _)| w > bw) {
                            best = Some((w, u));
                        }
                    }
                }
            }
            let next = match best {
                Some((_, u)) => u,
                None => break, // region can't grow further
            };
            assign[next as usize] = part;
            grown += weights[next as usize];
            remaining -= 1;
            frontier.push(next);
            if frontier.len() > 256 {
                // keep the frontier bounded: drop interior nodes
                frontier.retain(|&f| {
                    g.neighbors(f).iter().any(|&u| assign[u as usize] == u32::MAX)
                });
            }
        }
    }
    // leftovers → lightest partition (tracks METIS's balance fixup)
    let mut loads = vec![0usize; k];
    for v in 0..n {
        if assign[v] != u32::MAX {
            loads[assign[v] as usize] += weights[v];
        }
    }
    for v in 0..n {
        if assign[v] == u32::MAX {
            let lightest = (0..k).min_by_key(|&p| loads[p]).unwrap_or(0) as u32;
            assign[v] = lightest;
            loads[lightest as usize] += weights[v];
        }
    }
    assign
}

/// Boundary FM refinement: greedy positive-gain moves under a hard cap.
fn fm_refine(
    g: &CsrGraph,
    weights: &[usize],
    assign: &mut [u32],
    k: usize,
    cap: usize,
    passes: usize,
) {
    let n = g.num_nodes();
    let mut loads = vec![0usize; k];
    for v in 0..n {
        loads[assign[v] as usize] += weights[v];
    }
    let mut conn = vec![0.0f64; k]; // scratch: connectivity to each part

    for _ in 0..passes {
        let mut moved = 0usize;
        for v in 0..n as u32 {
            let cur = assign[v as usize];
            let nbrs = g.neighbors(v);
            if nbrs.is_empty() {
                continue;
            }
            for c in conn.iter_mut() {
                *c = 0.0;
            }
            let mut boundary = false;
            for (i, &u) in nbrs.iter().enumerate() {
                let p = assign[u as usize];
                conn[p as usize] += g.weight_at(v, i) as f64;
                boundary |= p != cur;
            }
            if !boundary {
                continue;
            }
            let internal = conn[cur as usize];
            let mut best = cur;
            let mut best_gain = 0.0f64;
            for p in 0..k as u32 {
                if p == cur {
                    continue;
                }
                if loads[p as usize] + weights[v as usize] > cap {
                    continue;
                }
                let gain = conn[p as usize] - internal;
                if gain > best_gain + 1e-12 {
                    best_gain = gain;
                    best = p;
                }
            }
            if best != cur {
                loads[cur as usize] -= weights[v as usize];
                loads[best as usize] += weights[v as usize];
                assign[v as usize] = best;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{generate_sbm, SbmConfig};
    use crate::graph::karate::karate_graph;
    use crate::partition::cut_edges;

    #[test]
    fn partitions_karate_balanced() {
        let g = karate_graph();
        let p = MetisPartitioner::new(1).partition(&g, 2).unwrap();
        assert_eq!(p.k(), 2);
        let sizes = p.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 34);
        assert!(sizes.iter().all(|&s| (12..=22).contains(&s)), "{sizes:?}");
    }

    #[test]
    fn beats_random_on_cut() {
        let g = generate_sbm(&SbmConfig::arxiv_like(2000, 4)).unwrap().graph;
        for k in [2, 4, 8] {
            let m = MetisPartitioner::new(7).partition(&g, k).unwrap();
            let r = crate::partition::random::RandomPartitioner::new(7)
                .partition(&g, k)
                .unwrap();
            assert!(
                cut_edges(&g, &m) < cut_edges(&g, &r) / 2,
                "k={k}: metis {} vs random {}",
                cut_edges(&g, &m),
                cut_edges(&g, &r)
            );
        }
    }

    #[test]
    fn respects_balance_cap() {
        let g = generate_sbm(&SbmConfig::arxiv_like(1200, 8)).unwrap().graph;
        let k = 4;
        let p = MetisPartitioner::new(3).partition(&g, k).unwrap();
        let max = *p.sizes().iter().max().unwrap();
        // cap is (1+imbalance)·n/k with slack for coarse granularity
        assert!(
            (max as f64) <= 1200.0 / k as f64 * 1.20,
            "max part {max} too heavy"
        );
    }

    #[test]
    fn multilevel_path_exercised_on_larger_graph() {
        let g = generate_sbm(&SbmConfig::arxiv_like(5000, 6)).unwrap().graph;
        let p = MetisPartitioner::new(11).partition(&g, 8).unwrap();
        assert_eq!(p.k(), 8);
        assert!(p.sizes().iter().all(|&s| s > 0));
    }

    #[test]
    fn handles_tiny_graphs() {
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 2)]).unwrap();
        let p = MetisPartitioner::new(0).partition(&g, 3).unwrap();
        assert_eq!(p.k(), 3);
    }

    #[test]
    fn deterministic_per_seed() {
        let g = karate_graph();
        let a = MetisPartitioner::new(5).partition(&g, 4).unwrap();
        let b = MetisPartitioner::new(5).partition(&g, 4).unwrap();
        assert_eq!(a.assignments(), b.assignments());
    }
}
