//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("graph error: {0}")]
    Graph(String),

    #[error("partition error: {0}")]
    Partition(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("serve error: {0}")]
    Serve(String),

    #[error("xla error: {0}")]
    Xla(String),

    #[error("lint error: {0}")]
    Lint(String),

    #[error("injected fault: {0}")]
    Fault(String),

    #[error("net error: {0}")]
    Net(String),
}

impl Error {
    /// Whether a retry on another machine (or the same one, later) could
    /// plausibly succeed. Transient classes are environmental — I/O,
    /// network trouble, PJRT/XLA runtime trouble, injected faults (which
    /// model machine failures). Everything else (bad graph, bad config,
    /// corrupt manifest, …) is deterministic: retrying burns an attempt
    /// on the same failure, so the coordinator goes straight to its
    /// `on_failure` policy.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            Error::Io(_) | Error::Xla(_) | Error::Runtime(_) | Error::Fault(_) | Error::Net(_)
        )
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::Manifest(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_classification() {
        assert!(Error::Fault("x".into()).is_transient());
        assert!(Error::Runtime("x".into()).is_transient());
        assert!(Error::Xla("x".into()).is_transient());
        assert!(Error::Io(std::io::Error::other("x")).is_transient());
        assert!(Error::Net("x".into()).is_transient());
        assert!(!Error::Config("x".into()).is_transient());
        assert!(!Error::Serve("x".into()).is_transient());
        assert!(!Error::Coordinator("x".into()).is_transient());
        assert!(!Error::Graph("x".into()).is_transient());
    }
}
