//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("graph error: {0}")]
    Graph(String),

    #[error("partition error: {0}")]
    Partition(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("serve error: {0}")]
    Serve(String),

    #[error("xla error: {0}")]
    Xla(String),

    #[error("lint error: {0}")]
    Lint(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::Manifest(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
