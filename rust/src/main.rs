//! `repro` — the Leiden-Fusion launcher.
//!
//! Subcommands:
//!   partition  — partition a dataset and print §5.1 quality metrics
//!   train      — full distributed pipeline: partition → per-machine GNN
//!                training → embedding integration → MLP → eval
//!                (`--shards <dir>` also exports a serving bundle)
//!   coordinator — `train` over real TCP workers: `coordinator serve`
//!                binds a listener, waits for `worker join` processes,
//!                and runs the identical pipeline (same metrics, shards)
//!   worker     — `worker join <addr>`: dial a coordinator, prove the
//!                run fingerprint matches, train assigned partitions
//!   pipeline   — `train` for LF vs baselines side by side
//!   serve      — load a shard bundle and answer queries interactively,
//!                or over HTTP with `--http <addr>` (keep-alive, bounded
//!                admission, `/healthz` `/readyz` `/metrics`); `--watch`
//!                hot-swaps to newly published bundle versions
//!   query      — one-shot classification of --nodes against a bundle
//!   metrics    — run a small workload and dump the obs metrics registry
//!   lint       — run the in-crate static analysis pass over `src/`
//!                (exits non-zero on unannotated violations)
//!   info       — dataset + artifact inventory
//!
//! Every subcommand takes `--trace-out <path>` (or `[obs] trace = "path"`
//! in a `--config` file) to record nested tracing spans and write them as
//! Chrome-trace JSON (`chrome://tracing` / Perfetto) on exit.
//!
//! Examples:
//!   repro partition --dataset arxiv --spec "leiden(gamma=0.7)+fusion(alpha=0.05)" --k 8
//!   repro partition --dataset arxiv --method lf --k 8
//!   repro train --config configs/arxiv_lf.toml
//!   repro train --dataset karate --k 2 --epochs 40 --model gcn --shards /tmp/karate_shards
//!   repro serve --shards /tmp/karate_shards --warm
//!   repro query --shards /tmp/karate_shards --nodes 0,5,9
//!   repro info

use leiden_fusion::benchkit::Table;
use leiden_fusion::cli::Args;
use leiden_fusion::config::{obs_trace_path, ExperimentConfig, NetConfig, ServeConfig, Toml};
use leiden_fusion::coordinator::{Coordinator, CoordinatorConfig, Transport};
use leiden_fusion::data::{
    karate_dataset, synth_arxiv, synth_proteins, ArxivLikeConfig, Dataset,
    ProteinsLikeConfig,
};
use leiden_fusion::graph::NodeId;
use leiden_fusion::obs;
use leiden_fusion::partition::{
    PartitionPipeline, PartitionReport, PartitionSpec, PipelineEvent,
};
use leiden_fusion::runtime::{default_artifacts_dir, Manifest};
use leiden_fusion::serve::{
    format_status_line, BundleHandle, Engine, EngineConfig, Generation, HttpServer,
    HttpServerConfig, NodeStatus, ShardedEmbeddingStore,
};
use leiden_fusion::train::ModelKind;
use leiden_fusion::util::{fmt_duration, init_logging, Stopwatch};
use leiden_fusion::{Error, Result};
use std::path::PathBuf;
use std::sync::Arc;

const USAGE: &str = "\
repro — Leiden-Fusion distributed graph-embedding training + serving

USAGE:
  repro partition --dataset <karate|arxiv|proteins> [--spec SPEC | --method NAME]
                  [--k 4] [--n 0] [--seed 42] [--threads 1]
                  [--assignments-out file]   (one partition id per line)
  repro train     [--config file.toml] [--dataset arxiv] [--spec SPEC | --method NAME]
                  [--k 4] [--model gcn|sage] [--mode inner|repli] [--epochs 80]
                  [--machines 4] [--n 0] [--seed 42] [--threads 1] [--shards dir]
                  [--exec session|reference]   (PJRT path: device-resident
                   session (default) or the host round-trip reference loop)
                  [--max-retries 1] [--on-failure abort|skip] [--deadline SECS]
                  [--resume]   (replay intact journaled partitions from the
                   --shards dir; retrain only what's missing)
                  [--fault-plan SPEC]   (deterministic fault injection, e.g.
                   \"worker.train:part=0,attempt=0:fail; shard.read:p=0.05,seed=7:corrupt\")
  repro coordinator serve
                  (all `train` flags, plus:)
                  [--bind 127.0.0.1:0] [--port-file file]   (write the bound
                   port for scripts when --bind picks port 0)
                  [--heartbeat-ms 500] [--grace-ms 2000] [--join-timeout 30]
                  (waits for `worker join` processes, then runs the exact
                   `train` pipeline over them: identical metrics + shards)
  repro worker    join <addr>   (plus the same dataset/partition/train
                   flags or --config as the coordinator — the handshake
                   rejects a worker whose run fingerprint differs)
                  [--reconnect-attempts 5]
  repro pipeline  [--dataset arxiv] [--k 4] (LF vs METIS vs LPA comparison)
  repro serve     --shards dir [--batch 64] [--workers 2] [--cache 4096]
                  [--cache-stripes 8] [--artifacts dir] [--warm]
                  (interactive: node ids on stdin; --warm preloads every
                   shard slab in parallel before the first query)
                  [--http 127.0.0.1:8080]   (HTTP/1.1 front-end instead of
                   stdin: GET /classify?nodes=0,5,9[&format=text|json],
                   /healthz, /readyz, /metrics)
                  [--port-file file]   (write the bound port when --http
                   picks port 0)
                  [--watch]   (hot-swap to newly published bundle versions)
                  [--max-inflight 256] [--request-deadline-ms 2000]
  repro query     --shards dir --nodes 0,5,9 [--batch 64] [--workers 2]
                  [--cache 4096] [--cache-stripes 8]
                  [--logits-out file]   (canonical per-node lines with
                   bit-exact hex logits — byte-comparable against the
                   HTTP front-end's format=text output)
  repro metrics   [--dataset karate] [--k 2] [--seed 42] [--n 0]
                  [--shards dir] [--train] [--epochs 2]
                  [--format json|prom] [--out file]
                  (runs a small partition workload — plus the serving
                   engine when --shards is given and a tiny training run
                   when --train is given — then dumps the metrics
                   registry as JSON or Prometheus text)
  repro lint      [--src dir] [--json-out LINT.json] [--fixable]
                  (static analysis: determinism, panic-safety, and
                   concurrency invariants; non-zero exit on unannotated
                   violations; --fixable lists justified suppressions)
  repro info      (dataset defaults + compiled artifact inventory)

  any subcommand: --trace-out trace.json   (record tracing spans; write
                   Chrome-trace JSON on exit; config: [obs] trace = "...")

SPEC grammar (stages joined by '+', optional key=value parameters):
  detect:     leiden(gamma,beta,theta) | louvain(gamma,beta) |
              metis(imbalance) | lpa(iters,slack) | random
  transforms: fusion(alpha) | balance(slack)
  suffix:     !novalidate  (skip the invariant-checking stage)
  examples:   \"leiden(gamma=0.7,beta=0.05)+fusion(alpha=0.05)\", \"metis+fusion\"
  legacy --method names still work: lf, leiden, louvain, metis, lpa,
  random, metis+f, lpa+f, louvain+f
  --threads parallelises the partitioning pipeline; same seed gives a
  byte-identical partitioning for every thread count
";

/// Boolean switches (never bind the next token as a value).
const SWITCHES: &[&str] = &["help", "warm", "train", "fixable", "resume", "watch"];

fn main() {
    init_logging();
    let args = match Args::parse_declared(std::env::args(), SWITCHES) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("{USAGE}");
        return Ok(());
    }
    let trace_out = trace_out_path(args)?;
    if trace_out.is_some() {
        obs::set_enabled(true);
    }
    let result = dispatch(args);
    if let Some(path) = trace_out {
        // write the trace even when the command failed — a trace of a
        // failing run is exactly when you want one
        obs::write_chrome_trace(&path)?;
        eprintln!("trace written to {path}");
    }
    result
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("partition") => cmd_partition(args),
        Some("train") => cmd_train(args),
        Some("coordinator") => cmd_coordinator(args),
        Some("worker") => cmd_worker(args),
        Some("pipeline") => cmd_pipeline(args),
        Some("serve") => cmd_serve(args),
        Some("query") => cmd_query(args),
        Some("metrics") => cmd_metrics(args),
        Some("lint") => cmd_lint(args),
        Some("info") => cmd_info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

/// Resolve the trace destination: `--trace-out` wins, then the
/// `[obs] trace` key of a `--config` file.
fn trace_out_path(args: &Args) -> Result<Option<String>> {
    if let Some(p) = args.get("trace-out") {
        return Ok(Some(p.to_string()));
    }
    if let Some(cfg) = args.get("config") {
        let text = std::fs::read_to_string(cfg)?;
        if let Some(p) = obs_trace_path(&Toml::parse(&text)?)? {
            return Ok(Some(p.display().to_string()));
        }
    }
    Ok(None)
}

/// Resolve a dataset by name with optional size override.
fn load_dataset(name: &str, n: usize, seed: u64) -> Result<Dataset> {
    match name {
        "karate" => Ok(karate_dataset(seed)),
        "arxiv" => {
            let mut cfg = ArxivLikeConfig { seed, ..Default::default() };
            if n > 0 {
                cfg.n = n;
            }
            synth_arxiv(&cfg)
        }
        "proteins" => {
            let mut cfg = ProteinsLikeConfig { seed, ..Default::default() };
            if n > 0 {
                cfg.n = n;
            }
            synth_proteins(&cfg)
        }
        path => {
            // treat as an edge-list file → unlabeled; only `partition` works
            let g = leiden_fusion::graph::io::read_edge_list(std::path::Path::new(path))?;
            let n = g.num_nodes();
            Ok(Dataset {
                name: path.to_string(),
                graph: g,
                features: vec![0.0; n],
                feat_dim: 1,
                labels: leiden_fusion::data::Labels::Multiclass {
                    classes: 1,
                    labels: vec![0; n],
                },
                train_mask: vec![true; n],
                val_mask: vec![false; n],
                test_mask: vec![false; n],
            })
        }
    }
}

/// `--spec` (grammar) wins over `--method` (legacy alias); default `lf`.
fn spec_from_args(args: &Args) -> Result<PartitionSpec> {
    let spec = args.get("spec");
    if spec.is_some() && args.get("method").is_some() {
        log::warn!("--method ignored: --spec wins");
    }
    spec.or_else(|| args.get("method")).unwrap_or("lf").parse()
}

fn cmd_partition(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "arxiv");
    let spec = spec_from_args(args)?;
    let k = args.usize_or("k", 4)?;
    let seed = args.u64_or("seed", 42)?;
    let n = args.usize_or("n", 0)?;
    let threads = args.usize_or("threads", 1)?;

    let ds = load_dataset(&dataset, n, seed)?;
    println!(
        "dataset={} nodes={} edges={} spec={} k={} threads={}",
        ds.name,
        ds.graph.num_nodes(),
        ds.graph.num_edges(),
        spec,
        k,
        threads.max(1)
    );
    let pipeline = PartitionPipeline::new(spec, seed).with_threads(threads);
    let report = pipeline.run_observed(&ds.graph, k, &mut |ev| {
        if let PipelineEvent::StageFinished { name, secs, parts, .. } = ev {
            println!("  stage {name:<9} {:>9} → {parts} parts", fmt_duration(*secs));
        }
    })?;
    let q = report.quality(&ds.graph);

    println!("partitioning total: {}", fmt_duration(report.total_secs()));
    let mut t = Table::new(
        "Partition quality (§5.1)",
        &["part", "nodes", "edges", "components", "isolated"],
    );
    for i in 0..q.k {
        t.row(vec![
            i.to_string(),
            q.node_counts[i].to_string(),
            q.edge_counts[i].to_string(),
            q.components[i].to_string(),
            q.isolated[i].to_string(),
        ]);
    }
    t.print();
    println!(
        "edge-cut: {:.2}%  node-balance ρ: {:.3}  edge-balance: {:.3}  RF: {:.3}  ideal: {}",
        q.edge_cut_fraction * 100.0,
        q.node_balance,
        q.edge_balance,
        q.replication_factor,
        q.is_structurally_ideal()
    );
    if let Some(path) = args.get("assignments-out") {
        // one partition id per line — what the tier-1 determinism check
        // (and any external tooling) diffs across runs and thread counts
        let mut out = String::with_capacity(report.partitioning.num_nodes() * 3);
        for &p in report.partitioning.assignments() {
            out.push_str(&p.to_string());
            out.push('\n');
        }
        std::fs::write(path, out)?;
        println!("assignments written to {path}");
    }
    Ok(())
}

fn experiment_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    // CLI overrides
    if let Some(d) = args.get("dataset") {
        cfg.dataset = d.to_string();
    }
    // a CLI-provided strategy replaces the config's spec wholesale,
    // including any [partition] alpha/beta overrides already folded in
    if args.get("spec").is_some() || args.get("method").is_some() {
        cfg.spec = spec_from_args(args)?;
    }
    if let Some(m) = args.get("model") {
        cfg.model = ModelKind::parse(m)?;
    }
    if let Some(m) = args.get("mode") {
        cfg.mode = match m {
            "inner" => leiden_fusion::train::Mode::Inner,
            "repli" => leiden_fusion::train::Mode::Repli,
            other => return Err(Error::Config(format!("unknown mode {other:?}"))),
        };
    }
    cfg.k = args.usize_or("k", cfg.k)?;
    cfg.epochs = args.usize_or("epochs", cfg.epochs)?;
    cfg.mlp_epochs = args.usize_or("mlp-epochs", cfg.mlp_epochs)?;
    cfg.machines = args.usize_or("machines", cfg.machines)?;
    cfg.dataset_n = args.usize_or("n", cfg.dataset_n)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.partition_threads = args.usize_or("threads", cfg.partition_threads)?;
    if let Some(e) = args.get("exec") {
        cfg.exec = leiden_fusion::train::ExecPath::parse(e)?;
    }
    if let Some(dir) = args.get("shards") {
        cfg.shards_out = Some(PathBuf::from(dir));
    }
    cfg.max_retries = args.usize_or("max-retries", cfg.max_retries as usize)? as u32;
    if let Some(p) = args.get("on-failure") {
        cfg.on_failure = leiden_fusion::coordinator::FailurePolicy::parse(p)?;
    }
    cfg.deadline_secs = args.f64_or("deadline", cfg.deadline_secs)?;
    if cfg.deadline_secs < 0.0 {
        return Err(Error::Config(format!(
            "--deadline must be >= 0 seconds, got {}",
            cfg.deadline_secs
        )));
    }
    cfg.resume = cfg.resume || args.has("resume");
    if let Some(p) = args.get("fault-plan") {
        cfg.fault_plan = Some(p.to_string());
    }
    install_fault_plan(cfg.fault_plan.as_deref())?;
    Ok(cfg)
}

/// Parse and install the deterministic fault-injection plan (CLI
/// `--fault-plan` wins over the config's `[fault] plan`). No-op when
/// neither is given — every fault point stays one relaxed atomic load.
fn install_fault_plan(spec: Option<&str>) -> Result<()> {
    if let Some(spec) = spec {
        leiden_fusion::fault::install(leiden_fusion::fault::FaultPlan::parse(spec)?);
        eprintln!("fault plan installed: {spec}");
    }
    Ok(())
}

/// Lower an experiment config to the coordinator's own knobs. Shared by
/// every launch shape (in-process train, TCP leader, TCP worker) so the
/// training configuration can never diverge between transports.
fn coordinator_config(cfg: &ExperimentConfig) -> CoordinatorConfig {
    let mut ccfg = CoordinatorConfig::new(cfg.artifacts_dir.clone());
    ccfg.machines = cfg.machines;
    ccfg.mode = cfg.mode;
    ccfg.model = cfg.model;
    ccfg.epochs = cfg.epochs;
    ccfg.mlp_epochs = cfg.mlp_epochs;
    ccfg.seed = cfg.seed;
    ccfg.exec = cfg.exec;
    ccfg.shard_dir = cfg.shards_out.clone();
    ccfg.max_retries = cfg.max_retries;
    ccfg.on_failure = cfg.on_failure;
    ccfg.deadline_secs = cfg.deadline_secs;
    ccfg.resume = cfg.resume;
    ccfg
}

/// `[net]` options with their CLI overrides, for the TCP subcommands.
fn net_config(args: &Args, cfg: &ExperimentConfig) -> Result<NetConfig> {
    let mut net = cfg.net.clone();
    if let Some(b) = args.get("bind") {
        net.bind = b.to_string();
    }
    if let Some(p) = args.get("port-file") {
        net.port_file = Some(PathBuf::from(p));
    }
    net.heartbeat_ms = args.u64_or("heartbeat-ms", net.heartbeat_ms)?;
    net.grace_ms = args.u64_or("grace-ms", net.grace_ms)?;
    net.join_timeout_secs = args.f64_or("join-timeout", net.join_timeout_secs)?;
    net.reconnect_attempts =
        args.u64_or("reconnect-attempts", net.reconnect_attempts as u64)? as u32;
    Ok(net)
}

/// Run the full distributed pipeline for one configuration.
fn run_experiment(
    cfg: &ExperimentConfig,
    ds: &Dataset,
    transport: Transport,
) -> Result<(PartitionReport, leiden_fusion::coordinator::TrainReport)> {
    let pipeline = PartitionPipeline::new(cfg.spec.clone(), cfg.seed)
        .with_threads(cfg.partition_threads);
    let preport = pipeline.run(&ds.graph, cfg.k)?;
    let mut ccfg = coordinator_config(cfg);
    ccfg.transport = transport;
    let report = Coordinator::new(ccfg).run_report(ds, &preport)?;
    Ok((preport, report))
}

fn cmd_train(args: &Args) -> Result<()> {
    train_with_transport(args, Transport::Local)
}

/// `repro coordinator serve`: the exact `train` pipeline, but partitions
/// are shipped to TCP workers instead of in-process threads. Output
/// lines are identical to `train` on purpose — the tier-1 loopback smoke
/// diffs them to prove the transports agree bit for bit.
fn cmd_coordinator(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("serve") => {}
        other => {
            return Err(Error::Config(format!(
                "coordinator: expected `serve`, got {other:?} (usage: repro coordinator serve)"
            )))
        }
    }
    let cfg = experiment_config(args)?;
    let net = net_config(args, &cfg)?;
    train_with_transport(args, Transport::Tcp(net))
}

/// `repro worker join <addr>`: run the deterministic partition pipeline
/// locally (proving this process describes the same run as the leader),
/// then serve training assignments until drained.
fn cmd_worker(args: &Args) -> Result<()> {
    let addr = match (
        args.positional.first().map(String::as_str),
        args.positional.get(1),
    ) {
        (Some("join"), Some(addr)) => addr.clone(),
        _ => {
            return Err(Error::Config(
                "worker: usage: repro worker join <host:port>".into(),
            ))
        }
    };
    let cfg = experiment_config(args)?;
    let net = net_config(args, &cfg)?;
    let ds = load_dataset(&cfg.dataset, cfg.dataset_n, cfg.seed)?;
    println!(
        "worker joining {addr}: dataset={} spec={} k={} seed={}",
        ds.name, cfg.spec, cfg.k, cfg.seed
    );
    let pipeline = PartitionPipeline::new(cfg.spec.clone(), cfg.seed)
        .with_threads(cfg.partition_threads);
    let preport = pipeline.run(&ds.graph, cfg.k)?;
    let members = preport.partitioning.members();
    let fingerprint = leiden_fusion::coordinator::RunJournal::fingerprint(
        &ds.name,
        ds.num_nodes(),
        &members,
        cfg.seed,
        cfg.epochs,
        cfg.mlp_epochs,
        cfg.mode.as_str(),
        cfg.model.as_str(),
        cfg.exec.as_str(),
    );
    let ccfg = coordinator_config(&cfg);
    leiden_fusion::net::run_worker(&addr, &ds, &ccfg, &net, fingerprint)
}

fn train_with_transport(args: &Args, transport: Transport) -> Result<()> {
    let cfg = experiment_config(args)?;
    let ds = load_dataset(&cfg.dataset, cfg.dataset_n, cfg.seed)?;
    println!(
        "training {} on {}: k={} model={} mode={} epochs={} machines={} exec={}",
        cfg.spec,
        ds.name,
        cfg.k,
        cfg.model.as_str(),
        cfg.mode.as_str(),
        cfg.epochs,
        cfg.machines,
        cfg.exec.as_str()
    );
    let (preport, report) = run_experiment(&cfg, &ds, transport)?;
    println!("partition stages: {}", preport.stage_summary());
    let q = preport.quality(&ds.graph);
    let mut t = Table::new(
        "Per-partition training",
        &["part", "nodes", "replicas", "final-loss", "train-time"],
    );
    for s in &report.per_partition {
        t.row(vec![
            s.part_id.to_string(),
            s.num_nodes.to_string(),
            s.num_replicas.to_string(),
            format!("{:.4}", s.losses.last().copied().unwrap_or(f32::NAN)),
            fmt_duration(s.train_secs),
        ]);
    }
    t.print();
    println!(
        "edge-cut {:.2}% | structurally ideal: {} | max-part-train {} | total {}",
        q.edge_cut_fraction * 100.0,
        q.is_structurally_ideal(),
        fmt_duration(report.max_partition_train_secs),
        fmt_duration(report.wall_secs),
    );
    println!(
        "val {} = {:.4} | test {} = {:.4}",
        report.eval.metric_name,
        report.eval.val_metric,
        report.eval.metric_name,
        report.eval.test_metric
    );
    if report.skipped_partitions.is_empty() {
        println!("coverage: 1.000 (all partitions embedded)");
    } else {
        println!(
            "coverage: {:.3} — DEGRADED, skipped partitions {:?} \
             (on_failure = skip); metrics cover the survivors only",
            report.coverage, report.skipped_partitions
        );
    }
    if let Some(dir) = &cfg.shards_out {
        println!(
            "serving bundle: {} (query it with `repro serve --shards {}`)",
            dir.display(),
            dir.display()
        );
    }
    Ok(())
}

// ---- serving --------------------------------------------------------------

/// Resolve serve options (config file < CLI flags), open the shard store,
/// and start the engine. The `EngineConfig` comes back too so the
/// hot-swap path can build replacement engines with identical knobs.
fn serve_setup(
    args: &Args,
) -> Result<(Arc<ShardedEmbeddingStore>, Engine, ServeConfig, EngineConfig)> {
    let mut scfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            ServeConfig::from_toml(&Toml::parse(&text)?)
        }
        None => ServeConfig::default(),
    };
    if let Some(dir) = args.get("shards") {
        scfg.shards_dir = PathBuf::from(dir);
    }
    scfg.batch_size = args.usize_or("batch", scfg.batch_size)?;
    scfg.workers = args.usize_or("workers", scfg.workers)?;
    scfg.cache_capacity = args.usize_or("cache", scfg.cache_capacity)?;
    scfg.cache_stripes = args.usize_or("cache-stripes", scfg.cache_stripes)?;
    scfg.warm = scfg.warm || args.has("warm");
    if let Some(addr) = args.get("http") {
        scfg.http = Some(addr.to_string());
    }
    scfg.max_inflight = args.usize_or("max-inflight", scfg.max_inflight)?;
    scfg.request_deadline_ms =
        args.u64_or("request-deadline-ms", scfg.request_deadline_ms)?;
    scfg.watch = scfg.watch || args.has("watch");
    // shard.read / manifest.load fault points are live under serve too
    install_fault_plan(args.get("fault-plan"))?;

    let store = Arc::new(ShardedEmbeddingStore::open(&scfg.shards_dir)?);
    let ecfg = EngineConfig {
        artifacts_dir: match args.get("artifacts") {
            Some(p) => PathBuf::from(p),
            None => default_artifacts_dir(),
        },
        batch_size: scfg.batch_size,
        workers: scfg.workers,
        cache_capacity: scfg.cache_capacity,
        cache_stripes: scfg.cache_stripes,
    };
    let engine = Engine::new(ecfg.clone(), Arc::clone(&store))?;
    Ok((store, engine, scfg, ecfg))
}

fn parse_node_list(text: &str) -> Result<Vec<NodeId>> {
    text.split(|c: char| c == ',' || c.is_whitespace())
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<NodeId>()
                .map_err(|_| Error::Config(format!("bad node id {t:?}")))
        })
        .collect()
}

fn print_engine_stats(engine: &Engine) {
    let st = engine.stats();
    let hit_pct = if st.requests > 0 {
        st.cache_hits as f64 / st.requests as f64 * 100.0
    } else {
        0.0
    };
    println!(
        "requests {} | cache hits {} ({hit_pct:.1}%) | coalesced {} | batches {} | \
         computed {}",
        st.requests, st.cache_hits, st.coalesced, st.batches, st.computed
    );
    if st.batches > 0 {
        println!(
            "worker stages: gather {:.1}ms | forward {:.1}ms | publish {:.1}ms",
            st.gather_secs * 1e3,
            st.forward_secs * 1e3,
            st.publish_secs * 1e3
        );
    }
}

/// Per-row query output: healthy rows render node/class/score,
/// quarantined or unknown rows show the unavailability reason instead.
fn print_statuses(statuses: &[NodeStatus]) {
    let mut t = Table::new("Predictions", &["node", "class", "score"]);
    for s in statuses {
        match s {
            NodeStatus::Ready(p) => {
                t.row(vec![
                    p.node.to_string(),
                    p.class.to_string(),
                    format!("{:.4}", p.score),
                ]);
            }
            NodeStatus::Unavailable { node, reason } => {
                t.row(vec![node.to_string(), "unavailable".into(), reason.clone()]);
            }
        }
    }
    t.print();
}

fn cmd_query(args: &Args) -> Result<()> {
    let nodes_arg = args
        .get("nodes")
        .ok_or_else(|| Error::Config("query needs --nodes 0,5,9".into()))?;
    let nodes = parse_node_list(nodes_arg)?;
    let (store, engine, _, _) = serve_setup(args)?;
    println!(
        "bundle {} ({} shards, {} nodes, dim {})",
        store.dir().display(),
        store.num_shards(),
        store.num_nodes(),
        store.dim()
    );
    let quarantined = store.quarantined_shards();
    if quarantined > 0 {
        eprintln!(
            "DEGRADED bundle: {quarantined}/{} shard(s) quarantined — \
             rows they own come back unavailable",
            store.num_shards()
        );
    }
    let statuses = engine.query_status(&nodes)?;
    print_statuses(&statuses);
    if let Some(path) = args.get("logits-out") {
        // canonical per-node lines with bit-exact hex logits — the same
        // renderer the HTTP front-end uses for format=text, so `cmp`
        // between this file and a /classify response proves the two
        // paths produce identical bits
        let mut out = String::new();
        for st in &statuses {
            out.push_str(&format_status_line(st));
            out.push('\n');
        }
        std::fs::write(path, out)?;
        println!("logit lines written to {path}");
    }
    print_engine_stats(&engine);
    Ok(())
}

/// `repro metrics` — exercise the instrumented hot paths inside this
/// process, then snapshot the global metrics registry.
///
/// The registry is in-process state, so the subcommand generates its own
/// activity: the partitioning pipeline always runs (artifact-free,
/// `partition.*` series); `--shards <dir>` additionally drives the
/// serving engine (`serve.*`); `--train` additionally runs a tiny
/// end-to-end training job (`session.*` + `coordinator.*`), skipping
/// itself with a note when PJRT artifacts are absent.
fn cmd_metrics(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "karate");
    let spec = spec_from_args(args)?;
    let k = args.usize_or("k", 2)?;
    let seed = args.u64_or("seed", 42)?;
    let n = args.usize_or("n", 0)?;
    let format = args.str_or("format", "json");

    let ds = load_dataset(&dataset, n, seed)?;
    let report = PartitionPipeline::new(spec, seed).run(&ds.graph, k)?;

    if args.get("shards").is_some() {
        let (store, engine, _, _) = serve_setup(args)?;
        let probe = store.num_nodes().min(64) as NodeId;
        let nodes: Vec<NodeId> = (0..probe).collect();
        engine.query(&nodes)?;
        // a second pass over the same ids exercises the cache-hit path
        engine.query(&nodes)?;
    }

    if args.has("train") {
        let artifacts = match args.get("artifacts") {
            Some(p) => PathBuf::from(p),
            None => default_artifacts_dir(),
        };
        if artifacts.join("manifest.json").exists() {
            let mut ccfg = CoordinatorConfig::new(artifacts);
            ccfg.machines = 1;
            ccfg.epochs = args.usize_or("epochs", 2)?;
            ccfg.mlp_epochs = 10;
            ccfg.seed = seed;
            Coordinator::new(ccfg).run(&ds, &report.partitioning)?;
        } else {
            eprintln!(
                "note: --train skipped — PJRT artifacts absent \
                 (run `make artifacts`); session.* series will be empty"
            );
        }
    }

    let reg = obs::registry();
    let text = match format.as_str() {
        "json" => reg.snapshot_json().to_string(),
        "prom" | "prometheus" => reg.render_prometheus(),
        other => {
            return Err(Error::Config(format!(
                "--format expects json or prom, got {other:?}"
            )))
        }
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("metrics written to {path}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use std::io::BufRead;
    let (store, engine, scfg, ecfg) = serve_setup(args)?;
    let m = store.manifest();
    println!(
        "serving {} from {}: {} shards, {} nodes, dim {}, {} logit columns, \
         batch ≤ {}, {} workers, {} cache stripes",
        m.dataset,
        store.dir().display(),
        store.num_shards(),
        store.num_nodes(),
        store.dim(),
        m.classes,
        engine.max_batch(),
        scfg.workers.max(1),
        engine.cache_stripes(),
    );
    if scfg.warm {
        let sw = Stopwatch::start();
        store.warm(scfg.workers.max(1))?;
        println!("warmed {} shard slabs in {}", store.num_shards(), fmt_duration(sw.secs()));
    }
    let quarantined = store.quarantined_shards();
    if quarantined > 0 {
        eprintln!(
            "DEGRADED bundle: {quarantined}/{} shard(s) quarantined — \
             rows they own come back unavailable",
            store.num_shards()
        );
    }
    if scfg.http.is_some() {
        return serve_http(args, store, engine, &scfg, ecfg);
    }
    println!("enter node ids (e.g. `0,5,9`), `stats`, or `quit`:");
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match line {
            "quit" | "exit" => break,
            "stats" => print_engine_stats(&engine),
            _ => match parse_node_list(line).and_then(|ns| engine.query_status(&ns)) {
                Ok(statuses) => print_statuses(&statuses),
                Err(e) => eprintln!("error: {e}"),
            },
        }
    }
    print_engine_stats(&engine);
    Ok(())
}

/// `repro serve --http <addr>`: the HTTP/1.1 front-end over a
/// hot-swappable bundle handle. Blocks until the process is killed —
/// deliberately NOT the stdin loop, so a backgrounded server whose
/// stdin hits EOF keeps serving.
fn serve_http(
    args: &Args,
    store: Arc<ShardedEmbeddingStore>,
    engine: Engine,
    scfg: &ServeConfig,
    ecfg: EngineConfig,
) -> Result<()> {
    let version = store.manifest().version;
    let handle = Arc::new(BundleHandle::new(
        &scfg.shards_dir,
        ecfg,
        Generation { version, store, engine },
    ));
    if scfg.watch {
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
        // detached for the process lifetime: the server only stops by
        // being killed, which takes the watcher with it
        let _watcher = handle.spawn_watcher(
            leiden_fusion::serve::bundle::WATCH_TICK_MS,
            Arc::clone(&shutdown),
        )?;
        println!("watching {} for new bundle versions", scfg.shards_dir.display());
    }
    let addr = scfg.http.clone().unwrap_or_else(|| "127.0.0.1:0".into());
    let server = HttpServer::start(
        HttpServerConfig {
            addr,
            max_inflight: scfg.max_inflight,
            request_deadline_ms: scfg.request_deadline_ms,
            port_file: args.get("port-file").map(PathBuf::from),
            ..HttpServerConfig::default()
        },
        handle,
    )?;
    println!(
        "http front-end on {} (v{version}): /healthz /readyz /metrics \
         /classify?nodes=0,5,9[&format=text|json]",
        server.addr()
    );
    server.join();
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let base = experiment_config(args)?;
    let ds = load_dataset(&base.dataset, base.dataset_n, base.seed)?;
    let mut t = Table::new(
        "LF vs baselines",
        &["method", "edge-cut%", "ideal", "test-metric", "max-part-train"],
    );
    for method in ["lf", "metis", "lpa"] {
        let mut cfg = base.clone();
        cfg.spec = method.parse()?;
        let (preport, report) = run_experiment(&cfg, &ds, Transport::Local)?;
        let q = preport.quality(&ds.graph);
        t.row(vec![
            method.to_string(),
            format!("{:.2}", q.edge_cut_fraction * 100.0),
            q.is_structurally_ideal().to_string(),
            format!("{:.4}", report.eval.test_metric),
            fmt_duration(report.max_partition_train_secs),
        ]);
    }
    t.print();
    Ok(())
}

/// `repro lint`: the in-crate static analysis pass (`analysis/`) over a
/// source tree. Exits non-zero when any unannotated violation remains;
/// `--json-out` writes the machine-readable report (the CI artifact) and
/// `--fixable` lists justified suppressions for triage.
fn cmd_lint(args: &Args) -> Result<()> {
    let src = args.str_or("src", "src");
    let root = PathBuf::from(&src);
    if !root.is_dir() {
        return Err(Error::Lint(format!("--src {src}: not a directory")));
    }
    let report = leiden_fusion::analysis::lint_root(&root)?;
    if let Some(path) = args.get("json-out") {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, report.to_json().to_string())?;
        eprintln!("lint report written to {path}");
    }
    print!("{}", report.render_human());
    if args.has("fixable") {
        print!("{}", report.render_fixable());
    }
    let violations = report.unannotated_count();
    if violations > 0 {
        return Err(Error::Lint(format!("{violations} unannotated violation(s)")));
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("datasets:");
    println!("  karate    34 nodes / 78 edges, 2 classes (exact Zachary graph)");
    let a = ArxivLikeConfig::default();
    println!(
        "  arxiv     {} nodes (default), {} classes, multiclass (SBM stand-in)",
        a.n, a.classes
    );
    let p = ProteinsLikeConfig::default();
    println!(
        "  proteins  {} nodes (default), {} tasks, multilabel dense (SBM stand-in)",
        p.n, p.tasks
    );
    let dir = leiden_fusion::runtime::default_artifacts_dir();
    match Manifest::load(&dir) {
        Ok(man) => {
            println!("\nartifacts ({}):", dir.display());
            let mut t =
                Table::new("Compiled artifacts", &["name", "model", "task", "role", "n", "e"]);
            for a in &man.artifacts {
                t.row(vec![
                    a.name.clone(),
                    a.model.clone(),
                    a.task.clone(),
                    a.role.clone(),
                    a.dims.n.to_string(),
                    a.dims.e.to_string(),
                ]);
            }
            t.print();
        }
        Err(e) => println!("\nartifacts: unavailable ({e})"),
    }
    Ok(())
}
