//! `repro` — the Leiden-Fusion launcher.
//!
//! Subcommands:
//!   partition  — partition a dataset and print §5.1 quality metrics
//!   train      — full distributed pipeline: partition → per-machine GNN
//!                training → embedding integration → MLP → eval
//!   pipeline   — `train` for LF vs baselines side by side
//!   info       — dataset + artifact inventory
//!
//! Examples:
//!   repro partition --dataset arxiv --method lf --k 8
//!   repro train --config configs/arxiv_lf.toml
//!   repro train --dataset karate --k 2 --epochs 40 --model gcn
//!   repro info

use leiden_fusion::benchkit::Table;
use leiden_fusion::cli::Args;
use leiden_fusion::config::ExperimentConfig;
use leiden_fusion::coordinator::{Coordinator, CoordinatorConfig};
use leiden_fusion::data::{
    karate_dataset, synth_arxiv, synth_proteins, ArxivLikeConfig, Dataset,
    ProteinsLikeConfig,
};
use leiden_fusion::partition::{by_name, PartitionQuality, Partitioning};
use leiden_fusion::runtime::Manifest;
use leiden_fusion::train::ModelKind;
use leiden_fusion::util::{fmt_duration, init_logging, Stopwatch};
use leiden_fusion::{Error, Result};

const USAGE: &str = "\
repro — Leiden-Fusion distributed graph-embedding training

USAGE:
  repro partition --dataset <karate|arxiv|proteins> --method <lf|metis|lpa|random|metis+f|lpa+f>
                  [--k 4] [--n 0] [--seed 42]
  repro train     [--config file.toml] [--dataset arxiv] [--method lf] [--k 4]
                  [--model gcn|sage] [--mode inner|repli] [--epochs 80]
                  [--machines 4] [--n 0] [--seed 42]
  repro pipeline  [--dataset arxiv] [--k 4] (LF vs METIS vs LPA comparison)
  repro info      (dataset defaults + compiled artifact inventory)
";

fn main() {
    init_logging();
    let args = match Args::parse(std::env::args()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("partition") => cmd_partition(args),
        Some("train") => cmd_train(args),
        Some("pipeline") => cmd_pipeline(args),
        Some("info") => cmd_info(),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

/// Resolve a dataset by name with optional size override.
fn load_dataset(name: &str, n: usize, seed: u64) -> Result<Dataset> {
    match name {
        "karate" => Ok(karate_dataset(seed)),
        "arxiv" => {
            let mut cfg = ArxivLikeConfig { seed, ..Default::default() };
            if n > 0 {
                cfg.n = n;
            }
            synth_arxiv(&cfg)
        }
        "proteins" => {
            let mut cfg = ProteinsLikeConfig { seed, ..Default::default() };
            if n > 0 {
                cfg.n = n;
            }
            synth_proteins(&cfg)
        }
        path => {
            // treat as an edge-list file → unlabeled; only `partition` works
            let g = leiden_fusion::graph::io::read_edge_list(std::path::Path::new(path))?;
            let n = g.num_nodes();
            Ok(Dataset {
                name: path.to_string(),
                graph: g,
                features: vec![0.0; n],
                feat_dim: 1,
                labels: leiden_fusion::data::Labels::Multiclass {
                    classes: 1,
                    labels: vec![0; n],
                },
                train_mask: vec![true; n],
                val_mask: vec![false; n],
                test_mask: vec![false; n],
            })
        }
    }
}

fn cmd_partition(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "arxiv");
    let method = args.str_or("method", "lf");
    let k = args.usize_or("k", 4)?;
    let seed = args.u64_or("seed", 42)?;
    let n = args.usize_or("n", 0)?;

    let ds = load_dataset(&dataset, n, seed)?;
    let sw = Stopwatch::start();
    let p = by_name(&method, seed)?.partition(&ds.graph, k)?;
    let secs = sw.secs();
    let q = PartitionQuality::measure(&ds.graph, &p);

    println!(
        "dataset={} nodes={} edges={} method={} k={} time={}",
        ds.name,
        ds.graph.num_nodes(),
        ds.graph.num_edges(),
        method,
        k,
        fmt_duration(secs)
    );
    let mut t = Table::new(
        "Partition quality (§5.1)",
        &["part", "nodes", "edges", "components", "isolated"],
    );
    for i in 0..q.k {
        t.row(vec![
            i.to_string(),
            q.node_counts[i].to_string(),
            q.edge_counts[i].to_string(),
            q.components[i].to_string(),
            q.isolated[i].to_string(),
        ]);
    }
    t.print();
    println!(
        "edge-cut: {:.2}%  node-balance ρ: {:.3}  edge-balance: {:.3}  RF: {:.3}  ideal: {}",
        q.edge_cut_fraction * 100.0,
        q.node_balance,
        q.edge_balance,
        q.replication_factor,
        q.is_structurally_ideal()
    );
    Ok(())
}

fn experiment_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    // CLI overrides
    if let Some(d) = args.get("dataset") {
        cfg.dataset = d.to_string();
    }
    if let Some(m) = args.get("method") {
        cfg.partitioner = m.to_string();
    }
    if let Some(m) = args.get("model") {
        cfg.model = ModelKind::parse(m)?;
    }
    if let Some(m) = args.get("mode") {
        cfg.mode = match m {
            "inner" => leiden_fusion::train::Mode::Inner,
            "repli" => leiden_fusion::train::Mode::Repli,
            other => return Err(Error::Config(format!("unknown mode {other:?}"))),
        };
    }
    cfg.k = args.usize_or("k", cfg.k)?;
    cfg.epochs = args.usize_or("epochs", cfg.epochs)?;
    cfg.mlp_epochs = args.usize_or("mlp-epochs", cfg.mlp_epochs)?;
    cfg.machines = args.usize_or("machines", cfg.machines)?;
    cfg.dataset_n = args.usize_or("n", cfg.dataset_n)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    Ok(cfg)
}

/// Run the full distributed pipeline for one configuration.
fn run_experiment(
    cfg: &ExperimentConfig,
    ds: &Dataset,
) -> Result<(Partitioning, leiden_fusion::coordinator::TrainReport)> {
    let p = by_name(&cfg.partitioner, cfg.seed)?.partition(&ds.graph, cfg.k)?;
    let mut ccfg = CoordinatorConfig::new(cfg.artifacts_dir.clone());
    ccfg.machines = cfg.machines;
    ccfg.mode = cfg.mode;
    ccfg.model = cfg.model;
    ccfg.epochs = cfg.epochs;
    ccfg.mlp_epochs = cfg.mlp_epochs;
    ccfg.seed = cfg.seed;
    let report = Coordinator::new(ccfg).run(ds, &p)?;
    Ok((p, report))
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = experiment_config(args)?;
    let ds = load_dataset(&cfg.dataset, cfg.dataset_n, cfg.seed)?;
    println!(
        "training {} on {}: k={} model={} mode={} epochs={} machines={}",
        cfg.partitioner,
        ds.name,
        cfg.k,
        cfg.model.as_str(),
        cfg.mode.as_str(),
        cfg.epochs,
        cfg.machines
    );
    let (p, report) = run_experiment(&cfg, &ds)?;
    let q = PartitionQuality::measure(&ds.graph, &p);
    let mut t = Table::new(
        "Per-partition training",
        &["part", "nodes", "replicas", "final-loss", "train-time"],
    );
    for s in &report.per_partition {
        t.row(vec![
            s.part_id.to_string(),
            s.num_nodes.to_string(),
            s.num_replicas.to_string(),
            format!("{:.4}", s.losses.last().copied().unwrap_or(f32::NAN)),
            fmt_duration(s.train_secs),
        ]);
    }
    t.print();
    println!(
        "edge-cut {:.2}% | structurally ideal: {} | max-part-train {} | total {}",
        q.edge_cut_fraction * 100.0,
        q.is_structurally_ideal(),
        fmt_duration(report.max_partition_train_secs),
        fmt_duration(report.wall_secs),
    );
    println!(
        "val {} = {:.4} | test {} = {:.4}",
        report.eval.metric_name,
        report.eval.val_metric,
        report.eval.metric_name,
        report.eval.test_metric
    );
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let base = experiment_config(args)?;
    let ds = load_dataset(&base.dataset, base.dataset_n, base.seed)?;
    let mut t = Table::new(
        "LF vs baselines",
        &["method", "edge-cut%", "ideal", "test-metric", "max-part-train"],
    );
    for method in ["lf", "metis", "lpa"] {
        let mut cfg = base.clone();
        cfg.partitioner = method.to_string();
        let (p, report) = run_experiment(&cfg, &ds)?;
        let q = PartitionQuality::measure(&ds.graph, &p);
        t.row(vec![
            method.to_string(),
            format!("{:.2}", q.edge_cut_fraction * 100.0),
            q.is_structurally_ideal().to_string(),
            format!("{:.4}", report.eval.test_metric),
            fmt_duration(report.max_partition_train_secs),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("datasets:");
    println!("  karate    34 nodes / 78 edges, 2 classes (exact Zachary graph)");
    let a = ArxivLikeConfig::default();
    println!(
        "  arxiv     {} nodes (default), {} classes, multiclass (SBM stand-in)",
        a.n, a.classes
    );
    let p = ProteinsLikeConfig::default();
    println!(
        "  proteins  {} nodes (default), {} tasks, multilabel dense (SBM stand-in)",
        p.n, p.tasks
    );
    let dir = leiden_fusion::runtime::default_artifacts_dir();
    match Manifest::load(&dir) {
        Ok(man) => {
            println!("\nartifacts ({}):", dir.display());
            let mut t =
                Table::new("Compiled artifacts", &["name", "model", "task", "role", "n", "e"]);
            for a in &man.artifacts {
                t.row(vec![
                    a.name.clone(),
                    a.model.clone(),
                    a.task.clone(),
                    a.role.clone(),
                    a.dims.n.to_string(),
                    a.dims.e.to_string(),
                ]);
            }
            t.print();
        }
        Err(e) => println!("\nartifacts: unavailable ({e})"),
    }
    Ok(())
}
