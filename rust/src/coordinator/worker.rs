//! Machine worker: owns a thread-local PJRT runtime and trains partitions
//! pulled from the shared [`JobQueue`] until the queue signals exit.
//!
//! Fault surface (see `fault/`): `runtime.init` fires before the PJRT
//! client comes up — an injected (or real) init failure retires the
//! worker via [`WorkerEvent::Retired`]; `worker.batch` fires around
//! subgraph/tensor assembly and `worker.train` around the training loop —
//! both surface as ordinary job failures for the leader's retry/backoff
//! machinery.

use super::messages::{ErrorCode, Job, WorkerEvent};
use super::queue::JobQueue;
use super::CoordinatorConfig;
use crate::data::Dataset;
use crate::error::Result;
use crate::fault;
use crate::graph::SubgraphScratch;
use crate::obs;
use crate::runtime::Runtime;
use crate::train::{
    build_batch_with, train_partition_with, PadScratch, TrainOptions, TrainedPartition,
};
use crate::util::json::num;
use std::sync::mpsc::Sender;

/// Worker main loop. Pops jobs until [`JobQueue::pop`] returns `None`
/// (shutdown, retirement, or no open jobs left).
pub fn worker_loop(
    worker: usize,
    dataset: &Dataset,
    queue: &JobQueue,
    tx: Sender<WorkerEvent>,
    cfg: &CoordinatorConfig,
) {
    // One PJRT client per machine (PjRtClient is thread-local by design).
    let rt = match init_runtime(cfg) {
        Ok(rt) => rt,
        Err(e) => {
            // Without a runtime this worker can do nothing: retire it so
            // the leader re-plans over the survivors (or aborts at zero).
            log::error!("worker {worker}: runtime init failed: {e}");
            let _ = tx.send(WorkerEvent::Retired { worker, error: e.to_string() });
            return;
        }
    };

    // One subgraph-extraction scratch and one bucket-padding scratch
    // reused across every partition this machine trains (the dense id map
    // and the padded tensor slabs allocate once, not per job — retries of
    // a failed partition reuse them too).
    let mut scratch = SubgraphScratch::new();
    let mut pads = PadScratch::new();
    // One span per worker lifetime — the trace shows each simulated
    // machine as a lane of per-partition training spans.
    let _worker_span = obs::span("coordinator", "worker").with("worker", num(worker as f64));
    while let Some(job) = queue.pop(worker) {
        let _ = tx.send(WorkerEvent::Started { worker, part_id: job.part_id });
        let mut job_span = obs::span("coordinator", "train_partition");
        if obs::tracing_enabled() {
            job_span.attr("worker", num(worker as f64));
            job_span.attr("part", num(job.part_id as f64));
            job_span.attr("nodes", num(job.members.len() as f64));
            job_span.attr("attempt", num(job.attempt as f64));
        }
        match run_job(&rt, dataset, &job, cfg, &mut scratch, &mut pads) {
            Ok((nodes, result)) => {
                if tx
                    .send(WorkerEvent::Finished {
                        worker,
                        part_id: job.part_id,
                        attempt: job.attempt,
                        nodes,
                        result,
                    })
                    .is_err()
                {
                    break; // leader gone
                }
            }
            Err(e) => {
                if tx
                    .send(WorkerEvent::Failed {
                        worker,
                        part_id: job.part_id,
                        code: ErrorCode::of(&e),
                        message: e.to_string(),
                    })
                    .is_err()
                {
                    break;
                }
            }
        }
    }
}

pub(crate) fn init_runtime(cfg: &CoordinatorConfig) -> Result<Runtime> {
    if let Some(inj) = fault::point("runtime.init").fire() {
        return Err(inj.error());
    }
    Runtime::new(&cfg.artifacts_dir)
}

pub(crate) fn run_job(
    rt: &Runtime,
    dataset: &Dataset,
    job: &Job,
    cfg: &CoordinatorConfig,
    scratch: &mut SubgraphScratch,
    pads: &mut PadScratch,
) -> Result<(Vec<crate::graph::NodeId>, TrainedPartition)> {
    if let Some(inj) = fault::point("worker.batch").part(job.part_id).attempt(job.attempt).fire() {
        return Err(inj.error());
    }
    let batch = build_batch_with(dataset, &job.members, cfg.mode, cfg.model, scratch)?;
    if let Some(inj) = fault::point("worker.train").part(job.part_id).attempt(job.attempt).fire() {
        return Err(inj.error());
    }
    let opts = TrainOptions {
        model: cfg.model,
        epochs: cfg.epochs,
        // seed depends on the partition only, never the attempt: a
        // retried job trains bit-identically to a first-try success —
        // the chaos-determinism contract rests on this line
        seed: cfg.seed ^ (job.part_id as u64) << 8,
        log_every: 0,
        exec: cfg.exec,
    };
    let result = train_partition_with(rt, &batch, &opts, pads)?;
    // Owned nodes only (prefix of sub.nodes) — replicas are discarded.
    let nodes = batch.sub.nodes[..batch.sub.num_owned].to_vec();
    Ok((nodes, result))
}
