//! Machine worker: owns a thread-local PJRT runtime and trains partitions
//! pulled from the shared job queue until the queue drains.

use super::messages::{Job, WorkerEvent};
use super::CoordinatorConfig;
use crate::data::Dataset;
use crate::error::Result;
use crate::graph::SubgraphScratch;
use crate::obs;
use crate::runtime::Runtime;
use crate::util::json::num;
use crate::train::{
    build_batch_with, train_partition_with, PadScratch, TrainOptions, TrainedPartition,
};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

/// Worker main loop. Runs until `remaining` (jobs not yet successfully
/// finished, maintained by the leader) reaches zero — merely draining the
/// queue is not enough because a failed job may be re-queued by the leader
/// after this worker observes an empty queue.
pub fn worker_loop(
    worker: usize,
    dataset: &Dataset,
    queue: Arc<Mutex<VecDeque<Job>>>,
    remaining: Arc<AtomicUsize>,
    tx: Sender<WorkerEvent>,
    cfg: &CoordinatorConfig,
) {
    // One PJRT client per machine (PjRtClient is thread-local by design).
    let rt = match Runtime::new(&cfg.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) => {
            // Without a runtime this worker can do nothing; report failure
            // for the next job so the leader can retry elsewhere.
            log::error!("worker {worker}: runtime init failed: {e}");
            // recover a poisoned queue: it only ever holds complete Jobs,
            // and stalling here would hang the leader's recv loop
            let next = queue
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .pop_front();
            if let Some(job) = next {
                let _ = tx.send(WorkerEvent::Failed {
                    worker,
                    part_id: job.part_id,
                    error: format!("runtime init: {e}"),
                });
            }
            return;
        }
    };

    // One subgraph-extraction scratch and one bucket-padding scratch
    // reused across every partition this machine trains (the dense id map
    // and the padded tensor slabs allocate once, not per job — retries of
    // a failed partition reuse them too).
    let mut scratch = SubgraphScratch::new();
    let mut pads = PadScratch::new();
    // One span per worker lifetime — the trace shows each simulated
    // machine as a lane of per-partition training spans.
    let _worker_span = obs::span("coordinator", "worker").with("worker", num(worker as f64));
    loop {
        if remaining.load(Ordering::Acquire) == 0 {
            break;
        }
        let next = queue
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .pop_front();
        let job = match next {
            Some(j) => j,
            None => {
                // queue drained but work may be re-queued on failure
                std::thread::sleep(std::time::Duration::from_millis(2));
                continue;
            }
        };
        let _ = tx.send(WorkerEvent::Started { worker, part_id: job.part_id });
        let mut job_span = obs::span("coordinator", "train_partition");
        if obs::tracing_enabled() {
            job_span.attr("worker", num(worker as f64));
            job_span.attr("part", num(job.part_id as f64));
            job_span.attr("nodes", num(job.members.len() as f64));
            job_span.attr("attempt", num(job.attempt as f64));
        }
        match run_job(&rt, dataset, &job, cfg, &mut scratch, &mut pads) {
            Ok((nodes, result)) => {
                if tx
                    .send(WorkerEvent::Finished { worker, part_id: job.part_id, nodes, result })
                    .is_err()
                {
                    break; // leader gone
                }
            }
            Err(e) => {
                if tx
                    .send(WorkerEvent::Failed {
                        worker,
                        part_id: job.part_id,
                        error: e.to_string(),
                    })
                    .is_err()
                {
                    break;
                }
            }
        }
    }
}

fn run_job(
    rt: &Runtime,
    dataset: &Dataset,
    job: &Job,
    cfg: &CoordinatorConfig,
    scratch: &mut SubgraphScratch,
    pads: &mut PadScratch,
) -> Result<(Vec<crate::graph::NodeId>, TrainedPartition)> {
    // Test hook: simulate a machine fault on the first attempt.
    if cfg.inject_failure == Some(job.part_id) && job.attempt == 0 {
        return Err(crate::error::Error::Coordinator(
            "injected fault (test hook)".into(),
        ));
    }
    let batch = build_batch_with(dataset, &job.members, cfg.mode, cfg.model, scratch)?;
    let opts = TrainOptions {
        model: cfg.model,
        epochs: cfg.epochs,
        seed: cfg.seed ^ (job.part_id as u64) << 8,
        log_every: 0,
        exec: cfg.exec,
    };
    let result = train_partition_with(rt, &batch, &opts, pads)?;
    // Owned nodes only (prefix of sub.nodes) — replicas are discarded.
    let nodes = batch.sub.nodes[..batch.sub.num_owned].to_vec();
    Ok((nodes, result))
}
