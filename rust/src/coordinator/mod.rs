//! Distributed-training coordinator: leader + machine workers.
//!
//! The paper's training phase is *communication-free*: after partitioning,
//! each machine trains its subgraph independently and only the final
//! embeddings are gathered. The coordinator therefore exchanges nothing but
//! control messages (job dispatch, progress, results) — which is why worker
//! threads with private PJRT runtimes are a behaviour-preserving stand-in
//! for physical machines (the paper itself emulates the cluster by training
//! partitions sequentially on one host; §5 Setup).
//!
//! Topology: a work queue feeds `min(machines, k)` workers; each worker
//! owns a thread-local [`Runtime`] (PJRT clients are not `Send`), trains
//! whole partitions, and streams [`WorkerEvent`]s back to the leader, which
//! assembles the embedding store, retries failed jobs, and finally runs the
//! integration MLP + evaluation.

pub mod messages;
pub mod worker;

pub use messages::{Job, WorkerEvent};

use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::obs;
use crate::partition::{PartitionReport, Partitioning, StageTiming};
use crate::runtime::Runtime;
use crate::train::{
    checkpoint, evaluate_classifier, train_classifier_path, EmbeddingStore, EvalReport,
    ExecPath, Mode, ModelKind,
};
use crate::util::json::num;
use crate::util::Stopwatch;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Simulated machine count (worker threads). Partitions are scheduled
    /// onto machines; k > machines simply queues.
    pub machines: usize,
    pub mode: Mode,
    pub model: ModelKind,
    /// GNN epochs per partition.
    pub epochs: usize,
    /// Integration-MLP epochs.
    pub mlp_epochs: usize,
    pub seed: u64,
    /// Re-dispatch attempts for a failed partition.
    pub max_retries: u32,
    /// PJRT execution strategy for the GNN and MLP training loops
    /// (default: the device-resident session; `Reference` restores the
    /// host round-trip for A/B runs and oracle checks).
    pub exec: ExecPath,
    /// Artifacts directory (manifest + HLO text).
    pub artifacts_dir: PathBuf,
    /// When set, write a serving bundle here: one `LFS1` shard per
    /// partition (emitted as each partition finishes), the trained
    /// integration-MLP checkpoint, and `shards.json`.
    pub shard_dir: Option<PathBuf>,
    /// Test hook: partition id that fails on its first attempt.
    pub inject_failure: Option<u32>,
}

impl CoordinatorConfig {
    pub fn new(artifacts_dir: PathBuf) -> Self {
        CoordinatorConfig {
            machines: 4,
            mode: Mode::Inner,
            model: ModelKind::Gcn,
            epochs: 80,
            mlp_epochs: 200,
            seed: 0,
            max_retries: 1,
            exec: ExecPath::Session,
            artifacts_dir,
            shard_dir: None,
            inject_failure: None,
        }
    }
}

/// Per-partition statistics surfaced in the report.
#[derive(Clone, Debug)]
pub struct PartitionStats {
    pub part_id: u32,
    pub num_nodes: usize,
    pub num_replicas: usize,
    pub losses: Vec<f32>,
    pub train_secs: f64,
    pub attempts: u32,
}

/// Full distributed-training report.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub per_partition: Vec<PartitionStats>,
    pub eval: EvalReport,
    /// Per-stage partitioning wall times, carried over from the
    /// [`PartitionReport`] when the run was started with
    /// [`Coordinator::run_report`] (empty for a bare [`Partitioning`]).
    pub partition_stages: Vec<StageTiming>,
    /// Leader wall-clock for the whole run.
    pub wall_secs: f64,
    /// Longest single-partition training time — the paper's Fig. 7 metric
    /// (= makespan of a truly distributed run with k machines).
    pub max_partition_train_secs: f64,
    /// Σ per-partition training time (= sequential-emulation cost).
    pub total_train_secs: f64,
}

/// The leader. Owns the job queue and the result channel.
pub struct Coordinator {
    cfg: CoordinatorConfig,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Self {
        Coordinator { cfg }
    }

    /// Run distributed training over a [`PartitionReport`], logging the
    /// partitioning stage timings and carrying them into the
    /// [`TrainReport`].
    pub fn run_report(
        &self,
        dataset: &Dataset,
        partition: &PartitionReport,
    ) -> Result<TrainReport> {
        // Progress chatter goes to the trace as structured events (the
        // pipeline already recorded the stage spans themselves) and to the
        // logger only at debug level — quiet runs stay quiet.
        for st in &partition.stages {
            obs::event(
                "coordinator",
                "partition.stage",
                vec![("secs", num(st.secs)), ("parts", num(st.parts as f64))],
            );
            log::debug!(
                "partition stage {}: {:.1}ms → {} parts",
                st.name,
                st.secs * 1e3,
                st.parts
            );
        }
        let mut report = self.run(dataset, &partition.partitioning)?;
        report.partition_stages = partition.stages.clone();
        Ok(report)
    }

    /// Run distributed training of `dataset` over `partitioning`.
    pub fn run(&self, dataset: &Dataset, partitioning: &Partitioning) -> Result<TrainReport> {
        let sw = Stopwatch::start();
        let mut run_span = obs::span("coordinator", "run");
        if obs::tracing_enabled() {
            run_span.attr("k", num(partitioning.k() as f64));
            run_span.attr("nodes", num(dataset.num_nodes() as f64));
            run_span.attr("machines", num(self.cfg.machines as f64));
        }
        // Invalidate any pre-existing bundle before writing the first
        // shard: the manifest is deleted now and rewritten only after a
        // fully successful run, so an aborted run can never leave a
        // readable bundle that mixes shards from different runs.
        if let Some(dir) = &self.cfg.shard_dir {
            std::fs::create_dir_all(dir)?;
            let manifest_path = crate::serve::ShardManifest::path_in(dir);
            if manifest_path.exists() {
                std::fs::remove_file(&manifest_path)?;
            }
        }
        let k = partitioning.k();
        let members = partitioning.members();
        let workers = self.cfg.machines.min(k).max(1);

        let queue: Arc<Mutex<VecDeque<Job>>> = Arc::new(Mutex::new(
            members
                .iter()
                .enumerate()
                .filter(|(_, m)| !m.is_empty())
                .map(|(part_id, m)| Job {
                    part_id: part_id as u32,
                    members: m.clone(),
                    attempt: 0,
                })
                .collect(),
        ));
        // queue ops are a pop/push of plain Jobs — never left mid-update,
        // so a poisoned lock (panicked worker) is safe to recover
        let live_jobs = queue
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .len();
        let remaining = Arc::new(AtomicUsize::new(live_jobs));
        let (tx, rx) = mpsc::channel::<WorkerEvent>();

        let mut store: Option<EmbeddingStore> = None;
        let mut stats: Vec<PartitionStats> = Vec::with_capacity(live_jobs);
        let mut attempts = vec![0u32; k];

        // lint: allow(spawn_outside_parallel) — leader/worker topology over an mpsc channel with retries, not the ordered fork-join map util::parallel models
        let run_result = std::thread::scope(|scope| -> Result<()> {
            for wid in 0..workers {
                let queue = Arc::clone(&queue);
                let remaining = Arc::clone(&remaining);
                let tx = tx.clone();
                let cfg = self.cfg.clone();
                scope.spawn(move || {
                    worker::worker_loop(wid, dataset, queue, remaining, tx, &cfg);
                });
            }
            drop(tx);

            let mut done = 0usize;
            while done < live_jobs {
                let event = rx.recv().map_err(|_| {
                    Error::Coordinator("all workers exited before completion".into())
                })?;
                match event {
                    WorkerEvent::Started { worker, part_id } => {
                        log::debug!("worker {worker} started partition {part_id}");
                    }
                    WorkerEvent::Finished { worker, part_id, nodes, result } => {
                        obs::event(
                            "coordinator",
                            "partition.finished",
                            vec![
                                ("worker", num(worker as f64)),
                                ("part", num(part_id as f64)),
                                ("nodes", num(nodes.len() as f64)),
                                ("train_secs", num(result.train_secs)),
                            ],
                        );
                        obs::registry().counter("coordinator.partitions_trained").inc();
                        log::debug!(
                            "worker {worker} finished partition {part_id}: \
                             {} nodes, final loss {:.4}, {:.2}s",
                            nodes.len(),
                            result.losses.last().copied().unwrap_or(f32::NAN),
                            result.train_secs
                        );
                        let st = store.get_or_insert_with(|| {
                            EmbeddingStore::new(dataset.num_nodes(), result.emb_dim)
                        });
                        st.insert(&nodes, &result.embeddings)?;
                        // shard-per-partition export: write while the rest
                        // of the cluster is still training
                        if let Some(dir) = &self.cfg.shard_dir {
                            crate::serve::write_shard(
                                &dir.join(crate::serve::shard_file_name(part_id)),
                                part_id,
                                &nodes,
                                &result.embeddings,
                                result.emb_dim,
                            )?;
                        }
                        stats.push(PartitionStats {
                            part_id,
                            num_nodes: nodes.len(),
                            num_replicas: result.num_replicas,
                            losses: result.losses,
                            train_secs: result.train_secs,
                            attempts: attempts[part_id as usize] + 1,
                        });
                        done += 1;
                        remaining.fetch_sub(1, Ordering::Release);
                    }
                    WorkerEvent::Failed { worker, part_id, error } => {
                        attempts[part_id as usize] += 1;
                        let tries = attempts[part_id as usize];
                        if tries > self.cfg.max_retries {
                            remaining.store(0, Ordering::Release); // stop workers
                            return Err(Error::Coordinator(format!(
                                "partition {part_id} failed {tries} times \
                                 (worker {worker}): {error}"
                            )));
                        }
                        obs::event(
                            "coordinator",
                            "partition.retry",
                            vec![
                                ("worker", num(worker as f64)),
                                ("part", num(part_id as f64)),
                                ("attempt", num(tries as f64)),
                            ],
                        );
                        obs::registry().counter("coordinator.retries").inc();
                        log::warn!(
                            "partition {part_id} failed on worker {worker} \
                             (attempt {tries}): {error}; requeueing"
                        );
                        let mut q = queue
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner);
                        q.push_back(Job {
                            part_id,
                            members: members[part_id as usize].clone(),
                            attempt: tries,
                        });
                    }
                }
            }
            Ok(())
        });
        remaining.store(0, Ordering::Release);
        run_result?;

        let store = store
            .ok_or_else(|| Error::Coordinator("no partitions produced output".into()))?;

        // ---- integration + evaluation on the leader ---------------------
        let leader_rt = Runtime::new(&self.cfg.artifacts_dir)?;
        // preflight the pred artifact so a train-only manifest fails here,
        // not after the full MLP training loop (compilation is cached for
        // the evaluation pass)
        leader_rt.load_for("mlp", dataset.labels.task_name(), "pred", store.n, 0)?;
        let clf = {
            let _sp = obs::span("coordinator", "integrate");
            train_classifier_path(
                &leader_rt,
                dataset,
                &store,
                self.cfg.mlp_epochs,
                self.cfg.seed ^ 0x11,
                self.cfg.exec,
            )?
        };
        let eval = {
            let _sp = obs::span("coordinator", "evaluate");
            evaluate_classifier(&leader_rt, dataset, &store, &clf)?
        };

        stats.sort_by_key(|s| s.part_id);

        // ---- finalize the serving bundle --------------------------------
        if let Some(dir) = &self.cfg.shard_dir {
            checkpoint::save_tensors(&dir.join(crate::serve::CLASSIFIER_FILE), &clf.params)?;
            let manifest = crate::serve::ShardManifest {
                version: 1,
                dataset: dataset.name.clone(),
                task: clf.task.to_string(),
                num_nodes: dataset.num_nodes(),
                dim: store.dim,
                classes: clf.classes,
                classifier_file: crate::serve::CLASSIFIER_FILE.to_string(),
                shards: stats
                    .iter()
                    .map(|s| crate::serve::ShardEntry {
                        file: crate::serve::shard_file_name(s.part_id),
                        part_id: s.part_id,
                        rows: s.num_nodes,
                    })
                    .collect(),
            };
            manifest.save(dir)?;
            obs::event(
                "coordinator",
                "bundle.written",
                vec![
                    ("shards", num(manifest.shards.len() as f64)),
                    ("nodes", num(manifest.num_nodes as f64)),
                ],
            );
            log::debug!(
                "serving bundle written to {} ({} shards, {} nodes, dim {})",
                dir.display(),
                manifest.shards.len(),
                manifest.num_nodes,
                manifest.dim
            );
        }

        let max_partition_train_secs = stats
            .iter()
            .map(|s| s.train_secs)
            .fold(0.0f64, f64::max);
        let total_train_secs = stats.iter().map(|s| s.train_secs).sum();
        Ok(TrainReport {
            per_partition: stats,
            eval,
            partition_stages: Vec::new(),
            wall_secs: sw.secs(),
            max_partition_train_secs,
            total_train_secs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::karate_dataset;
    use crate::partition::leiden::leiden_fusion;
    use crate::testing::artifacts_if_built;

    fn cfg_if_built() -> Option<CoordinatorConfig> {
        let mut c = CoordinatorConfig::new(artifacts_if_built()?);
        c.epochs = 10;
        c.mlp_epochs = 30;
        c.machines = 2;
        Some(c)
    }

    #[test]
    fn end_to_end_karate_two_partitions() {
        let Some(cfg) = cfg_if_built() else { return };
        let ds = karate_dataset(5);
        let p = leiden_fusion(&ds.graph, 2, 0.05, 0.5, 1).unwrap();
        let report = Coordinator::new(cfg).run(&ds, &p).unwrap();
        assert_eq!(report.per_partition.len(), 2);
        assert!(report.eval.test_metric >= 0.0);
        assert!(report.max_partition_train_secs > 0.0);
        assert!(report.total_train_secs >= report.max_partition_train_secs);
    }

    #[test]
    fn run_report_carries_partition_stage_timings() {
        let Some(cfg) = cfg_if_built() else { return };
        let ds = karate_dataset(5);
        let preport = crate::partition::PartitionPipeline::parse("lf", 1)
            .unwrap()
            .run(&ds.graph, 2)
            .unwrap();
        let report = Coordinator::new(cfg).run_report(&ds, &preport).unwrap();
        let names: Vec<&str> = report
            .partition_stages
            .iter()
            .map(|s| s.name.as_str())
            .collect();
        assert_eq!(names, vec!["leiden", "fusion", "validate"]);
    }

    #[test]
    fn session_and_reference_exec_agree_end_to_end() {
        // Same seeds, same partitioning: the device-resident session and
        // the host round-trip must land on identical metrics (the session
        // is bit-exact per step, so the whole pipeline agrees).
        let Some(cfg) = cfg_if_built() else { return };
        let ds = karate_dataset(5);
        let p = leiden_fusion(&ds.graph, 2, 0.05, 0.5, 1).unwrap();
        let mut ref_cfg = cfg.clone();
        ref_cfg.exec = ExecPath::Reference;
        ref_cfg.machines = 1;
        let mut ses_cfg = cfg;
        ses_cfg.machines = 1;
        let a = Coordinator::new(ses_cfg).run(&ds, &p).unwrap();
        let b = Coordinator::new(ref_cfg).run(&ds, &p).unwrap();
        assert_eq!(a.eval.test_metric, b.eval.test_metric);
        assert_eq!(a.eval.val_metric, b.eval.val_metric);
        for (x, y) in a.eval.mlp_losses.iter().zip(&b.eval.mlp_losses) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn failure_injection_retries_and_succeeds() {
        let Some(mut cfg) = cfg_if_built() else { return };
        cfg.inject_failure = Some(0);
        cfg.max_retries = 1;
        let ds = karate_dataset(5);
        let p = leiden_fusion(&ds.graph, 2, 0.05, 0.5, 1).unwrap();
        let report = Coordinator::new(cfg).run(&ds, &p).unwrap();
        let p0 = report.per_partition.iter().find(|s| s.part_id == 0).unwrap();
        assert_eq!(p0.attempts, 2, "partition 0 should have been retried");
    }

    #[test]
    fn writes_serving_bundle_when_shard_dir_set() {
        let Some(mut cfg) = cfg_if_built() else { return };
        let dir = std::env::temp_dir().join(format!("lf_bundle_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        cfg.shard_dir = Some(dir.clone());
        let ds = karate_dataset(5);
        let p = leiden_fusion(&ds.graph, 2, 0.05, 0.5, 1).unwrap();
        let report = Coordinator::new(cfg).run(&ds, &p).unwrap();
        let store = crate::serve::ShardedEmbeddingStore::open(&dir).unwrap();
        assert_eq!(store.num_nodes(), ds.num_nodes());
        assert_eq!(store.num_shards(), report.per_partition.len());
        assert!(dir.join(crate::serve::CLASSIFIER_FILE).exists());
        // shard rows must be the exact embeddings the store assembled
        for s in &report.per_partition {
            let (header, _) = crate::serve::read_shard(
                &dir.join(crate::serve::shard_file_name(s.part_id)),
            )
            .unwrap();
            assert_eq!(header.rows, s.num_nodes);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn failure_exhausts_retries() {
        let Some(mut cfg) = cfg_if_built() else { return };
        cfg.inject_failure = Some(0);
        cfg.max_retries = 0;
        let ds = karate_dataset(5);
        let p = leiden_fusion(&ds.graph, 2, 0.05, 0.5, 1).unwrap();
        assert!(Coordinator::new(cfg).run(&ds, &p).is_err());
    }
}
