//! Distributed-training coordinator: leader + machine workers.
//!
//! The paper's training phase is *communication-free*: after partitioning,
//! each machine trains its subgraph independently and only the final
//! embeddings are gathered. The coordinator therefore exchanges nothing but
//! control messages (job dispatch, progress, results) — which is why worker
//! threads with private PJRT runtimes are a behaviour-preserving stand-in
//! for physical machines (the paper itself emulates the cluster by training
//! partitions sequentially on one host; §5 Setup).
//!
//! Topology: a condvar [`JobQueue`] feeds the workers; each worker owns
//! a thread-local [`Runtime`] (PJRT clients are not `Send`), trains
//! whole partitions, and streams [`WorkerEvent`]s back to the leader,
//! which assembles the embedding store and finally runs the integration
//! MLP + evaluation. The worker side is one [`Transport`] choice:
//! `Local` spawns `min(machines, jobs)` in-process threads; `Tcp` binds
//! a socket and lets `repro worker join` processes fill the `machines`
//! slots over the `LFN1` wire protocol (see [`crate::net`]) — the event
//! loop is transport-blind, so retries, deadlines, journaling, and the
//! final metrics are byte-for-byte the same code either way.
//!
//! Fault tolerance (see DESIGN.md *Robustness*):
//!
//! * **Retries with backoff** — a transiently-failed partition is
//!   requeued after a seeded-jitter exponential delay
//!   ([`crate::fault::Backoff`]); the delay lives on the queue, the
//!   leader's event loop never sleeps. Permanent errors skip the retry
//!   budget entirely.
//! * **Deadline watchdog** — with `deadline_secs` set, a partition
//!   running past the deadline is requeued elsewhere and the worker is
//!   marked suspect; two expiries retire the worker.
//! * **`on_failure` policy** — a partition that exhausts its retries
//!   either aborts the run (`Abort`, the default) or becomes a recorded
//!   hole (`Skip`): integration and evaluation run over the survivors
//!   and [`TrainReport::coverage`] drops below 1.0.
//! * **Run journal + resume** — with a shard dir, every completed
//!   partition is journaled ([`RunJournal`]); `--resume` replays intact
//!   journaled shards and retrains only what's missing.
//! * **Worker retirement** — a worker whose PJRT runtime fails to
//!   initialise sends [`WorkerEvent::Retired`]; its jobs redistribute
//!   over the survivors and a run with zero live workers aborts. Over
//!   TCP the same event retires a worker that stayed disconnected past
//!   its grace window.
//! * **Idempotent results** — the leader accepts each `(part_id,
//!   attempt)` at most once and ignores results for resolved
//!   partitions, so a result racing its own requeue (a crashed worker's
//!   last frame, a deadline-expired attempt that finished anyway) can
//!   never double-count.

pub mod journal;
pub mod messages;
pub mod queue;
pub mod worker;

pub use journal::{JournalState, PartRecord, RunJournal};
pub use messages::{ErrorCode, Job, WorkerEvent};
pub use queue::JobQueue;

use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::fault::Backoff;
use crate::graph::NodeId;
use crate::obs;
use crate::partition::{PartitionReport, Partitioning, StageTiming};
use crate::runtime::Runtime;
use crate::train::{
    checkpoint, evaluate_classifier, train_classifier_path, EmbeddingStore, EvalReport,
    ExecPath, Mode, ModelKind,
};
use crate::util::json::num;
use crate::util::Stopwatch;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Watchdog granularity: how often the leader scans for deadline
/// expiries while waiting for worker events.
const WATCHDOG_TICK_MS: u64 = 20;

/// Deadline expiries before a worker is retired as unhealthy.
const SUSPECT_RETIRE_THRESHOLD: u32 = 2;

/// Leader-side attempts for one shard write (first try + retries).
const SHARD_WRITE_ATTEMPTS: u32 = 3;

/// What to do with a partition that exhausted its retry budget (or
/// failed permanently).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailurePolicy {
    /// Fail the whole run (the strict default).
    Abort,
    /// Record the partition as a hole and train/evaluate over the
    /// survivors; [`TrainReport::coverage`] reports the damage.
    Skip,
}

impl FailurePolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            FailurePolicy::Abort => "abort",
            FailurePolicy::Skip => "skip",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "abort" => Ok(FailurePolicy::Abort),
            "skip" => Ok(FailurePolicy::Skip),
            other => Err(Error::Config(format!(
                "unknown on_failure policy {other:?} (expected abort|skip)"
            ))),
        }
    }
}

/// Which transport carries jobs and results between leader and workers.
#[derive(Clone, Debug, Default)]
pub enum Transport {
    /// In-process worker threads over an mpsc channel (the default).
    #[default]
    Local,
    /// Multi-process TCP: the leader binds a socket and `repro worker
    /// join` processes fill the worker slots over the `LFN1` framed
    /// protocol (see [`crate::net`]).
    Tcp(crate::config::NetConfig),
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Simulated machine count (worker threads). Partitions are scheduled
    /// onto machines; k > machines simply queues.
    pub machines: usize,
    pub mode: Mode,
    pub model: ModelKind,
    /// GNN epochs per partition.
    pub epochs: usize,
    /// Integration-MLP epochs.
    pub mlp_epochs: usize,
    pub seed: u64,
    /// Re-dispatch attempts for a transiently-failed partition.
    pub max_retries: u32,
    /// PJRT execution strategy for the GNN and MLP training loops
    /// (default: the device-resident session; `Reference` restores the
    /// host round-trip for A/B runs and oracle checks).
    pub exec: ExecPath,
    /// Artifacts directory (manifest + HLO text).
    pub artifacts_dir: PathBuf,
    /// When set, write a serving bundle here: one `LFS1` shard per
    /// partition (emitted as each partition finishes), the trained
    /// integration-MLP checkpoint, `shards.json`, and the run journal.
    pub shard_dir: Option<PathBuf>,
    /// Policy for partitions that exhaust their retries.
    pub on_failure: FailurePolicy,
    /// Per-partition training deadline in seconds (0 = no watchdog).
    pub deadline_secs: f64,
    /// Replay intact journaled partitions instead of retraining them
    /// (requires `shard_dir`; see [`RunJournal`]).
    pub resume: bool,
    /// How workers are attached: in-process threads or TCP sessions.
    pub transport: Transport,
}

impl CoordinatorConfig {
    pub fn new(artifacts_dir: PathBuf) -> Self {
        CoordinatorConfig {
            machines: 4,
            mode: Mode::Inner,
            model: ModelKind::Gcn,
            epochs: 80,
            mlp_epochs: 200,
            seed: 0,
            max_retries: 1,
            exec: ExecPath::Session,
            artifacts_dir,
            shard_dir: None,
            on_failure: FailurePolicy::Abort,
            deadline_secs: 0.0,
            resume: false,
            transport: Transport::Local,
        }
    }
}

/// Per-partition statistics surfaced in the report.
#[derive(Clone, Debug)]
pub struct PartitionStats {
    pub part_id: u32,
    pub num_nodes: usize,
    pub num_replicas: usize,
    /// Per-call training losses (empty for a partition replayed from the
    /// journal — the numbers were not retained, only the embeddings).
    pub losses: Vec<f32>,
    pub train_secs: f64,
    pub attempts: u32,
}

/// Full distributed-training report.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub per_partition: Vec<PartitionStats>,
    pub eval: EvalReport,
    /// Per-stage partitioning wall times, carried over from the
    /// [`PartitionReport`] when the run was started with
    /// [`Coordinator::run_report`] (empty for a bare [`Partitioning`]).
    pub partition_stages: Vec<StageTiming>,
    /// Leader wall-clock for the whole run.
    pub wall_secs: f64,
    /// Longest single-partition training time — the paper's Fig. 7 metric
    /// (= makespan of a truly distributed run with k machines).
    pub max_partition_train_secs: f64,
    /// Σ per-partition training time (= sequential-emulation cost).
    pub total_train_secs: f64,
    /// Fraction of dataset nodes with a trained embedding: 1.0 for a
    /// clean run, < 1.0 when `on_failure = skip` recorded holes.
    pub coverage: f64,
    /// Partitions skipped under `on_failure = skip`, ascending.
    pub skipped_partitions: Vec<u32>,
}

/// Outcome of one exhausted/failed partition attempt.
enum Verdict {
    Requeued,
    Skipped,
    Abort(String),
}

/// Classify a partition failure and perform the retry/skip bookkeeping.
/// Transient failures inside the retry budget are requeued with seeded
/// exponential backoff; everything else falls to the `on_failure` policy.
fn handle_failure(
    cfg: &CoordinatorConfig,
    queue: &JobQueue,
    members: &[Vec<NodeId>],
    backoff: &mut Backoff,
    part_id: u32,
    tries: u32,
    transient: bool,
    error: &str,
) -> Verdict {
    if transient && tries <= cfg.max_retries {
        let delay_ms = backoff.delay_ms(tries);
        obs::registry().counter("coordinator.retries").inc();
        obs::registry()
            .histogram("coordinator.backoff_secs")
            .record(delay_ms as f64 / 1e3);
        obs::event(
            "coordinator",
            "partition.retry",
            vec![
                ("part", num(part_id as f64)),
                ("attempt", num(tries as f64)),
                ("backoff_ms", num(delay_ms as f64)),
            ],
        );
        log::warn!(
            "partition {part_id} failed (attempt {tries}): {error}; \
             requeueing after {delay_ms}ms backoff"
        );
        queue.push_delayed(
            Job {
                part_id,
                members: members[part_id as usize].clone(),
                attempt: tries,
            },
            delay_ms,
        );
        Verdict::Requeued
    } else {
        match cfg.on_failure {
            FailurePolicy::Abort => Verdict::Abort(format!(
                "partition {part_id} failed after {tries} attempt(s): {error}"
            )),
            FailurePolicy::Skip => {
                obs::registry().counter("coordinator.skipped_partitions").inc();
                obs::event(
                    "coordinator",
                    "partition.skipped",
                    vec![("part", num(part_id as f64)), ("attempts", num(tries as f64))],
                );
                log::warn!(
                    "partition {part_id} failed after {tries} attempt(s): {error}; \
                     skipping (on_failure = skip)"
                );
                Verdict::Skipped
            }
        }
    }
}

/// Leader-side durable shard write: transient failures (I/O, injected
/// `shard.write` faults) are retried with backoff; a persistent failure
/// is fatal regardless of `on_failure` — the partition trained fine, but
/// a bundle the run cannot complete must not be reported as written
/// (crash-recover via `--resume` instead).
fn write_shard_with_retry(
    path: &Path,
    part_id: u32,
    nodes: &[NodeId],
    emb: &[f32],
    dim: usize,
    backoff: &mut Backoff,
) -> Result<()> {
    let mut attempt = 0u32;
    loop {
        match crate::serve::write_shard(path, part_id, nodes, emb, dim) {
            Ok(()) => return Ok(()),
            Err(e) if e.is_transient() && attempt + 1 < SHARD_WRITE_ATTEMPTS => {
                attempt += 1;
                obs::registry().counter("coordinator.shard_write_retries").inc();
                let slept = backoff.sleep(attempt);
                obs::registry()
                    .histogram("coordinator.backoff_secs")
                    .record(slept as f64 / 1e3);
                log::warn!(
                    "shard write for partition {part_id} failed (attempt {attempt}): \
                     {e}; retried after {slept}ms"
                );
            }
            Err(e) => return Err(e),
        }
    }
}

/// The leader. Owns the job queue and the result channel.
pub struct Coordinator {
    cfg: CoordinatorConfig,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Self {
        Coordinator { cfg }
    }

    /// Run distributed training over a [`PartitionReport`], logging the
    /// partitioning stage timings and carrying them into the
    /// [`TrainReport`].
    pub fn run_report(
        &self,
        dataset: &Dataset,
        partition: &PartitionReport,
    ) -> Result<TrainReport> {
        // Progress chatter goes to the trace as structured events (the
        // pipeline already recorded the stage spans themselves) and to the
        // logger only at debug level — quiet runs stay quiet.
        for st in &partition.stages {
            obs::event(
                "coordinator",
                "partition.stage",
                vec![("secs", num(st.secs)), ("parts", num(st.parts as f64))],
            );
            log::debug!(
                "partition stage {}: {:.1}ms → {} parts",
                st.name,
                st.secs * 1e3,
                st.parts
            );
        }
        let mut report = self.run(dataset, &partition.partitioning)?;
        report.partition_stages = partition.stages.clone();
        Ok(report)
    }

    /// Run distributed training of `dataset` over `partitioning`.
    pub fn run(&self, dataset: &Dataset, partitioning: &Partitioning) -> Result<TrainReport> {
        let sw = Stopwatch::start();
        let mut run_span = obs::span("coordinator", "run");
        if obs::tracing_enabled() {
            run_span.attr("k", num(partitioning.k() as f64));
            run_span.attr("nodes", num(dataset.num_nodes() as f64));
            run_span.attr("machines", num(self.cfg.machines as f64));
        }
        if self.cfg.resume && self.cfg.shard_dir.is_none() {
            return Err(Error::Config(
                "--resume requires a shard directory (--shards): the journal and the \
                 completed shards live there"
                    .into(),
            ));
        }
        let k = partitioning.k();
        let members = partitioning.members();
        let fingerprint = RunJournal::fingerprint(
            &dataset.name,
            dataset.num_nodes(),
            &members,
            self.cfg.seed,
            self.cfg.epochs,
            self.cfg.mlp_epochs,
            self.cfg.mode.as_str(),
            self.cfg.model.as_str(),
            self.cfg.exec.as_str(),
        );

        let mut store: Option<EmbeddingStore> = None;
        let mut stats: Vec<PartitionStats> = Vec::with_capacity(k);
        // finished or permanently skipped, by part_id — late duplicate
        // results for a resolved partition are ignored
        let mut resolved = vec![false; k];
        let mut attempts = vec![0u32; k];

        // ---- journal: create fresh, or replay for --resume --------------
        let mut journal: Option<RunJournal> = None;
        if let Some(dir) = &self.cfg.shard_dir {
            std::fs::create_dir_all(dir)?;
            // The live manifest is deliberately left in place while this
            // run writes shards: a server watching the directory keeps
            // serving the published version and only flips once the new
            // manifest is atomically published (temp + fsync + rename)
            // at the end of a fully successful run. Mixed-bundle safety
            // no longer needs a delete-first step — every manifest entry
            // carries the shard's sha256, so a shard left by a
            // different-config crash fails its digest check at load and
            // is quarantined instead of silently served.
            let prior = if self.cfg.resume { RunJournal::load(dir)? } else { None };
            match prior {
                Some(state) => {
                    if state.fingerprint != fingerprint {
                        return Err(Error::Coordinator(format!(
                            "cannot resume: journal fingerprint {:016x} does not match \
                             this run ({fingerprint:016x}) — dataset, partitioning, seed, \
                             or training config changed",
                            state.fingerprint
                        )));
                    }
                    let mut resumed = 0usize;
                    for rec in &state.parts {
                        let p = rec.part_id as usize;
                        if p >= k {
                            return Err(Error::Coordinator(format!(
                                "cannot resume: journal records partition {} but the \
                                 run has k = {k}",
                                rec.part_id
                            )));
                        }
                        let path = dir.join(crate::serve::shard_file_name(rec.part_id));
                        // full read: the LFS1 section checksums re-verify
                        // every byte before the rows are trusted
                        let verified = match crate::serve::read_shard(&path) {
                            Ok((header, data))
                                if header.part_id == rec.part_id
                                    && header.rows == rec.rows =>
                            {
                                Some((header, data))
                            }
                            Ok(_) => {
                                log::warn!(
                                    "--resume: shard {} does not match its journal \
                                     record; partition {} will retrain",
                                    path.display(),
                                    rec.part_id
                                );
                                None
                            }
                            Err(e) => {
                                log::warn!(
                                    "--resume: cannot verify shard {} ({e}); \
                                     partition {} will retrain",
                                    path.display(),
                                    rec.part_id
                                );
                                None
                            }
                        };
                        let Some((header, data)) = verified else { continue };
                        let st = store.get_or_insert_with(|| {
                            EmbeddingStore::new(dataset.num_nodes(), header.dim)
                        });
                        if header.dim != st.dim {
                            log::warn!(
                                "--resume: shard {} has dim {} (expected {}); \
                                 partition {} will retrain",
                                path.display(),
                                header.dim,
                                st.dim,
                                rec.part_id
                            );
                            continue;
                        }
                        st.insert(&header.nodes, &data)?;
                        stats.push(PartitionStats {
                            part_id: rec.part_id,
                            num_nodes: rec.rows,
                            num_replicas: rec.num_replicas,
                            losses: Vec::new(),
                            train_secs: rec.train_secs,
                            attempts: rec.attempts,
                        });
                        resolved[p] = true;
                        resumed += 1;
                    }
                    obs::registry()
                        .counter("resume.partitions_skipped")
                        .add(resumed as u64);
                    obs::event(
                        "coordinator",
                        "resume",
                        vec![("skipped", num(resumed as f64)), ("k", num(k as f64))],
                    );
                    log::info!(
                        "--resume: {resumed} partition(s) intact in the journal; \
                         retraining the rest"
                    );
                    journal = Some(RunJournal::reopen(dir));
                }
                None => {
                    if self.cfg.resume {
                        log::warn!(
                            "--resume: no journal at {}; running from scratch",
                            dir.display()
                        );
                    }
                    journal = Some(RunJournal::create(dir, fingerprint, &dataset.name, k)?);
                }
            }
        }

        // ---- dispatch the unresolved partitions -------------------------
        let jobs: Vec<Job> = members
            .iter()
            .enumerate()
            .filter(|(p, m)| !m.is_empty() && !resolved[*p])
            .map(|(part_id, m)| Job {
                part_id: part_id as u32,
                members: m.clone(),
                attempt: 0,
            })
            .collect();
        let live_jobs = jobs.len();
        let mut skipped: Vec<u32> = Vec::new();

        if live_jobs > 0 {
            let workers = match &self.cfg.transport {
                // remote sessions are real processes: keep every
                // configured slot open even when jobs < machines
                Transport::Local => self.cfg.machines.min(live_jobs).max(1),
                Transport::Tcp(_) => self.cfg.machines.max(1),
            };
            let queue = Arc::new(JobQueue::new(jobs, workers));
            let (tx, rx) = mpsc::channel::<WorkerEvent>();
            // per-partition retry backoff, seeded so a rerun schedules
            // the same jitter (splitmix decorrelates adjacent parts)
            let mut backoffs: Vec<Backoff> = (0..k)
                .map(|p| {
                    Backoff::new(
                        self.cfg.seed ^ (p as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    )
                })
                .collect();
            let mut shard_backoff = Backoff::new(self.cfg.seed ^ 0x5AD0);
            // (worker, started-at) per in-flight partition, for the
            // deadline watchdog and stale-event attribution
            let mut running: Vec<Option<(usize, f64)>> = vec![None; k];
            let mut suspect = vec![0u32; workers];
            let mut retired = vec![false; workers];
            let mut live_workers = workers;
            let clock = Stopwatch::start();
            // results accepted at most once per (part, attempt): a slow or
            // resurrected worker re-delivering after a requeue is dropped
            let mut accepted: BTreeSet<(u32, u32)> = BTreeSet::new();

            // lint: allow(spawn_outside_parallel) — leader/worker topology over an mpsc channel with retries, not the ordered fork-join map util::parallel models
            let run_result = std::thread::scope(|scope| -> Result<()> {
                // the event loop below is transport-blind: local threads
                // and TCP sessions feed the same WorkerEvent channel
                let mut server = None;
                match &self.cfg.transport {
                    Transport::Local => {
                        let q = &queue;
                        for wid in 0..workers {
                            let tx = tx.clone();
                            let cfg = self.cfg.clone();
                            scope.spawn(move || worker::worker_loop(wid, dataset, q, tx, &cfg));
                        }
                    }
                    Transport::Tcp(net) => {
                        server = Some(crate::net::TcpServer::start(
                            net,
                            self.cfg.seed,
                            fingerprint,
                            workers,
                            Arc::clone(&queue),
                            tx.clone(),
                        )?);
                    }
                }
                drop(tx);

                // every exit path must shut the queue down, or idle
                // workers would block the scope join forever
                let r = (|| -> Result<()> {
                    let mut completed = 0usize;
                    while completed < live_jobs {
                        let event = if self.cfg.deadline_secs > 0.0 {
                            match rx.recv_timeout(Duration::from_millis(WATCHDOG_TICK_MS)) {
                                Ok(ev) => Some(ev),
                                Err(mpsc::RecvTimeoutError::Timeout) => None,
                                Err(mpsc::RecvTimeoutError::Disconnected) => {
                                    return Err(Error::Coordinator(
                                        "all workers exited before completion".into(),
                                    ))
                                }
                            }
                        } else {
                            Some(rx.recv().map_err(|_| {
                                Error::Coordinator(
                                    "all workers exited before completion".into(),
                                )
                            })?)
                        };

                        let Some(event) = event else {
                            // ---- deadline watchdog tick ----------------
                            let now = clock.secs();
                            for part in 0..k {
                                let Some((w, started)) = running[part] else { continue };
                                if now - started <= self.cfg.deadline_secs {
                                    continue;
                                }
                                running[part] = None;
                                obs::registry().counter("coordinator.deadline_kills").inc();
                                obs::event(
                                    "coordinator",
                                    "deadline.expired",
                                    vec![
                                        ("part", num(part as f64)),
                                        ("worker", num(w as f64)),
                                        ("secs", num(now - started)),
                                    ],
                                );
                                suspect[w] += 1;
                                log::warn!(
                                    "partition {part} exceeded the {:.1}s deadline on \
                                     worker {w} (expiry {} for this worker)",
                                    self.cfg.deadline_secs,
                                    suspect[w]
                                );
                                if suspect[w] >= SUSPECT_RETIRE_THRESHOLD && !retired[w] {
                                    retired[w] = true;
                                    live_workers -= 1;
                                    queue.retire_worker(w);
                                    obs::registry()
                                        .counter("coordinator.workers_retired")
                                        .inc();
                                    log::warn!(
                                        "worker {w} exceeded the deadline {} times; retired",
                                        suspect[w]
                                    );
                                }
                                attempts[part] += 1;
                                // an expiry is transient by definition —
                                // the same partition may finish in time
                                // on a healthy worker
                                match handle_failure(
                                    &self.cfg,
                                    &queue,
                                    &members,
                                    &mut backoffs[part],
                                    part as u32,
                                    attempts[part],
                                    true,
                                    "deadline expired",
                                ) {
                                    Verdict::Requeued => {}
                                    Verdict::Skipped => {
                                        resolved[part] = true;
                                        skipped.push(part as u32);
                                        completed += 1;
                                        queue.resolve_job();
                                    }
                                    Verdict::Abort(msg) => {
                                        return Err(Error::Coordinator(msg))
                                    }
                                }
                            }
                            if live_workers == 0 && completed < live_jobs {
                                return Err(Error::Coordinator(
                                    "all workers retired before completion".into(),
                                ));
                            }
                            continue;
                        };

                        match event {
                            WorkerEvent::Started { worker, part_id } => {
                                log::debug!("worker {worker} started partition {part_id}");
                                let p = part_id as usize;
                                if !resolved[p] {
                                    running[p] = Some((worker, clock.secs()));
                                }
                            }
                            WorkerEvent::Finished { worker, part_id, attempt, nodes, result } => {
                                let p = part_id as usize;
                                if p >= k {
                                    // remote peers are the only source of
                                    // out-of-range ids; never index on one
                                    log::warn!(
                                        "ignoring result for unknown partition \
                                         {part_id} from worker {worker}"
                                    );
                                    continue;
                                }
                                if !accepted.insert((part_id, attempt)) || resolved[p] {
                                    // duplicate attempt (deadline expiry or a
                                    // reconnect requeued it, both delivered)
                                    log::debug!(
                                        "ignoring duplicate result for partition \
                                         {part_id} (attempt {attempt}) from worker {worker}"
                                    );
                                    continue;
                                }
                                running[p] = None;
                                obs::event(
                                    "coordinator",
                                    "partition.finished",
                                    vec![
                                        ("worker", num(worker as f64)),
                                        ("part", num(part_id as f64)),
                                        ("nodes", num(nodes.len() as f64)),
                                        ("train_secs", num(result.train_secs)),
                                    ],
                                );
                                obs::registry()
                                    .counter("coordinator.partitions_trained")
                                    .inc();
                                log::debug!(
                                    "worker {worker} finished partition {part_id}: \
                                     {} nodes, final loss {:.4}, {:.2}s",
                                    nodes.len(),
                                    result.losses.last().copied().unwrap_or(f32::NAN),
                                    result.train_secs
                                );
                                let st = store.get_or_insert_with(|| {
                                    EmbeddingStore::new(dataset.num_nodes(), result.emb_dim)
                                });
                                st.insert(&nodes, &result.embeddings)?;
                                let tries = attempts[p] + 1;
                                // shard-per-partition export: write while
                                // the rest of the cluster is still training
                                if let Some(dir) = &self.cfg.shard_dir {
                                    write_shard_with_retry(
                                        &dir.join(crate::serve::shard_file_name(part_id)),
                                        part_id,
                                        &nodes,
                                        &result.embeddings,
                                        result.emb_dim,
                                        &mut shard_backoff,
                                    )?;
                                }
                                // journal only after the shard is durable
                                if let Some(j) = &journal {
                                    j.append_partition(&PartRecord {
                                        part_id,
                                        rows: nodes.len(),
                                        attempts: tries,
                                        train_secs: result.train_secs,
                                        num_replicas: result.num_replicas,
                                    })?;
                                }
                                stats.push(PartitionStats {
                                    part_id,
                                    num_nodes: nodes.len(),
                                    num_replicas: result.num_replicas,
                                    losses: result.losses,
                                    train_secs: result.train_secs,
                                    attempts: tries,
                                });
                                resolved[p] = true;
                                completed += 1;
                                queue.resolve_job();
                            }
                            WorkerEvent::Failed { worker, part_id, code, message } => {
                                let p = part_id as usize;
                                if p >= k {
                                    log::warn!(
                                        "ignoring failure for unknown partition \
                                         {part_id} from worker {worker}: {message}"
                                    );
                                    continue;
                                }
                                if resolved[p] {
                                    log::debug!(
                                        "ignoring stale failure for resolved partition \
                                         {part_id}: {message}"
                                    );
                                    continue;
                                }
                                // only the attempt we believe is running
                                // may fail; anything else is a late echo
                                // of a deadline-expired attempt that was
                                // already counted and requeued
                                match running[p] {
                                    Some((w, _)) if w == worker => running[p] = None,
                                    _ => {
                                        log::debug!(
                                            "ignoring failure from expired attempt on \
                                             partition {part_id} (worker {worker}): {message}"
                                        );
                                        continue;
                                    }
                                }
                                attempts[p] += 1;
                                match handle_failure(
                                    &self.cfg,
                                    &queue,
                                    &members,
                                    &mut backoffs[p],
                                    part_id,
                                    attempts[p],
                                    code.is_transient(),
                                    &message,
                                ) {
                                    Verdict::Requeued => {}
                                    Verdict::Skipped => {
                                        resolved[p] = true;
                                        skipped.push(part_id);
                                        completed += 1;
                                        queue.resolve_job();
                                    }
                                    Verdict::Abort(msg) => {
                                        return Err(Error::Coordinator(msg))
                                    }
                                }
                            }
                            WorkerEvent::Retired { worker, error } => {
                                if worker < retired.len() && !retired[worker] {
                                    retired[worker] = true;
                                    live_workers -= 1;
                                    queue.retire_worker(worker);
                                    obs::registry()
                                        .counter("coordinator.workers_retired")
                                        .inc();
                                    obs::event(
                                        "coordinator",
                                        "worker.retired",
                                        vec![("worker", num(worker as f64))],
                                    );
                                    log::error!("worker {worker} retired: {error}");
                                }
                                if live_workers == 0 && completed < live_jobs {
                                    return Err(Error::Coordinator(format!(
                                        "all workers retired before completion \
                                         (last: {error})"
                                    )));
                                }
                            }
                        }
                    }
                    Ok(())
                })();
                queue.shutdown();
                if let Some(server) = server {
                    // sessions see the closed queue, drain their workers
                    // (Shutdown → Bye), and are joined here
                    server.drain();
                }
                r
            });
            run_result?;
        }

        let store = store
            .ok_or_else(|| Error::Coordinator("no partitions produced output".into()))?;

        // ---- coverage accounting ----------------------------------------
        let covered: usize = stats.iter().map(|s| s.num_nodes).sum();
        let coverage = if dataset.num_nodes() == 0 {
            1.0
        } else {
            covered as f64 / dataset.num_nodes() as f64
        };
        obs::registry().gauge("coordinator.coverage").set(coverage);
        skipped.sort_unstable();
        if !skipped.is_empty() {
            log::warn!(
                "run degraded: {} partition(s) skipped, coverage {coverage:.3}",
                skipped.len()
            );
        }

        // ---- integration + evaluation on the leader ---------------------
        // With holes, train and evaluate over the survivors only: nodes of
        // skipped partitions leave every split mask (their embedding rows
        // are zeros — including them would silently poison the classifier
        // and the reported metrics).
        let masked;
        let eval_ds: &Dataset = if skipped.is_empty() {
            dataset
        } else {
            let mut d = dataset.clone();
            for &pid in &skipped {
                for &v in &members[pid as usize] {
                    let vi = v as usize;
                    d.train_mask[vi] = false;
                    d.val_mask[vi] = false;
                    d.test_mask[vi] = false;
                }
            }
            masked = d;
            &masked
        };
        let leader_rt = Runtime::new(&self.cfg.artifacts_dir)?;
        // preflight the pred artifact so a train-only manifest fails here,
        // not after the full MLP training loop (compilation is cached for
        // the evaluation pass)
        leader_rt.load_for("mlp", dataset.labels.task_name(), "pred", store.n, 0)?;
        let clf = {
            let _sp = obs::span("coordinator", "integrate");
            train_classifier_path(
                &leader_rt,
                eval_ds,
                &store,
                self.cfg.mlp_epochs,
                self.cfg.seed ^ 0x11,
                self.cfg.exec,
            )?
        };
        let eval = {
            let _sp = obs::span("coordinator", "evaluate");
            evaluate_classifier(&leader_rt, eval_ds, &store, &clf)?
        };

        stats.sort_by_key(|s| s.part_id);

        // ---- finalize the serving bundle --------------------------------
        if let Some(dir) = &self.cfg.shard_dir {
            checkpoint::save_tensors(&dir.join(crate::serve::CLASSIFIER_FILE), &clf.params)?;
            // bump past whatever is currently published so a watching
            // server sees a strictly newer version and hot-swaps to it
            let version = crate::serve::bundle::live_version(dir) + 1;
            let mut manifest = crate::serve::ShardManifest {
                version,
                dataset: dataset.name.clone(),
                task: clf.task.to_string(),
                num_nodes: covered,
                dim: store.dim,
                classes: clf.classes,
                classifier_file: crate::serve::CLASSIFIER_FILE.to_string(),
                classifier_sha256: String::new(),
                shards: stats
                    .iter()
                    .map(|s| crate::serve::ShardEntry {
                        file: crate::serve::shard_file_name(s.part_id),
                        part_id: s.part_id,
                        rows: s.num_nodes,
                        sha256: String::new(),
                    })
                    .collect(),
            };
            crate::serve::bundle::stamp_digests(dir, &mut manifest)?;
            crate::serve::bundle::publish(dir, &manifest)?;
            obs::event(
                "coordinator",
                "bundle.written",
                vec![
                    ("version", num(manifest.version as f64)),
                    ("shards", num(manifest.shards.len() as f64)),
                    ("nodes", num(manifest.num_nodes as f64)),
                ],
            );
            log::debug!(
                "serving bundle v{} published to {} ({} shards, {} nodes, dim {})",
                manifest.version,
                dir.display(),
                manifest.shards.len(),
                manifest.num_nodes,
                manifest.dim
            );
        }

        let max_partition_train_secs = stats
            .iter()
            .map(|s| s.train_secs)
            .fold(0.0f64, f64::max);
        let total_train_secs = stats.iter().map(|s| s.train_secs).sum();
        Ok(TrainReport {
            per_partition: stats,
            eval,
            partition_stages: Vec::new(),
            wall_secs: sw.secs(),
            max_partition_train_secs,
            total_train_secs,
            coverage,
            skipped_partitions: skipped,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::karate_dataset;
    use crate::partition::leiden::leiden_fusion;
    use crate::testing::artifacts_if_built;

    fn cfg_if_built() -> Option<CoordinatorConfig> {
        let mut c = CoordinatorConfig::new(artifacts_if_built()?);
        c.epochs = 10;
        c.mlp_epochs = 30;
        c.machines = 2;
        Some(c)
    }

    #[test]
    fn failure_policy_parses() {
        assert_eq!(FailurePolicy::parse("abort").unwrap(), FailurePolicy::Abort);
        assert_eq!(FailurePolicy::parse("skip").unwrap(), FailurePolicy::Skip);
        assert!(FailurePolicy::parse("retry").is_err());
        assert_eq!(FailurePolicy::Skip.as_str(), "skip");
    }

    #[test]
    fn resume_requires_shard_dir() {
        let mut cfg = CoordinatorConfig::new(PathBuf::from("/nonexistent_artifacts"));
        cfg.resume = true;
        let ds = karate_dataset(5);
        let p = leiden_fusion(&ds.graph, 2, 0.05, 0.5, 1).unwrap();
        let err = Coordinator::new(cfg).run(&ds, &p).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }

    #[test]
    fn end_to_end_karate_two_partitions() {
        let Some(cfg) = cfg_if_built() else { return };
        let ds = karate_dataset(5);
        let p = leiden_fusion(&ds.graph, 2, 0.05, 0.5, 1).unwrap();
        let report = Coordinator::new(cfg).run(&ds, &p).unwrap();
        assert_eq!(report.per_partition.len(), 2);
        assert!(report.eval.test_metric >= 0.0);
        assert!(report.max_partition_train_secs > 0.0);
        assert!(report.total_train_secs >= report.max_partition_train_secs);
        assert_eq!(report.coverage, 1.0);
        assert!(report.skipped_partitions.is_empty());
    }

    #[test]
    fn run_report_carries_partition_stage_timings() {
        let Some(cfg) = cfg_if_built() else { return };
        let ds = karate_dataset(5);
        let preport = crate::partition::PartitionPipeline::parse("lf", 1)
            .unwrap()
            .run(&ds.graph, 2)
            .unwrap();
        let report = Coordinator::new(cfg).run_report(&ds, &preport).unwrap();
        let names: Vec<&str> = report
            .partition_stages
            .iter()
            .map(|s| s.name.as_str())
            .collect();
        assert_eq!(names, vec!["leiden", "fusion", "validate"]);
    }

    #[test]
    fn session_and_reference_exec_agree_end_to_end() {
        // Same seeds, same partitioning: the device-resident session and
        // the host round-trip must land on identical metrics (the session
        // is bit-exact per step, so the whole pipeline agrees).
        let Some(cfg) = cfg_if_built() else { return };
        let ds = karate_dataset(5);
        let p = leiden_fusion(&ds.graph, 2, 0.05, 0.5, 1).unwrap();
        let mut ref_cfg = cfg.clone();
        ref_cfg.exec = ExecPath::Reference;
        ref_cfg.machines = 1;
        let mut ses_cfg = cfg;
        ses_cfg.machines = 1;
        let a = Coordinator::new(ses_cfg).run(&ds, &p).unwrap();
        let b = Coordinator::new(ref_cfg).run(&ds, &p).unwrap();
        assert_eq!(a.eval.test_metric, b.eval.test_metric);
        assert_eq!(a.eval.val_metric, b.eval.val_metric);
        for (x, y) in a.eval.mlp_losses.iter().zip(&b.eval.mlp_losses) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn writes_serving_bundle_when_shard_dir_set() {
        let Some(mut cfg) = cfg_if_built() else { return };
        let dir = std::env::temp_dir().join(format!("lf_bundle_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        cfg.shard_dir = Some(dir.clone());
        let ds = karate_dataset(5);
        let p = leiden_fusion(&ds.graph, 2, 0.05, 0.5, 1).unwrap();
        let report = Coordinator::new(cfg).run(&ds, &p).unwrap();
        let store = crate::serve::ShardedEmbeddingStore::open(&dir).unwrap();
        assert_eq!(store.num_nodes(), ds.num_nodes());
        assert_eq!(store.num_shards(), report.per_partition.len());
        assert!(dir.join(crate::serve::CLASSIFIER_FILE).exists());
        // shard rows must be the exact embeddings the store assembled
        for s in &report.per_partition {
            let (header, _) = crate::serve::read_shard(
                &dir.join(crate::serve::shard_file_name(s.part_id)),
            )
            .unwrap();
            assert_eq!(header.rows, s.num_nodes);
        }
        // the run journal records every partition
        let state = RunJournal::load(&dir).unwrap().expect("journal written");
        assert_eq!(state.parts.len(), report.per_partition.len());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn resume_retrains_only_missing_partitions() {
        let Some(mut cfg) = cfg_if_built() else { return };
        let dir = std::env::temp_dir().join(format!("lf_resume_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        cfg.shard_dir = Some(dir.clone());
        let ds = karate_dataset(5);
        let p = leiden_fusion(&ds.graph, 2, 0.05, 0.5, 1).unwrap();
        let first = Coordinator::new(cfg.clone()).run(&ds, &p).unwrap();

        // simulate a mid-run kill: partition 0's shard never landed
        std::fs::remove_file(dir.join(crate::serve::shard_file_name(0))).unwrap();
        cfg.resume = true;
        let second = Coordinator::new(cfg).run(&ds, &p).unwrap();

        assert_eq!(second.per_partition.len(), first.per_partition.len());
        // partition 1 was replayed from its journaled shard (no losses
        // retained), partition 0 retrained from scratch
        let p0 = second.per_partition.iter().find(|s| s.part_id == 0).unwrap();
        let p1 = second.per_partition.iter().find(|s| s.part_id == 1).unwrap();
        assert!(!p0.losses.is_empty(), "partition 0 must retrain");
        assert!(p1.losses.is_empty(), "partition 1 must replay from the journal");
        // identical embeddings in, identical metrics out — bit-exact
        assert_eq!(
            first.eval.test_metric.to_bits(),
            second.eval.test_metric.to_bits()
        );
        assert_eq!(second.coverage, 1.0);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn resume_rejects_fingerprint_mismatch() {
        let Some(mut cfg) = cfg_if_built() else { return };
        let dir = std::env::temp_dir().join(format!("lf_resume_fp_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        cfg.shard_dir = Some(dir.clone());
        let ds = karate_dataset(5);
        let p = leiden_fusion(&ds.graph, 2, 0.05, 0.5, 1).unwrap();
        Coordinator::new(cfg.clone()).run(&ds, &p).unwrap();

        cfg.resume = true;
        cfg.seed ^= 1; // different run → different embeddings → refuse
        let err = Coordinator::new(cfg).run(&ds, &p).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }
}
