//! Append-only run journal: crash recovery for distributed training.
//!
//! The coordinator writes `journal.jsonl` next to the shard directory —
//! one header line identifying the run, then one line per partition the
//! moment its shard hits disk. A killed run leaves the journal and the
//! already-written shards behind; `repro train --resume` replays them:
//! the fingerprint is validated (same graph, partitioning, seed, and
//! training config — resuming under a different config would silently
//! mix incompatible embeddings), each journaled shard is re-read and
//! verified via its `LFS1` checksums, and only the missing or damaged
//! partitions are retrained.
//!
//! Format (JSONL, one object per line):
//!
//! ```text
//! {"kind":"run","version":1,"fingerprint":"<16 hex>", "dataset":"...","k":N}
//! {"kind":"part","part_id":0,"rows":18,"attempts":1,"train_secs":1.25,"num_replicas":0}
//! ```
//!
//! The tail line of a killed run may be torn; the loader tolerates a
//! single unparseable *final* line (garbage anywhere else is an error —
//! it means something other than a mid-write crash damaged the file).

use crate::error::{Error, Result};
use crate::util::json::{num, obj, s, Json};
use crate::util::Fnv64;
use std::io::Write;
use std::path::{Path, PathBuf};

pub const JOURNAL_FILE: &str = "journal.jsonl";

/// One completed partition, as recorded in the journal.
#[derive(Clone, Debug, PartialEq)]
pub struct PartRecord {
    pub part_id: u32,
    pub rows: usize,
    pub attempts: u32,
    pub train_secs: f64,
    pub num_replicas: usize,
}

/// Journal contents after a (possibly interrupted) run.
#[derive(Clone, Debug)]
pub struct JournalState {
    pub fingerprint: u64,
    /// Completed partitions, deduplicated by `part_id` (last write wins —
    /// a partition retrained after a damaged-shard resume appears twice).
    pub parts: Vec<PartRecord>,
}

/// Writer handle for the current run's journal.
pub struct RunJournal {
    path: PathBuf,
}

impl RunJournal {
    pub fn path_in(dir: &Path) -> PathBuf {
        dir.join(JOURNAL_FILE)
    }

    /// Fingerprint of everything that determines the run's output:
    /// dataset identity, the exact partition membership, and every
    /// training knob. Two runs agree on the fingerprint iff their
    /// completed shards are interchangeable.
    pub fn fingerprint(
        dataset_name: &str,
        num_nodes: usize,
        members: &[Vec<crate::graph::NodeId>],
        seed: u64,
        epochs: usize,
        mlp_epochs: usize,
        mode: &str,
        model: &str,
        exec: &str,
    ) -> u64 {
        let mut h = Fnv64::new();
        h.write(dataset_name.as_bytes());
        h.write(&[0]);
        h.write_u64(num_nodes as u64);
        h.write_u64(members.len() as u64);
        for (part, m) in members.iter().enumerate() {
            h.write_u64(part as u64);
            h.write_u64(m.len() as u64);
            for &v in m {
                h.write(&v.to_le_bytes());
            }
        }
        h.write_u64(seed);
        h.write_u64(epochs as u64);
        h.write_u64(mlp_epochs as u64);
        h.write(mode.as_bytes());
        h.write(&[0]);
        h.write(model.as_bytes());
        h.write(&[0]);
        h.write(exec.as_bytes());
        h.finish()
    }

    /// Start a fresh journal (truncates any previous one) with the run
    /// header line.
    pub fn create(dir: &Path, fingerprint: u64, dataset: &str, k: usize) -> Result<RunJournal> {
        std::fs::create_dir_all(dir)?;
        let path = Self::path_in(dir);
        let header = obj(vec![
            ("kind", s("run")),
            ("version", num(1.0)),
            ("fingerprint", s(&format!("{fingerprint:016x}"))),
            ("dataset", s(dataset)),
            ("k", num(k as f64)),
        ]);
        let mut text = header.to_string();
        text.push('\n');
        std::fs::write(&path, text)?;
        Ok(RunJournal { path })
    }

    /// Reopen an existing journal for appending (resume path). The caller
    /// has already validated the fingerprint via [`RunJournal::load`].
    pub fn reopen(dir: &Path) -> RunJournal {
        RunJournal { path: Self::path_in(dir) }
    }

    /// Record one completed partition. Append + flush so a kill after
    /// this call never loses the line.
    pub fn append_partition(&self, rec: &PartRecord) -> Result<()> {
        let line = obj(vec![
            ("kind", s("part")),
            ("part_id", num(rec.part_id as f64)),
            ("rows", num(rec.rows as f64)),
            ("attempts", num(rec.attempts as f64)),
            ("train_secs", num(rec.train_secs)),
            ("num_replicas", num(rec.num_replicas as f64)),
        ]);
        let mut text = line.to_string();
        text.push('\n');
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .create(true)
            .open(&self.path)?;
        f.write_all(text.as_bytes())?;
        f.flush()?;
        Ok(())
    }

    /// Load a journal: `Ok(None)` if the file doesn't exist, an error if
    /// it exists but is unusable (bad header, garbage before the last
    /// line), `Ok(Some(state))` otherwise. A torn final line — the
    /// signature of a mid-write kill — is dropped silently.
    pub fn load(dir: &Path) -> Result<Option<JournalState>> {
        let path = Self::path_in(dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        let Some(first) = lines.first() else {
            return Err(Error::Coordinator(format!(
                "{}: journal is empty",
                path.display()
            )));
        };
        let header = Json::parse(first).map_err(|e| {
            Error::Coordinator(format!("{}: bad journal header: {e}", path.display()))
        })?;
        if header.get("kind").and_then(Json::as_str) != Some("run") {
            return Err(Error::Coordinator(format!(
                "{}: journal does not start with a run header",
                path.display()
            )));
        }
        let fingerprint = header
            .get("fingerprint")
            .and_then(Json::as_str)
            .and_then(|hex| u64::from_str_radix(hex, 16).ok())
            .ok_or_else(|| {
                Error::Coordinator(format!(
                    "{}: journal header missing fingerprint",
                    path.display()
                ))
            })?;
        let mut parts: Vec<PartRecord> = Vec::new();
        for (i, line) in lines.iter().enumerate().skip(1) {
            let last = i + 1 == lines.len();
            let rec = Json::parse(line).ok().and_then(|j| {
                if j.get("kind").and_then(Json::as_str) != Some("part") {
                    return None;
                }
                Some(PartRecord {
                    part_id: j.get("part_id").and_then(Json::as_usize)? as u32,
                    rows: j.get("rows").and_then(Json::as_usize)?,
                    attempts: j.get("attempts").and_then(Json::as_usize)? as u32,
                    train_secs: j.get("train_secs").and_then(Json::as_f64)?,
                    num_replicas: j.get("num_replicas").and_then(Json::as_usize)?,
                })
            });
            match rec {
                Some(r) => {
                    // last write wins: a partition retrained after a
                    // damaged-shard resume is listed twice
                    parts.retain(|p| p.part_id != r.part_id);
                    parts.push(r);
                }
                None if last => {
                    log::warn!(
                        "{}: dropping torn final journal line (mid-write kill)",
                        path.display()
                    );
                }
                None => {
                    return Err(Error::Coordinator(format!(
                        "{}: journal line {} is corrupt",
                        path.display(),
                        i + 1
                    )));
                }
            }
        }
        Ok(Some(JournalState { fingerprint, parts }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("lf_journal_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn rec(part_id: u32, rows: usize) -> PartRecord {
        PartRecord { part_id, rows, attempts: 1, train_secs: 0.5, num_replicas: 0 }
    }

    #[test]
    fn roundtrip_header_and_parts() {
        let dir = tmp("roundtrip");
        let j = RunJournal::create(&dir, 0xABCD, "karate", 2).unwrap();
        j.append_partition(&rec(0, 18)).unwrap();
        j.append_partition(&rec(1, 16)).unwrap();
        let state = RunJournal::load(&dir).unwrap().unwrap();
        assert_eq!(state.fingerprint, 0xABCD);
        assert_eq!(state.parts, vec![rec(0, 18), rec(1, 16)]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_journal_is_none() {
        let dir = tmp("missing");
        assert!(RunJournal::load(&dir).unwrap().is_none());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn torn_final_line_is_dropped() {
        let dir = tmp("torn");
        let j = RunJournal::create(&dir, 7, "karate", 2).unwrap();
        j.append_partition(&rec(0, 18)).unwrap();
        // simulate a kill mid-append: half a JSON object, no newline
        let path = RunJournal::path_in(&dir);
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"kind\":\"part\",\"part_id\":1,\"ro");
        std::fs::write(&path, text).unwrap();
        let state = RunJournal::load(&dir).unwrap().unwrap();
        assert_eq!(state.parts, vec![rec(0, 18)], "torn tail dropped, prefix kept");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn garbage_before_the_tail_is_an_error() {
        let dir = tmp("garbage");
        let j = RunJournal::create(&dir, 7, "karate", 2).unwrap();
        j.append_partition(&rec(0, 18)).unwrap();
        let path = RunJournal::path_in(&dir);
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("not json at all\n");
        std::fs::write(&path, text).unwrap();
        let j2 = RunJournal::reopen(&dir);
        j2.append_partition(&rec(1, 16)).unwrap();
        assert!(RunJournal::load(&dir).is_err(), "mid-file garbage must not be silent");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn duplicate_part_lines_keep_the_last_write() {
        let dir = tmp("dup");
        let j = RunJournal::create(&dir, 7, "karate", 2).unwrap();
        j.append_partition(&rec(0, 18)).unwrap();
        let mut retrained = rec(0, 18);
        retrained.attempts = 3;
        j.append_partition(&retrained).unwrap();
        let state = RunJournal::load(&dir).unwrap().unwrap();
        assert_eq!(state.parts, vec![retrained]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn bad_header_is_an_error() {
        let dir = tmp("badheader");
        std::fs::write(RunJournal::path_in(&dir), "{\"kind\":\"part\"}\n").unwrap();
        assert!(RunJournal::load(&dir).is_err());
        std::fs::write(RunJournal::path_in(&dir), "").unwrap();
        assert!(RunJournal::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fingerprint_is_sensitive_to_each_input() {
        let members = vec![vec![0, 1], vec![2, 3]];
        let base = RunJournal::fingerprint(
            "karate", 4, &members, 42, 10, 30, "inner", "gcn", "session",
        );
        assert_eq!(
            base,
            RunJournal::fingerprint(
                "karate", 4, &members, 42, 10, 30, "inner", "gcn", "session",
            ),
            "fingerprint must be deterministic"
        );
        let other_members = vec![vec![0, 1, 2], vec![3]];
        for different in [
            RunJournal::fingerprint("karate2", 4, &members, 42, 10, 30, "inner", "gcn", "session"),
            RunJournal::fingerprint("karate", 5, &members, 42, 10, 30, "inner", "gcn", "session"),
            RunJournal::fingerprint("karate", 4, &other_members, 42, 10, 30, "inner", "gcn", "session"),
            RunJournal::fingerprint("karate", 4, &members, 43, 10, 30, "inner", "gcn", "session"),
            RunJournal::fingerprint("karate", 4, &members, 42, 11, 30, "inner", "gcn", "session"),
            RunJournal::fingerprint("karate", 4, &members, 42, 10, 31, "inner", "gcn", "session"),
            RunJournal::fingerprint("karate", 4, &members, 42, 10, 30, "repli", "gcn", "session"),
            RunJournal::fingerprint("karate", 4, &members, 42, 10, 30, "inner", "sage", "session"),
            RunJournal::fingerprint("karate", 4, &members, 42, 10, 30, "inner", "gcn", "reference"),
        ] {
            assert_ne!(base, different);
        }
    }
}
