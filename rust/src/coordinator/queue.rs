//! Condvar-backed job queue shared by the leader and machine workers.
//!
//! Replaces the old poll-and-sleep loop (workers spinning on an empty
//! `VecDeque` every 2ms) with a blocking queue that still preserves the
//! determinism contract: *which* worker trains *which* partition may vary
//! run to run, but results are keyed by `part_id` and each job's training
//! seed is derived from `part_id` alone, so the assembled output is
//! bit-identical regardless of pop order.
//!
//! Two features beyond a plain blocking queue:
//!
//! * **Delayed jobs** — the leader's retry backoff (see the event loop
//!   in `mod.rs`) never sleeps; it schedules the requeued job with a
//!   due time and workers promote it when the delay elapses (waiting with
//!   a timeout capped by the earliest due job, so a delayed job is picked
//!   up promptly without polling).
//! * **Per-worker retirement** — a worker the leader has declared dead
//!   (repeated deadline expiries) stops receiving jobs: its next
//!   [`JobQueue::pop`] returns `None` and its thread exits.
//!
//! `pop` returns `None` exactly when this worker should exit: shutdown,
//! retirement, or no open jobs left (merely *empty* is not enough — a
//! running job may fail and be requeued).

use super::messages::Job;
use crate::util::Stopwatch;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Upper bound on a single condvar wait when delayed jobs are pending —
/// a lost wakeup can delay a retry by at most this much.
const MAX_WAIT_MS: u64 = 100;

struct Inner {
    ready: VecDeque<Job>,
    /// `(due_secs, job)` — promoted to `ready` once the queue clock
    /// passes `due_secs`. Small (≤ in-flight retries), so a linear scan
    /// beats a heap.
    delayed: Vec<(f64, Job)>,
    /// Jobs not yet successfully finished or permanently skipped. While
    /// this is non-zero an idle worker must keep waiting: a running job
    /// may fail and be requeued.
    open: usize,
    retired: Vec<bool>,
    shutdown: bool,
}

pub struct JobQueue {
    inner: Mutex<Inner>,
    notify: Condvar,
    /// Time base for delayed-job due times.
    clock: Stopwatch,
}

impl JobQueue {
    pub fn new(jobs: Vec<Job>, workers: usize) -> Self {
        let open = jobs.len();
        JobQueue {
            inner: Mutex::new(Inner {
                ready: jobs.into(),
                delayed: Vec::new(),
                open,
                retired: vec![false; workers],
                shutdown: false,
            }),
            notify: Condvar::new(),
            clock: Stopwatch::start(),
        }
    }

    // queue state is plain data never left mid-update, so a poisoned
    // lock (panicked worker) is safe to recover everywhere below
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Blocking pop for `worker`. Returns `None` when this worker should
    /// exit: shutdown, retirement, or zero open jobs.
    pub fn pop(&self, worker: usize) -> Option<Job> {
        let mut st = self.lock();
        loop {
            if st.shutdown
                || st.open == 0
                || st.retired.get(worker).copied().unwrap_or(false)
            {
                return None;
            }
            let now = self.clock.secs();
            // promote due delayed jobs (stable: scan order = insert order)
            let mut i = 0;
            while i < st.delayed.len() {
                if st.delayed[i].0 <= now {
                    let (_, job) = st.delayed.remove(i);
                    st.ready.push_back(job);
                } else {
                    i += 1;
                }
            }
            if let Some(job) = st.ready.pop_front() {
                return Some(job);
            }
            // next wakeup: earliest delayed due time, capped so state
            // changes we might have raced are re-checked promptly
            let wait_ms = st
                .delayed
                .iter()
                .map(|(due, _)| ((due - now).max(0.0) * 1e3) as u64 + 1)
                .min()
                .unwrap_or(MAX_WAIT_MS)
                .min(MAX_WAIT_MS);
            let (guard, _) = self
                .notify
                .wait_timeout(st, Duration::from_millis(wait_ms))
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            st = guard;
        }
    }

    /// Requeue a job immediately.
    pub fn push_ready(&self, job: Job) {
        let mut st = self.lock();
        st.ready.push_back(job);
        drop(st);
        self.notify.notify_one();
    }

    /// Requeue a job after `delay_ms` (retry backoff). The leader never
    /// sleeps — the delay lives in the queue and workers promote the job
    /// when it comes due.
    pub fn push_delayed(&self, job: Job, delay_ms: u64) {
        let due = self.clock.secs() + delay_ms as f64 / 1e3;
        let mut st = self.lock();
        st.delayed.push((due, job));
        drop(st);
        // wake everyone: sleepers must re-derive their wait bound from
        // the new earliest due time
        self.notify.notify_all();
    }

    /// One open job resolved (finished or permanently skipped). At zero,
    /// idle workers wake up and exit.
    pub fn resolve_job(&self) {
        let mut st = self.lock();
        st.open = st.open.saturating_sub(1);
        let drained = st.open == 0;
        drop(st);
        if drained {
            self.notify.notify_all();
        }
    }

    pub fn open_jobs(&self) -> usize {
        self.lock().open
    }

    /// Stop handing jobs to `worker`; its next `pop` returns `None`.
    pub fn retire_worker(&self, worker: usize) {
        let mut st = self.lock();
        if let Some(flag) = st.retired.get_mut(worker) {
            *flag = true;
        }
        drop(st);
        self.notify.notify_all();
    }

    /// Abort: every `pop` (current and future) returns `None`.
    pub fn shutdown(&self) {
        let mut st = self.lock();
        st.shutdown = true;
        drop(st);
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn job(part_id: u32) -> Job {
        Job { part_id, members: vec![part_id], attempt: 0 }
    }

    #[test]
    fn pops_in_fifo_order_and_exits_at_zero_open() {
        let q = JobQueue::new(vec![job(0), job(1)], 1);
        assert_eq!(q.pop(0).unwrap().part_id, 0);
        assert_eq!(q.pop(0).unwrap().part_id, 1);
        q.resolve_job();
        q.resolve_job();
        assert_eq!(q.open_jobs(), 0);
        assert!(q.pop(0).is_none(), "no open jobs → exit signal");
    }

    #[test]
    fn empty_but_open_queue_blocks_until_requeue() {
        let q = Arc::new(JobQueue::new(vec![job(0)], 2));
        assert_eq!(q.pop(0).unwrap().part_id, 0);
        // worker 1 blocks on the empty-but-open queue until the leader
        // requeues the failed job
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop(1).map(|j| j.part_id));
        q.push_ready(job(0));
        assert_eq!(h.join().unwrap(), Some(0));
    }

    #[test]
    fn delayed_jobs_become_visible_after_their_due_time() {
        let q = JobQueue::new(Vec::new(), 1);
        {
            let mut st = q.lock();
            st.open = 1; // keep pop waiting instead of exiting
        }
        let sw = Stopwatch::start();
        q.push_delayed(job(7), 30);
        let got = q.pop(0).expect("delayed job must surface");
        assert_eq!(got.part_id, 7);
        assert!(
            sw.millis() >= 25.0,
            "promoted after ~{}ms, expected ≥ ~30ms",
            sw.millis()
        );
    }

    #[test]
    fn retired_worker_gets_none_while_others_still_pop() {
        let q = JobQueue::new(vec![job(0)], 2);
        q.retire_worker(0);
        assert!(q.pop(0).is_none());
        assert_eq!(q.pop(1).unwrap().part_id, 0);
    }

    #[test]
    fn shutdown_unblocks_waiting_workers() {
        let q = Arc::new(JobQueue::new(vec![job(0)], 2));
        assert!(q.pop(0).is_some());
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop(1));
        q.shutdown();
        assert!(h.join().unwrap().is_none());
    }
}
