//! Control-plane messages between the leader and machine workers.
//!
//! Only control data crosses threads — partition tensors are built
//! worker-side from the shared read-only dataset, mirroring a cluster
//! where each machine loads its own shard.
//!
//! The same message shapes ride both transports: in-process they cross
//! an mpsc channel as-is; over TCP they are serialized into frames by
//! `net::wire`. That is why failures carry a typed [`ErrorCode`]
//! instead of a worker-side `transient: bool` — the classification is
//! one shared taxonomy, computed from the error class itself, and small
//! enough to put on the wire.

use crate::error::Error;
use crate::graph::NodeId;
use crate::train::TrainedPartition;

/// One unit of work: train a partition.
#[derive(Clone, Debug)]
pub struct Job {
    pub part_id: u32,
    pub members: Vec<NodeId>,
    /// 0 on first dispatch; incremented on retry.
    pub attempt: u32,
}

/// Wire-serializable classification of a worker-side failure.
///
/// One code per [`Error`] variant, so transient-vs-permanent is decided
/// by the error *class* (see [`Error::is_transient`]) on both sides of
/// any transport, and survives a round-trip through a u16 on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum ErrorCode {
    Graph = 1,
    Partition = 2,
    Runtime = 3,
    Config = 4,
    Coordinator = 5,
    Io = 6,
    Manifest = 7,
    Serve = 8,
    Xla = 9,
    Lint = 10,
    Fault = 11,
    Net = 12,
}

impl ErrorCode {
    /// Classify a typed error into its wire code.
    pub fn of(e: &Error) -> Self {
        match e {
            Error::Graph(_) => ErrorCode::Graph,
            Error::Partition(_) => ErrorCode::Partition,
            Error::Runtime(_) => ErrorCode::Runtime,
            Error::Config(_) => ErrorCode::Config,
            Error::Coordinator(_) => ErrorCode::Coordinator,
            Error::Io(_) => ErrorCode::Io,
            Error::Manifest(_) => ErrorCode::Manifest,
            Error::Serve(_) => ErrorCode::Serve,
            Error::Xla(_) => ErrorCode::Xla,
            Error::Lint(_) => ErrorCode::Lint,
            Error::Fault(_) => ErrorCode::Fault,
            Error::Net(_) => ErrorCode::Net,
        }
    }

    /// Mirror of [`Error::is_transient`], decidable from the code alone
    /// so the leader never needs the (lossy) message string to pick a
    /// retry-vs-policy path.
    pub fn is_transient(self) -> bool {
        matches!(
            self,
            ErrorCode::Io | ErrorCode::Xla | ErrorCode::Runtime | ErrorCode::Fault | ErrorCode::Net
        )
    }

    pub fn as_u16(self) -> u16 {
        self as u16
    }

    /// Decode a wire code; unknown values map to `None` so a corrupt or
    /// future-version frame degrades into a typed decode error, not UB.
    pub fn from_u16(v: u16) -> Option<Self> {
        match v {
            1 => Some(ErrorCode::Graph),
            2 => Some(ErrorCode::Partition),
            3 => Some(ErrorCode::Runtime),
            4 => Some(ErrorCode::Config),
            5 => Some(ErrorCode::Coordinator),
            6 => Some(ErrorCode::Io),
            7 => Some(ErrorCode::Manifest),
            8 => Some(ErrorCode::Serve),
            9 => Some(ErrorCode::Xla),
            10 => Some(ErrorCode::Lint),
            11 => Some(ErrorCode::Fault),
            12 => Some(ErrorCode::Net),
            _ => None,
        }
    }

    /// Short stable name, for logs and journal lines.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::Graph => "graph",
            ErrorCode::Partition => "partition",
            ErrorCode::Runtime => "runtime",
            ErrorCode::Config => "config",
            ErrorCode::Coordinator => "coordinator",
            ErrorCode::Io => "io",
            ErrorCode::Manifest => "manifest",
            ErrorCode::Serve => "serve",
            ErrorCode::Xla => "xla",
            ErrorCode::Lint => "lint",
            ErrorCode::Fault => "fault",
            ErrorCode::Net => "net",
        }
    }
}

/// Events streamed from workers to the leader.
#[derive(Debug)]
pub enum WorkerEvent {
    Started {
        worker: usize,
        part_id: u32,
    },
    Finished {
        worker: usize,
        part_id: u32,
        /// Attempt number the result was produced under. The leader
        /// dedupes idempotent re-deliveries (e.g. a retried job whose
        /// first result arrives late over a resurrected connection) by
        /// `(part_id, attempt)`.
        attempt: u32,
        /// Owned (non-replica) global node ids, in the result's row order.
        nodes: Vec<NodeId>,
        result: TrainedPartition,
    },
    Failed {
        worker: usize,
        part_id: u32,
        /// Typed classification; [`ErrorCode::is_transient`] failures
        /// earn backoff + retry, permanent ones go straight to the
        /// leader's `on_failure` policy.
        code: ErrorCode,
        message: String,
    },
    /// The worker is permanently out of service (runtime init failed —
    /// without a PJRT client it can train nothing). The leader removes
    /// it from the schedulable pool; remaining jobs redistribute over
    /// the survivors, and a run with zero live workers aborts.
    Retired {
        worker: usize,
        error: String,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_code_roundtrips_through_u16() {
        for code in [
            ErrorCode::Graph,
            ErrorCode::Partition,
            ErrorCode::Runtime,
            ErrorCode::Config,
            ErrorCode::Coordinator,
            ErrorCode::Io,
            ErrorCode::Manifest,
            ErrorCode::Serve,
            ErrorCode::Xla,
            ErrorCode::Lint,
            ErrorCode::Fault,
            ErrorCode::Net,
        ] {
            assert_eq!(ErrorCode::from_u16(code.as_u16()), Some(code));
        }
        assert_eq!(ErrorCode::from_u16(0), None);
        assert_eq!(ErrorCode::from_u16(13), None);
        assert_eq!(ErrorCode::from_u16(u16::MAX), None);
    }

    #[test]
    fn error_code_transience_matches_error_taxonomy() {
        let cases: Vec<Error> = vec![
            Error::Graph("x".into()),
            Error::Partition("x".into()),
            Error::Runtime("x".into()),
            Error::Config("x".into()),
            Error::Coordinator("x".into()),
            Error::Io(std::io::Error::other("x")),
            Error::Manifest("x".into()),
            Error::Serve("x".into()),
            Error::Xla("x".into()),
            Error::Lint("x".into()),
            Error::Fault("x".into()),
            Error::Net("x".into()),
        ];
        for e in &cases {
            assert_eq!(
                ErrorCode::of(e).is_transient(),
                e.is_transient(),
                "taxonomy drift for {e}"
            );
        }
    }
}
