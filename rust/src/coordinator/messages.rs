//! Control-plane messages between the leader and machine workers.
//!
//! Only control data crosses threads — partition tensors are built
//! worker-side from the shared read-only dataset, mirroring a cluster
//! where each machine loads its own shard.

use crate::graph::NodeId;
use crate::train::TrainedPartition;

/// One unit of work: train a partition.
#[derive(Clone, Debug)]
pub struct Job {
    pub part_id: u32,
    pub members: Vec<NodeId>,
    /// 0 on first dispatch; incremented on retry.
    pub attempt: u32,
}

/// Events streamed from workers to the leader.
#[derive(Debug)]
pub enum WorkerEvent {
    Started {
        worker: usize,
        part_id: u32,
    },
    Finished {
        worker: usize,
        part_id: u32,
        /// Owned (non-replica) global node ids, in the result's row order.
        nodes: Vec<NodeId>,
        result: TrainedPartition,
    },
    Failed {
        worker: usize,
        part_id: u32,
        error: String,
        /// [`crate::error::Error::is_transient`] of the underlying error,
        /// classified worker-side (the typed error doesn't cross the
        /// channel). Transient failures earn backoff + retry; permanent
        /// ones go straight to the leader's `on_failure` policy.
        transient: bool,
    },
    /// The worker is permanently out of service (runtime init failed —
    /// without a PJRT client it can train nothing). The leader removes
    /// it from the schedulable pool; remaining jobs redistribute over
    /// the survivors, and a run with zero live workers aborts.
    Retired {
        worker: usize,
        error: String,
    },
}
