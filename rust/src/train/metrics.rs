//! Evaluation metrics: multiclass accuracy (arxiv-like) and multilabel
//! ROC-AUC (proteins-like, averaged over tasks as in OGB).

/// Argmax accuracy over the rows selected by `mask`.
///
/// `logits` is row-major `[n, c]`; `labels[v] ∈ 0..c`.
pub fn accuracy(logits: &[f32], labels: &[i32], mask: &[bool], c: usize) -> f64 {
    debug_assert_eq!(logits.len(), labels.len() * c);
    let mut correct = 0usize;
    let mut total = 0usize;
    for (v, &keep) in mask.iter().enumerate() {
        if !keep {
            continue;
        }
        let row = &logits[v * c..(v + 1) * c];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i as i32)
            .unwrap_or(0);
        correct += (pred == labels[v]) as usize;
        total += 1;
    }
    if total == 0 {
        return 0.0;
    }
    correct as f64 / total as f64
}

/// ROC-AUC of one binary task via the rank formulation (Mann–Whitney U),
/// with midranks for ties. Returns `None` if the task is single-class on
/// the evaluated rows (OGB skips such tasks in the average).
pub fn binary_auc(scores: &[f32], targets: &[f32]) -> Option<f64> {
    debug_assert_eq!(scores.len(), targets.len());
    let n = scores.len();
    let pos = targets.iter().filter(|&&t| t > 0.5).count();
    let neg = n - pos;
    if pos == 0 || neg == 0 {
        return None;
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal)
    });
    // midranks
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0usize;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let midrank = (i + j) as f64 / 2.0 + 1.0;
        for &v in &idx[i..=j] {
            if targets[v] > 0.5 {
                rank_sum_pos += midrank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - pos as f64 * (pos as f64 + 1.0) / 2.0;
    Some(u / (pos as f64 * neg as f64))
}

/// Macro-averaged ROC-AUC over `tasks` columns, restricted to `mask` rows.
/// `logits`/`targets` are row-major `[n, tasks]`.
pub fn multilabel_auc(logits: &[f32], targets: &[f32], mask: &[bool], tasks: usize) -> f64 {
    let rows: Vec<usize> = mask
        .iter()
        .enumerate()
        .filter(|(_, &m)| m)
        .map(|(v, _)| v)
        .collect();
    if rows.is_empty() {
        return 0.0;
    }
    let mut scores = Vec::with_capacity(rows.len());
    let mut tgts = Vec::with_capacity(rows.len());
    let mut sum = 0.0f64;
    let mut counted = 0usize;
    for t in 0..tasks {
        scores.clear();
        tgts.clear();
        for &v in &rows {
            scores.push(logits[v * tasks + t]);
            tgts.push(targets[v * tasks + t]);
        }
        if let Some(auc) = binary_auc(&scores, &tgts) {
            sum += auc;
            counted += 1;
        }
    }
    if counted == 0 {
        return 0.0;
    }
    sum / counted as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        // 3 nodes, 2 classes
        let logits = [0.9f32, 0.1, 0.2, 0.8, 0.6, 0.4];
        let labels = [0, 1, 1];
        let acc = accuracy(&logits, &labels, &[true, true, true], 2);
        assert!((acc - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_respects_mask() {
        let logits = [0.9f32, 0.1, 0.2, 0.8];
        let labels = [1, 1]; // node 0 wrong, node 1 right
        assert_eq!(accuracy(&logits, &labels, &[false, true], 2), 1.0);
        assert_eq!(accuracy(&logits, &labels, &[false, false], 2), 0.0);
    }

    #[test]
    fn auc_perfect_and_inverted() {
        let scores = [0.1f32, 0.2, 0.8, 0.9];
        let targets = [0.0f32, 0.0, 1.0, 1.0];
        assert_eq!(binary_auc(&scores, &targets), Some(1.0));
        let inv = [0.9f32, 0.8, 0.2, 0.1];
        assert_eq!(binary_auc(&inv, &targets), Some(0.0));
    }

    #[test]
    fn auc_symmetric_split_is_half() {
        // positives at the extremes, negatives in the middle → 0.5
        let scores = [0.1f32, 0.2, 0.3, 0.4];
        let targets = [1.0f32, 0.0, 0.0, 1.0];
        assert_eq!(binary_auc(&scores, &targets), Some(0.5));
    }

    #[test]
    fn auc_ties_get_midranks() {
        let scores = [0.5f32, 0.5, 0.5, 0.5];
        let targets = [1.0f32, 0.0, 1.0, 0.0];
        assert_eq!(binary_auc(&scores, &targets), Some(0.5));
    }

    #[test]
    fn auc_single_class_is_none() {
        assert_eq!(binary_auc(&[0.1, 0.9], &[1.0, 1.0]), None);
        assert_eq!(binary_auc(&[], &[]), None);
    }

    #[test]
    fn multilabel_skips_degenerate_tasks() {
        // 2 tasks over 4 nodes; task 1 is all-positive → skipped
        let logits = [0.1f32, 9.0, 0.2, 9.0, 0.8, 9.0, 0.9, 9.0];
        let targets = [0.0f32, 1.0, 0.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let auc = multilabel_auc(&logits, &targets, &[true; 4], 2);
        assert_eq!(auc, 1.0);
    }

    #[test]
    fn multilabel_respects_mask() {
        let logits = [0.9f32, 0.1, 0.8, 0.2];
        let targets = [0.0f32, 1.0, 1.0, 0.0];
        // only rows 2,3 → single task columns... 2 tasks, rows {1}: degenerate
        let auc = multilabel_auc(&logits, &targets, &[false, true], 2);
        assert_eq!(auc, 0.0); // no task has both classes on one row
    }
}
