//! Per-partition trainer: drives a train artifact for E epochs, then the
//! eval artifact to extract embeddings + logits for the owned nodes.
//!
//! This is the "no communication during training" core of the paper: the
//! whole loop touches only partition-local tensors. By default it runs on
//! a device-resident [`ExecSession`] ([`ExecPath::Session`]): invariant
//! inputs (features, edges, labels, mask) are staged once, the mutable
//! state (params + Adam moments + step counter) never leaves the device
//! between calls, and only the loss scalar crosses back per call. The
//! original host round-trip loop survives as [`ExecPath::Reference`] —
//! the bit-exactness oracle (`tests/train_session.rs`).

use super::data::{pad_to_bucket_with, ModelKind, PadScratch, PartitionBatch};
use crate::error::{Error, Result};
use crate::runtime::{ExecStats, Executable, Runtime, Tensor};
use crate::util::rng::Rng;
use crate::util::Stopwatch;
use std::rc::Rc;

/// How a training loop drives PJRT.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecPath {
    /// Device-resident [`crate::runtime::ExecSession`]: stage invariants
    /// once, keep optimizer state on device, download only the loss.
    Session,
    /// The original host round-trip: rebuild every input literal and
    /// download every output, every call. Kept as the bit-exactness
    /// oracle and for A/B timing (`bench_train`).
    Reference,
}

impl ExecPath {
    pub fn as_str(&self) -> &'static str {
        match self {
            ExecPath::Session => "session",
            ExecPath::Reference => "reference",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "session" => Ok(ExecPath::Session),
            "reference" => Ok(ExecPath::Reference),
            other => Err(Error::Config(format!("unknown exec path {other:?}"))),
        }
    }
}

/// Hyper-parameters of one partition-training run.
#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub model: ModelKind,
    /// Total full-batch epochs (rounded up to epochs_per_call).
    pub epochs: usize,
    pub seed: u64,
    /// Report a loss sample every `log_every` calls (0 = never).
    pub log_every: usize,
    /// PJRT execution strategy (default: device-resident session).
    pub exec: ExecPath,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            model: ModelKind::Gcn,
            epochs: 80,
            seed: 0,
            log_every: 0,
            exec: ExecPath::Session,
        }
    }
}

/// Outcome of training one partition.
#[derive(Clone, Debug)]
pub struct TrainedPartition {
    /// Loss after each train call (each call = epochs_per_call epochs).
    pub losses: Vec<f32>,
    /// `[num_owned, h]` embeddings of owned nodes (local order).
    pub embeddings: Vec<f32>,
    pub emb_dim: usize,
    /// `[num_owned, c]` logits of owned nodes.
    pub logits: Vec<f32>,
    pub num_classes: usize,
    /// Replica (halo) nodes the subgraph carried (0 for Inner mode).
    pub num_replicas: usize,
    /// Wall-clock seconds spent in train executions (session path: incl.
    /// the one-time staging upload).
    pub train_secs: f64,
    /// Transfer/phase counters of the training session (`None` on the
    /// reference path).
    pub exec_stats: Option<ExecStats>,
}

/// Glorot-uniform init for the artifact's parameter tensors (matches the
/// python `init_params`): 2-D tensors get ±sqrt(6/(fan_in+fan_out)),
/// 1-D biases get zeros.
pub fn init_params(exe: &Executable, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    let p = exe.meta.num_params();
    exe.meta.inputs[..p]
        .iter()
        .map(|spec| {
            if spec.shape.len() == 2 {
                let lim = (6.0 / (spec.shape[0] + spec.shape[1]) as f64).sqrt();
                Tensor::f32(
                    (0..spec.num_elements())
                        .map(|_| ((rng.f64() * 2.0 - 1.0) * lim) as f32)
                        .collect(),
                )
            } else {
                Tensor::f32(vec![0.0; spec.num_elements()])
            }
        })
        .collect()
}

/// All-zero tensors shaped like `params` — the Adam moment init, shared
/// by this trainer and the integration classifier (one definition, not
/// two hand-rolled copies).
pub fn zeros_like(params: &[Tensor]) -> Vec<Tensor> {
    params.iter().map(|t| Tensor::f32(vec![0.0; t.len()])).collect()
}

/// Assemble the full Adam state block `[params, m, v, t]` in artifact
/// input order.
pub(crate) fn adam_state(params: Vec<Tensor>) -> Vec<Tensor> {
    let m = zeros_like(&params);
    let v = zeros_like(&params);
    let mut state = params;
    state.extend(m);
    state.extend(v);
    state.push(Tensor::f32(vec![0.0]));
    state
}

/// Train one partition end-to-end and extract owned-node outputs.
pub fn train_partition(
    rt: &Runtime,
    batch: &PartitionBatch,
    opts: &TrainOptions,
) -> Result<TrainedPartition> {
    train_partition_with(rt, batch, opts, &mut PadScratch::new())
}

/// [`train_partition`] with a caller-provided padding scratch — workers
/// that train many partitions (and coordinator retries) reuse the padded
/// bucket allocations instead of rebuilding them per job.
pub fn train_partition_with(
    rt: &Runtime,
    batch: &PartitionBatch,
    opts: &TrainOptions,
    pad: &mut PadScratch,
) -> Result<TrainedPartition> {
    let task = match &batch.y {
        super::data::LabelSlice::Multiclass(_) => "multiclass",
        super::data::LabelSlice::Multilabel { .. } => "multilabel",
    };
    let model = opts.model.as_str();
    let nl = batch.num_local();
    let el = batch.num_directed_edges();

    let train_exe = rt.load_for(model, task, "train", nl, el)?;
    let eval_exe = rt.load_for(model, task, "eval", nl, el)?;
    // train/eval pair must share buckets so params transfer directly
    debug_assert_eq!(train_exe.meta.dims.n, eval_exe.meta.dims.n);
    let dims = train_exe.meta.dims.clone();
    let padded = pad_to_bucket_with(batch, dims.n, dims.e, dims.c, pad)?;

    let p = train_exe.meta.num_params();
    let params = init_params(&train_exe, opts.seed);
    let calls = opts.epochs.div_ceil(dims.epochs_per_call.max(1));

    // ---- train loop: state stays where the path puts it ---------------
    let (losses, final_state, train_secs, exec_stats) = match opts.exec {
        ExecPath::Session => {
            let invariant = [
                padded.x.clone(),
                padded.src.clone(),
                padded.dst.clone(),
                padded.ew.clone(),
                padded.y.clone(),
                padded.mask.clone(),
            ];
            let state = adam_state(params);
            let sw = Stopwatch::start();
            let mut session = rt.session(Rc::clone(&train_exe), &state, &invariant)?;
            drop(state);
            let mut losses = Vec::with_capacity(calls);
            for call in 0..calls {
                let loss = session.run_step()?;
                losses.push(loss);
                if opts.log_every > 0 && call % opts.log_every == 0 {
                    log::debug!("train call {call}/{calls}: loss {loss:.4}");
                }
            }
            let train_secs = sw.secs();
            // the one download of the run: final params (+ moments)
            let final_state = session.state_tensors()?;
            (losses, final_state, train_secs, Some(session.stats()))
        }
        ExecPath::Reference => {
            let mut params = params;
            let mut m = zeros_like(&params);
            let mut v = zeros_like(&params);
            let mut t = Tensor::f32(vec![0.0]);
            let mut losses = Vec::with_capacity(calls);
            let sw = Stopwatch::start();
            for call in 0..calls {
                let mut inputs = Vec::with_capacity(3 * p + 7);
                inputs.extend(params.iter().cloned());
                inputs.extend(m.iter().cloned());
                inputs.extend(v.iter().cloned());
                inputs.push(t.clone());
                inputs.push(padded.x.clone());
                inputs.push(padded.src.clone());
                inputs.push(padded.dst.clone());
                inputs.push(padded.ew.clone());
                inputs.push(padded.y.clone());
                inputs.push(padded.mask.clone());
                let mut out = train_exe.run(&inputs)?;
                let loss = out
                    .last()
                    .ok_or_else(|| Error::Runtime("train step returned no outputs".into()))?
                    .scalar_f32()?;
                losses.push(loss);
                t = out[3 * p].clone();
                // reclaim updated state without copying
                v = out.drain(2 * p..3 * p).collect();
                m = out.drain(p..2 * p).collect();
                params = out.drain(..p).collect();
                if opts.log_every > 0 && call % opts.log_every == 0 {
                    log::debug!("train call {call}/{calls}: loss {loss:.4}");
                }
            }
            let train_secs = sw.secs();
            let mut state = params;
            state.extend(m);
            state.extend(v);
            state.push(t);
            (losses, state, train_secs, None)
        }
    };

    // ---- eval: embeddings + logits ------------------------------------
    let mut eval_inputs: Vec<Tensor> = final_state[..p].to_vec(); // refcount bumps
    eval_inputs.push(padded.x);
    eval_inputs.push(padded.src);
    eval_inputs.push(padded.dst);
    eval_inputs.push(padded.ew);
    let out = match opts.exec {
        ExecPath::Session => {
            let mut sess = rt.session(Rc::clone(&eval_exe), &[], &eval_inputs)?;
            sess.run_outputs()?
        }
        ExecPath::Reference => eval_exe.run(&eval_inputs)?,
    };
    let emb_full = out[0].as_f32()?;
    let logits_full = out[1].as_f32()?;
    let h = eval_exe.meta.dims.h;
    let c = eval_exe.meta.dims.c;
    let owned = batch.sub.num_owned;
    let embeddings = emb_full[..owned * h].to_vec();
    let logits = logits_full[..owned * c].to_vec();

    Ok(TrainedPartition {
        losses,
        embeddings,
        emb_dim: h,
        logits,
        num_classes: c,
        num_replicas: batch.sub.num_replicas(),
        train_secs,
        exec_stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::karate_dataset;
    use crate::graph::NodeId;
    use crate::testing::runtime_if_built;
    use crate::train::data::{build_batch, Mode};

    #[test]
    fn trains_karate_full_graph_loss_decreases() {
        let Some(rt) = runtime_if_built() else { return };
        let ds = karate_dataset(3);
        let members: Vec<NodeId> = (0..34).collect();
        let batch = build_batch(&ds, &members, Mode::Inner, ModelKind::Gcn).unwrap();
        let opts = TrainOptions { epochs: 20, seed: 1, ..Default::default() };
        let out = train_partition(&rt, &batch, &opts).unwrap();
        assert!(out.losses.len() >= 2);
        assert!(
            out.losses.last().unwrap() < out.losses.first().unwrap(),
            "{:?}",
            out.losses
        );
        assert_eq!(out.embeddings.len(), 34 * out.emb_dim);
        assert_eq!(out.logits.len(), 34 * out.num_classes);
        assert!(out.embeddings.iter().all(|x| x.is_finite()));
        // default path is the session: transfer counters must exist
        let stats = out.exec_stats.expect("session path reports stats");
        assert_eq!(stats.steps, out.losses.len());
        assert!(stats.bytes_to_device > 0);
    }

    #[test]
    fn repli_outputs_only_owned_rows() {
        let Some(rt) = runtime_if_built() else { return };
        let ds = karate_dataset(3);
        let members: Vec<NodeId> = (0..12).collect();
        let batch = build_batch(&ds, &members, Mode::Repli, ModelKind::Sage).unwrap();
        assert!(batch.sub.num_replicas() > 0);
        let opts = TrainOptions {
            epochs: 4,
            model: ModelKind::Sage,
            seed: 2,
            ..Default::default()
        };
        let out = train_partition(&rt, &batch, &opts).unwrap();
        assert_eq!(out.embeddings.len(), 12 * out.emb_dim);
    }

    #[test]
    fn init_params_matches_artifact_shapes() {
        let Some(rt) = runtime_if_built() else { return };
        let exe = rt.load("gcn_smoke_train").unwrap();
        let params = init_params(&exe, 0);
        assert_eq!(params.len(), exe.meta.num_params());
        for (t, spec) in params.iter().zip(&exe.meta.inputs) {
            assert_eq!(t.len(), spec.num_elements());
        }
        // biases zero, weights bounded
        for (t, spec) in params.iter().zip(&exe.meta.inputs) {
            let v = t.as_f32().unwrap();
            if spec.shape.len() == 1 {
                assert!(v.iter().all(|&x| x == 0.0));
            } else {
                let lim = (6.0 / (spec.shape[0] + spec.shape[1]) as f64).sqrt() as f32;
                assert!(v.iter().all(|&x| x.abs() <= lim));
            }
        }
    }

    #[test]
    fn exec_path_parses_and_round_trips() {
        assert_eq!(ExecPath::parse("session").unwrap(), ExecPath::Session);
        assert_eq!(ExecPath::parse("reference").unwrap(), ExecPath::Reference);
        assert!(ExecPath::parse("device").is_err());
        for p in [ExecPath::Session, ExecPath::Reference] {
            assert_eq!(ExecPath::parse(p.as_str()).unwrap(), p);
        }
    }

    #[test]
    fn zeros_like_matches_shapes_and_is_zero() {
        let params =
            vec![Tensor::f32(vec![1.0, 2.0, 3.0]), Tensor::f32(vec![4.0; 5])];
        let z = zeros_like(&params);
        assert_eq!(z.len(), 2);
        for (zt, pt) in z.iter().zip(&params) {
            assert_eq!(zt.len(), pt.len());
            assert!(zt.as_f32().unwrap().iter().all(|&x| x == 0.0));
        }
    }
}
