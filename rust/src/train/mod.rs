//! Training pipeline: partition batches, the per-partition trainer, the
//! embedding-integration + MLP stage, and evaluation metrics.

pub mod checkpoint;
pub mod data;
pub mod integrate;
pub mod metrics;
pub mod trainer;

pub use data::{build_batch, build_batch_with, pad_to_bucket, Mode, ModelKind, PartitionBatch};
pub use integrate::{
    classify, evaluate_classifier, train_classifier, Classifier, EmbeddingStore, EvalReport,
};
pub use trainer::{train_partition, TrainOptions, TrainedPartition};
