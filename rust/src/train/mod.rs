//! Training pipeline: partition batches, the per-partition trainer, the
//! embedding-integration + MLP stage, and evaluation metrics.

pub mod checkpoint;
pub mod data;
pub mod integrate;
pub mod metrics;
pub mod trainer;

pub use data::{
    build_batch, build_batch_with, pad_to_bucket, pad_to_bucket_with, Mode, ModelKind,
    PadScratch, PartitionBatch,
};
pub use integrate::{
    classify, evaluate_classifier, train_classifier, train_classifier_path,
    train_classifier_reference, Classifier, EmbeddingStore, EvalReport,
};
pub use trainer::{
    init_params, train_partition, train_partition_with, zeros_like, ExecPath,
    TrainOptions, TrainedPartition,
};
