//! Embedding integration (paper §5.2): assemble per-partition embeddings
//! into a global matrix, train the MLP classifier on it, and evaluate.
//!
//! Each node's embedding comes from the partition that *owns* it; replicas
//! are discarded by the trainer. The MLP stage runs on the leader after all
//! partitions finish — the only cross-partition data movement in the whole
//! pipeline, as in the paper.
//!
//! Training and evaluation are split ([`train_classifier`] /
//! [`evaluate_classifier`]) so the coordinator can persist the trained
//! parameters into a serving bundle (`serve::shard`) — the serving engine
//! replays the same row-wise MLP forward at query time.

use super::metrics;
use super::trainer::{adam_state, init_params, zeros_like, ExecPath};
use crate::data::{Dataset, Labels};
use crate::error::{Error, Result};
use crate::graph::NodeId;
use crate::runtime::{Runtime, Tensor};
use std::rc::Rc;

/// Global embedding matrix under assembly.
pub struct EmbeddingStore {
    pub n: usize,
    pub dim: usize,
    data: Vec<f32>,
    filled: Vec<bool>,
}

impl EmbeddingStore {
    pub fn new(n: usize, dim: usize) -> Self {
        EmbeddingStore { n, dim, data: vec![0.0; n * dim], filled: vec![false; n] }
    }

    /// Write the owned-node embeddings of one partition.
    ///
    /// Atomic: the whole block is validated (size, node-id range, no node
    /// already `filled`) before any row is written, so a rejected insert
    /// leaves the store exactly as it was — the coordinator relies on this
    /// when it retries a partition after a duplicate-delivery fault.
    pub fn insert(&mut self, nodes: &[NodeId], emb: &[f32]) -> Result<()> {
        if emb.len() != nodes.len() * self.dim {
            return Err(Error::Coordinator(format!(
                "embedding block {} != {} nodes × dim {}",
                emb.len(),
                nodes.len(),
                self.dim
            )));
        }
        for &v in nodes {
            let vi = v as usize;
            if vi >= self.n {
                return Err(Error::Coordinator(format!(
                    "node {v} out of range (store holds {} nodes)",
                    self.n
                )));
            }
            if self.filled[vi] {
                return Err(Error::Coordinator(format!("node {v} embedded twice")));
            }
        }
        if nodes.len() > 1 {
            // duplicates *within* the block would also double-embed
            let mut seen = std::collections::HashSet::with_capacity(nodes.len());
            for &v in nodes {
                if !seen.insert(v) {
                    return Err(Error::Coordinator(format!(
                        "node {v} appears twice in one embedding block"
                    )));
                }
            }
        }
        for (i, &v) in nodes.iter().enumerate() {
            let vi = v as usize;
            self.filled[vi] = true;
            self.data[vi * self.dim..(vi + 1) * self.dim]
                .copy_from_slice(&emb[i * self.dim..(i + 1) * self.dim]);
        }
        Ok(())
    }

    pub fn num_filled(&self) -> usize {
        self.filled.iter().filter(|&&b| b).count()
    }

    pub fn is_complete(&self) -> bool {
        self.filled.iter().all(|&b| b)
    }

    pub fn matrix(&self) -> &[f32] {
        &self.data
    }

    /// Extract the embedding rows of `nodes` in order, e.g. to re-shard an
    /// already-assembled store offline. (The coordinator's streaming export
    /// writes each `LFS1` shard directly from the worker result instead,
    /// before the store is complete.)
    pub fn rows_of(&self, nodes: &[NodeId]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(nodes.len() * self.dim);
        for &v in nodes {
            let vi = v as usize;
            if vi >= self.n {
                return Err(Error::Coordinator(format!(
                    "node {v} out of range (store holds {} nodes)",
                    self.n
                )));
            }
            if !self.filled[vi] {
                return Err(Error::Coordinator(format!("node {v} not embedded yet")));
            }
            out.extend_from_slice(&self.data[vi * self.dim..(vi + 1) * self.dim]);
        }
        Ok(out)
    }
}

/// Result of the classification stage.
#[derive(Clone, Debug)]
pub struct EvalReport {
    /// MLP losses per train call.
    pub mlp_losses: Vec<f32>,
    /// Accuracy (multiclass) or ROC-AUC (multilabel) on the test split.
    pub test_metric: f64,
    /// Same on the validation split.
    pub val_metric: f64,
    pub metric_name: &'static str,
}

/// A trained integration classifier: the MLP parameters plus the shape
/// metadata a serving engine needs to rebind them to a (possibly smaller)
/// inference bucket.
#[derive(Clone, Debug)]
pub struct Classifier {
    pub params: Vec<Tensor>,
    pub losses: Vec<f32>,
    /// `multiclass` | `multilabel`.
    pub task: &'static str,
    /// Embedding width the MLP consumes (artifact `f`).
    pub feat_dim: usize,
    /// Logit columns (artifact `c`, bucketed class dim).
    pub classes: usize,
}

/// Pad the store's embedding matrix into an artifact-sized `x` tensor.
fn padded_x(store: &EmbeddingStore, bucket_n: usize, feat_dim: usize) -> Tensor {
    let n = store.n;
    let mut x = vec![0f32; bucket_n * feat_dim];
    x[..n * feat_dim].copy_from_slice(store.matrix());
    Tensor::f32(x)
}

/// Pad labels + train mask to the bucket (train path only — the pred
/// artifact takes just `x`).
fn padded_targets(dataset: &Dataset, n: usize, bucket_n: usize) -> (Tensor, Tensor) {
    let y = match &dataset.labels {
        Labels::Multiclass { labels, .. } => {
            let mut yy = vec![0i32; bucket_n];
            yy[..n].copy_from_slice(labels);
            Tensor::i32(yy)
        }
        Labels::Multilabel { tasks, targets } => {
            let mut yy = vec![0f32; bucket_n * tasks];
            yy[..n * tasks].copy_from_slice(targets);
            Tensor::f32(yy)
        }
    };
    let mut mask = vec![0f32; bucket_n];
    for v in 0..n {
        mask[v] = dataset.train_mask[v] as u8 as f32;
    }
    (y, Tensor::f32(mask))
}

/// Train the integration MLP on the embeddings (train-split rows only)
/// and return the fitted parameters. Runs on the device-resident session
/// path; [`train_classifier_reference`] is the host round-trip oracle.
pub fn train_classifier(
    rt: &Runtime,
    dataset: &Dataset,
    store: &EmbeddingStore,
    epochs: usize,
    seed: u64,
) -> Result<Classifier> {
    train_classifier_path(rt, dataset, store, epochs, seed, ExecPath::Session)
}

/// [`train_classifier`] through the original host round-trip loop — kept
/// as the bit-exactness oracle (`tests/train_session.rs`) and for A/B
/// timing.
pub fn train_classifier_reference(
    rt: &Runtime,
    dataset: &Dataset,
    store: &EmbeddingStore,
    epochs: usize,
    seed: u64,
) -> Result<Classifier> {
    train_classifier_path(rt, dataset, store, epochs, seed, ExecPath::Reference)
}

/// [`train_classifier`] with an explicit [`ExecPath`].
pub fn train_classifier_path(
    rt: &Runtime,
    dataset: &Dataset,
    store: &EmbeddingStore,
    epochs: usize,
    seed: u64,
    exec: ExecPath,
) -> Result<Classifier> {
    if !store.is_complete() {
        return Err(Error::Coordinator(format!(
            "embedding store incomplete: {}/{} nodes",
            store.num_filled(),
            store.n
        )));
    }
    let n = store.n;
    let task = dataset.labels.task_name();
    let train_exe = rt.load_for("mlp", task, "train", n, 0)?;
    let dims = train_exe.meta.dims.clone();
    if dims.f != store.dim {
        return Err(Error::Coordinator(format!(
            "MLP expects dim {} embeddings, store has {}",
            dims.f, store.dim
        )));
    }
    let x = padded_x(store, dims.n, dims.f);
    let (y, mask) = padded_targets(dataset, n, dims.n);

    let p = train_exe.meta.num_params();
    let mut params = init_params(&train_exe, seed);
    let calls = epochs.div_ceil(dims.epochs_per_call.max(1));
    let mut losses = Vec::with_capacity(calls);
    match exec {
        ExecPath::Session => {
            // x/y/mask staged once; the Adam state never leaves the device
            let state = adam_state(params);
            let mut session = rt.session(Rc::clone(&train_exe), &state, &[x, y, mask])?;
            drop(state);
            for _ in 0..calls {
                losses.push(session.run_step()?);
            }
            let mut final_state = session.state_tensors()?;
            final_state.truncate(p);
            params = final_state;
        }
        ExecPath::Reference => {
            let mut m = zeros_like(&params);
            let mut v = zeros_like(&params);
            let mut t = Tensor::f32(vec![0.0]);
            for _ in 0..calls {
                let mut inputs = Vec::with_capacity(3 * p + 4);
                inputs.extend(params.iter().cloned());
                inputs.extend(m.iter().cloned());
                inputs.extend(v.iter().cloned());
                inputs.push(t.clone());
                inputs.push(x.clone());
                inputs.push(y.clone());
                inputs.push(mask.clone());
                let mut out = train_exe.run(&inputs)?;
                let loss = out
                    .last()
                    .ok_or_else(|| Error::Runtime("train step returned no outputs".into()))?
                    .scalar_f32()?;
                losses.push(loss);
                t = out[3 * p].clone();
                v = out.drain(2 * p..3 * p).collect();
                m = out.drain(p..2 * p).collect();
                params = out.drain(..p).collect();
            }
        }
    }

    Ok(Classifier { params, losses, task, feat_dim: dims.f, classes: dims.c })
}

/// Run the trained classifier over the full store and score the val/test
/// splits.
pub fn evaluate_classifier(
    rt: &Runtime,
    dataset: &Dataset,
    store: &EmbeddingStore,
    clf: &Classifier,
) -> Result<EvalReport> {
    let n = store.n;
    let pred_exe = rt.load_for("mlp", clf.task, "pred", n, 0)?;
    let dims = pred_exe.meta.dims.clone();
    if dims.f != clf.feat_dim || dims.c != clf.classes {
        return Err(Error::Coordinator(format!(
            "pred artifact shape (f={}, c={}) differs from trained classifier \
             (f={}, c={})",
            dims.f, dims.c, clf.feat_dim, clf.classes
        )));
    }
    let x = padded_x(store, dims.n, dims.f);
    // params clones are refcount bumps; the single forward runs through a
    // stateless session (same staged-buffer path the trainer uses)
    let mut inputs = clf.params.clone();
    inputs.push(x);
    let mut session = rt.session(pred_exe, &[], &inputs)?;
    let out = session.run_outputs()?;
    let logits_full = out[0].as_f32()?;
    let c = dims.c;
    let logits = &logits_full[..n * c];

    let (test_metric, val_metric, metric_name) = match &dataset.labels {
        // NB: the artifact may have more logit columns than the dataset has
        // classes (bucketed class dim); argmax runs over the artifact's c —
        // a prediction in an unused class simply counts as wrong.
        Labels::Multiclass { labels, classes: _ } => (
            metrics::accuracy(logits, labels, &dataset.test_mask, c),
            metrics::accuracy(logits, labels, &dataset.val_mask, c),
            "accuracy",
        ),
        Labels::Multilabel { tasks, targets } => {
            if *tasks != c {
                return Err(Error::Coordinator(format!(
                    "multilabel artifact has {c} tasks, dataset has {tasks}"
                )));
            }
            (
                metrics::multilabel_auc(logits, targets, &dataset.test_mask, *tasks),
                metrics::multilabel_auc(logits, targets, &dataset.val_mask, *tasks),
                "roc-auc",
            )
        }
    };
    Ok(EvalReport {
        mlp_losses: clf.losses.clone(),
        test_metric,
        val_metric,
        metric_name,
    })
}

/// Train the integration MLP on the embeddings and evaluate on the splits
/// (the original offline path: train + evaluate, parameters discarded).
pub fn classify(
    rt: &Runtime,
    dataset: &Dataset,
    store: &EmbeddingStore,
    epochs: usize,
    seed: u64,
) -> Result<EvalReport> {
    // preflight the pred artifact so a train-only manifest fails before
    // the MLP training loop, not after (compilation is cached for the
    // evaluation pass)
    rt.load_for("mlp", dataset.labels.task_name(), "pred", store.n, 0)?;
    let clf = train_classifier(rt, dataset, store, epochs, seed)?;
    evaluate_classifier(rt, dataset, store, &clf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_tracks_coverage() {
        let mut s = EmbeddingStore::new(4, 2);
        assert!(!s.is_complete());
        s.insert(&[0, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.num_filled(), 2);
        s.insert(&[1, 3], &[5.0, 6.0, 7.0, 8.0]).unwrap();
        assert!(s.is_complete());
        assert_eq!(&s.matrix()[2..4], &[5.0, 6.0]);
    }

    #[test]
    fn store_rejects_double_insert() {
        let mut s = EmbeddingStore::new(2, 1);
        s.insert(&[0], &[1.0]).unwrap();
        assert!(s.insert(&[0], &[2.0]).is_err());
        // the original value survives the rejected overwrite
        assert_eq!(s.matrix()[0], 1.0);
    }

    #[test]
    fn store_rejects_bad_block_size() {
        let mut s = EmbeddingStore::new(2, 3);
        assert!(s.insert(&[0], &[1.0]).is_err());
    }

    #[test]
    fn failed_insert_leaves_store_unchanged() {
        let mut s = EmbeddingStore::new(4, 1);
        s.insert(&[0, 1], &[1.0, 2.0]).unwrap();
        // block [2, 0]: node 2 is fresh but node 0 is filled → whole block
        // must be rejected without writing node 2
        assert!(s.insert(&[2, 0], &[9.0, 9.0]).is_err());
        assert_eq!(s.num_filled(), 2, "partial write leaked through");
        assert_eq!(s.matrix()[0], 1.0);
        // the same fresh nodes still insert cleanly afterwards
        s.insert(&[2, 3], &[3.0, 4.0]).unwrap();
        assert!(s.is_complete());
        assert_eq!(s.matrix(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn store_rejects_out_of_range_node() {
        let mut s = EmbeddingStore::new(2, 1);
        assert!(s.insert(&[5], &[1.0]).is_err());
        assert_eq!(s.num_filled(), 0);
    }

    #[test]
    fn store_rejects_duplicate_within_block() {
        let mut s = EmbeddingStore::new(3, 1);
        assert!(s.insert(&[1, 1], &[1.0, 2.0]).is_err());
        assert_eq!(s.num_filled(), 0);
    }

    #[test]
    fn rows_of_extracts_in_order() {
        let mut s = EmbeddingStore::new(3, 2);
        s.insert(&[0, 1, 2], &[0.0, 1.0, 10.0, 11.0, 20.0, 21.0]).unwrap();
        assert_eq!(s.rows_of(&[2, 0]).unwrap(), vec![20.0, 21.0, 0.0, 1.0]);
        assert!(s.rows_of(&[9]).is_err());
    }

    #[test]
    fn rows_of_rejects_unfilled() {
        let mut s = EmbeddingStore::new(2, 1);
        s.insert(&[0], &[1.0]).unwrap();
        assert!(s.rows_of(&[1]).is_err());
    }
}
