//! Embedding integration (paper §5.2): assemble per-partition embeddings
//! into a global matrix, train the MLP classifier on it, and evaluate.
//!
//! Each node's embedding comes from the partition that *owns* it; replicas
//! are discarded by the trainer. The MLP stage runs on the leader after all
//! partitions finish — the only cross-partition data movement in the whole
//! pipeline, as in the paper.

use super::metrics;
use super::trainer::init_params;
use crate::data::{Dataset, Labels};
use crate::error::{Error, Result};
use crate::graph::NodeId;
use crate::runtime::{Runtime, Tensor};

/// Global embedding matrix under assembly.
pub struct EmbeddingStore {
    pub n: usize,
    pub dim: usize,
    data: Vec<f32>,
    filled: Vec<bool>,
}

impl EmbeddingStore {
    pub fn new(n: usize, dim: usize) -> Self {
        EmbeddingStore { n, dim, data: vec![0.0; n * dim], filled: vec![false; n] }
    }

    /// Write the owned-node embeddings of one partition.
    pub fn insert(&mut self, nodes: &[NodeId], emb: &[f32]) -> Result<()> {
        if emb.len() != nodes.len() * self.dim {
            return Err(Error::Coordinator(format!(
                "embedding block {} != {} nodes × dim {}",
                emb.len(),
                nodes.len(),
                self.dim
            )));
        }
        for (i, &v) in nodes.iter().enumerate() {
            let vi = v as usize;
            if self.filled[vi] {
                return Err(Error::Coordinator(format!("node {v} embedded twice")));
            }
            self.filled[vi] = true;
            self.data[vi * self.dim..(vi + 1) * self.dim]
                .copy_from_slice(&emb[i * self.dim..(i + 1) * self.dim]);
        }
        Ok(())
    }

    pub fn num_filled(&self) -> usize {
        self.filled.iter().filter(|&&b| b).count()
    }

    pub fn is_complete(&self) -> bool {
        self.filled.iter().all(|&b| b)
    }

    pub fn matrix(&self) -> &[f32] {
        &self.data
    }
}

/// Result of the classification stage.
#[derive(Clone, Debug)]
pub struct EvalReport {
    /// MLP losses per train call.
    pub mlp_losses: Vec<f32>,
    /// Accuracy (multiclass) or ROC-AUC (multilabel) on the test split.
    pub test_metric: f64,
    /// Same on the validation split.
    pub val_metric: f64,
    pub metric_name: &'static str,
}

/// Train the integration MLP on the embeddings and evaluate on the splits.
pub fn classify(
    rt: &Runtime,
    dataset: &Dataset,
    store: &EmbeddingStore,
    epochs: usize,
    seed: u64,
) -> Result<EvalReport> {
    if !store.is_complete() {
        return Err(Error::Coordinator(format!(
            "embedding store incomplete: {}/{} nodes",
            store.num_filled(),
            store.n
        )));
    }
    let n = store.n;
    let task = dataset.labels.task_name();
    let train_exe = rt.load_for("mlp", task, "train", n, 0)?;
    let pred_exe = rt.load_for("mlp", task, "pred", n, 0)?;
    let dims = train_exe.meta.dims.clone();
    if dims.f != store.dim {
        return Err(Error::Coordinator(format!(
            "MLP expects dim {} embeddings, store has {}",
            dims.f, store.dim
        )));
    }

    // pad embeddings/labels/mask to the MLP bucket
    let mut x = vec![0f32; dims.n * dims.f];
    x[..n * dims.f].copy_from_slice(store.matrix());
    let x = Tensor::F32(x);
    let y = match &dataset.labels {
        Labels::Multiclass { labels, .. } => {
            let mut yy = vec![0i32; dims.n];
            yy[..n].copy_from_slice(labels);
            Tensor::I32(yy)
        }
        Labels::Multilabel { tasks, targets } => {
            let mut yy = vec![0f32; dims.n * tasks];
            yy[..n * tasks].copy_from_slice(targets);
            Tensor::F32(yy)
        }
    };
    let mut mask = vec![0f32; dims.n];
    for v in 0..n {
        mask[v] = dataset.train_mask[v] as u8 as f32;
    }
    let mask = Tensor::F32(mask);

    let p = train_exe.meta.num_params();
    let mut params = init_params(&train_exe, seed);
    let mut m: Vec<Tensor> = params.iter().map(|t| Tensor::F32(vec![0.0; t.len()])).collect();
    let mut v: Vec<Tensor> = m.clone();
    let mut t = Tensor::F32(vec![0.0]);
    let calls = epochs.div_ceil(dims.epochs_per_call.max(1));
    let mut mlp_losses = Vec::with_capacity(calls);
    for _ in 0..calls {
        let mut inputs = Vec::with_capacity(3 * p + 4);
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(t.clone());
        inputs.push(x.clone());
        inputs.push(y.clone());
        inputs.push(mask.clone());
        let mut out = train_exe.run(&inputs)?;
        mlp_losses.push(out.last().unwrap().scalar_f32()?);
        t = out[3 * p].clone();
        v = out.drain(2 * p..3 * p).collect();
        m = out.drain(p..2 * p).collect();
        params = out.drain(..p).collect();
    }

    // ---- predict + evaluate ------------------------------------------
    let mut inputs = params;
    inputs.push(x);
    let out = pred_exe.run(&inputs)?;
    let logits_full = out[0].as_f32()?;
    let c = dims.c;
    let logits = &logits_full[..n * c];

    let (test_metric, val_metric, metric_name) = match &dataset.labels {
        // NB: the artifact may have more logit columns than the dataset has
        // classes (bucketed class dim); argmax runs over the artifact's c —
        // a prediction in an unused class simply counts as wrong.
        Labels::Multiclass { labels, classes: _ } => (
            metrics::accuracy(logits, labels, &dataset.test_mask, c),
            metrics::accuracy(logits, labels, &dataset.val_mask, c),
            "accuracy",
        ),
        Labels::Multilabel { tasks, targets } => {
            if *tasks != c {
                return Err(Error::Coordinator(format!(
                    "multilabel artifact has {c} tasks, dataset has {tasks}"
                )));
            }
            (
                metrics::multilabel_auc(logits, targets, &dataset.test_mask, *tasks),
                metrics::multilabel_auc(logits, targets, &dataset.val_mask, *tasks),
                "roc-auc",
            )
        }
    };
    Ok(EvalReport { mlp_losses, test_metric, val_metric, metric_name })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_tracks_coverage() {
        let mut s = EmbeddingStore::new(4, 2);
        assert!(!s.is_complete());
        s.insert(&[0, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.num_filled(), 2);
        s.insert(&[1, 3], &[5.0, 6.0, 7.0, 8.0]).unwrap();
        assert!(s.is_complete());
        assert_eq!(&s.matrix()[2..4], &[5.0, 6.0]);
    }

    #[test]
    fn store_rejects_double_insert() {
        let mut s = EmbeddingStore::new(2, 1);
        s.insert(&[0], &[1.0]).unwrap();
        assert!(s.insert(&[0], &[2.0]).is_err());
    }

    #[test]
    fn store_rejects_bad_block_size() {
        let mut s = EmbeddingStore::new(2, 3);
        assert!(s.insert(&[0], &[1.0]).is_err());
    }
}
