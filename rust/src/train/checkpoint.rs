//! Parameter checkpointing: binary save/load of flat tensor lists.
//!
//! Used by the coordinator to persist per-partition model state (resume
//! after a fault without retraining finished partitions) and by users to
//! keep trained models across runs. Format: `LFC1` magic, little-endian,
//! per-tensor dtype tag + element count + raw data, trailing crc32-less
//! length check (artifact integrity is the manifest's job; this guards
//! against truncation).

use crate::error::{Error, Result};
use crate::runtime::Tensor;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"LFC1";

/// Save a flat tensor list.
pub fn save_tensors(path: &Path, tensors: &[Tensor]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = BufWriter::new(std::fs::File::create(path)?);
    out.write_all(MAGIC)?;
    out.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        match t {
            Tensor::F32(v) => {
                out.write_all(&[0u8])?;
                out.write_all(&(v.len() as u64).to_le_bytes())?;
                for x in v.iter() {
                    out.write_all(&x.to_le_bytes())?;
                }
            }
            Tensor::I32(v) => {
                out.write_all(&[1u8])?;
                out.write_all(&(v.len() as u64).to_le_bytes())?;
                for x in v.iter() {
                    out.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    out.write_all(&(tensors.len() as u32).to_le_bytes())?; // trailer
    Ok(())
}

/// Load a flat tensor list.
pub fn load_tensors(path: &Path) -> Result<Vec<Tensor>> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Runtime(format!("{}: not an LFC1 checkpoint", path.display())));
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let count = u32::from_le_bytes(b4) as usize;
    let mut tensors = Vec::with_capacity(count);
    let mut b8 = [0u8; 8];
    for _ in 0..count {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        r.read_exact(&mut b8)?;
        let len = u64::from_le_bytes(b8) as usize;
        match tag[0] {
            0 => {
                let mut v = vec![0f32; len];
                for x in v.iter_mut() {
                    r.read_exact(&mut b4)?;
                    *x = f32::from_le_bytes(b4);
                }
                tensors.push(Tensor::f32(v));
            }
            1 => {
                let mut v = vec![0i32; len];
                for x in v.iter_mut() {
                    r.read_exact(&mut b4)?;
                    *x = i32::from_le_bytes(b4);
                }
                tensors.push(Tensor::i32(v));
            }
            t => return Err(Error::Runtime(format!("unknown tensor tag {t}"))),
        }
    }
    r.read_exact(&mut b4)?;
    if u32::from_le_bytes(b4) as usize != count {
        return Err(Error::Runtime("checkpoint truncated".into()));
    }
    Ok(tensors)
}

/// Checkpoint path for one partition of a named run.
pub fn partition_checkpoint_path(dir: &Path, run: &str, part_id: u32) -> std::path::PathBuf {
    dir.join(format!("{run}_part{part_id}.lfc"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("lf_ckpt_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_mixed_tensors() {
        let tensors = vec![
            Tensor::f32(vec![1.5, -2.25, 0.0]),
            Tensor::i32(vec![7, -9]),
            Tensor::f32(vec![]),
        ];
        let path = tmp("mixed.lfc");
        save_tensors(&path, &tensors).unwrap();
        let back = load_tensors(&path).unwrap();
        assert_eq!(tensors, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let path = tmp("bad.lfc");
        std::fs::write(&path, b"XXXX").unwrap();
        assert!(load_tensors(&path).is_err());
        // truncated: valid header, missing trailer
        let tensors = vec![Tensor::f32(vec![1.0; 10])];
        save_tensors(&path, &tensors).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 2]).unwrap();
        assert!(load_tensors(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn checkpoint_paths_are_distinct() {
        let d = std::path::Path::new("/tmp");
        assert_ne!(
            partition_checkpoint_path(d, "run", 0),
            partition_checkpoint_path(d, "run", 1)
        );
    }
}
