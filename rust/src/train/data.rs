//! Partition batch construction: turn a partition of a [`Dataset`] into the
//! padded tensors an AOT artifact consumes.
//!
//! This is where the GNN normalisation weights are computed (the L2 models
//! receive structure as a weighted COO edge list — see model.py):
//!
//! * **GCN** — self-loops plus symmetric normalisation
//!   `w(u→v) = a_uv / sqrt((1+d_u)(1+d_v))`, `w(v→v) = 1/(1+d_v)`,
//!   where `d` is the (weighted) degree. Kipf-style; paper eq. (1).
//! * **SAGE** — in-edge mean `w(u→v) = a_uv / d_in(v)`; the self path is a
//!   separate weight matrix inside the model (paper eq. (2)).
//!
//! Padding contract (property-tested against the python side): pad nodes
//! carry zero features and mask 0; pad edges are `(0, 0, 0.0)`.

use crate::data::{Dataset, Labels};
use crate::error::{Error, Result};
use crate::graph::{
    inner_subgraph_with, repli_subgraph_with, NodeId, Subgraph, SubgraphKind,
    SubgraphScratch,
};
use crate::runtime::Tensor;
use std::sync::Arc;

/// Inner vs Repli subgraph construction (paper §5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Inner,
    Repli,
}

impl Mode {
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Inner => "inner",
            Mode::Repli => "repli",
        }
    }

    /// The graph-layer extraction this mode maps to (for
    /// [`crate::graph::extract_subgraphs`]).
    pub fn kind(&self) -> crate::graph::SubgraphKind {
        match self {
            Mode::Inner => crate::graph::SubgraphKind::Inner,
            Mode::Repli => crate::graph::SubgraphKind::Repli,
        }
    }
}

/// Which GNN the batch is normalised for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Gcn,
    Sage,
}

impl ModelKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::Gcn => "gcn",
            ModelKind::Sage => "sage",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "gcn" => Ok(ModelKind::Gcn),
            "sage" => Ok(ModelKind::Sage),
            other => Err(Error::Config(format!("unknown model {other:?}"))),
        }
    }
}

/// Un-padded tensors for one partition.
#[derive(Clone, Debug)]
pub struct PartitionBatch {
    /// The local subgraph (owned nodes first, then replicas).
    pub sub: Subgraph,
    /// Directed COO edges with normalisation weights (self-loops included
    /// for GCN).
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    pub ew: Vec<f32>,
    /// Row-major `[n_local, f]` features.
    pub x: Vec<f32>,
    pub feat_dim: usize,
    /// Labels for local nodes (padded later).
    pub y: LabelSlice,
    /// Training mask: 1.0 for *owned* nodes in the dataset train split.
    pub train_mask: Vec<f32>,
}

/// Local label slice matching `Labels`.
#[derive(Clone, Debug)]
pub enum LabelSlice {
    Multiclass(Vec<i32>),
    Multilabel { tasks: usize, targets: Vec<f32> },
}

impl PartitionBatch {
    pub fn num_local(&self) -> usize {
        self.sub.nodes.len()
    }

    pub fn num_directed_edges(&self) -> usize {
        self.src.len()
    }
}

/// Build the batch for `members` of `dataset`.
pub fn build_batch(
    dataset: &Dataset,
    members: &[NodeId],
    mode: Mode,
    model: ModelKind,
) -> Result<PartitionBatch> {
    build_batch_with(dataset, members, mode, model, &mut SubgraphScratch::new())
}

/// [`build_batch`] with a caller-provided extraction scratch — workers
/// that build batches for many partitions (the coordinator's machine
/// loop) reuse one dense id map instead of re-allocating per partition.
pub fn build_batch_with(
    dataset: &Dataset,
    members: &[NodeId],
    mode: Mode,
    model: ModelKind,
    scratch: &mut SubgraphScratch,
) -> Result<PartitionBatch> {
    let sub = match mode.kind() {
        SubgraphKind::Inner => inner_subgraph_with(&dataset.graph, members, scratch)?,
        SubgraphKind::Repli => repli_subgraph_with(&dataset.graph, members, scratch)?,
    };
    let g = &sub.graph;
    let nl = g.num_nodes();
    let f = dataset.feat_dim;

    // ---- features --------------------------------------------------------
    let mut x = vec![0f32; nl * f];
    for (local, &global) in sub.nodes.iter().enumerate() {
        x[local * f..(local + 1) * f].copy_from_slice(dataset.feature_row(global));
    }

    // ---- normalisation weights -------------------------------------------
    let wdeg: Vec<f64> = (0..nl as NodeId).map(|v| g.weighted_degree(v)).collect();
    let mut src = Vec::new();
    let mut dst = Vec::new();
    let mut ew = Vec::new();
    match model {
        ModelKind::Gcn => {
            src.reserve(2 * g.num_edges() + nl);
            for u in 0..nl as NodeId {
                for (i, &v) in g.neighbors(u).iter().enumerate() {
                    let w = g.weight_at(u, i) as f64;
                    // directed u→v (aggregated into v)
                    let norm = w / ((1.0 + wdeg[u as usize]) * (1.0 + wdeg[v as usize]))
                        .sqrt();
                    src.push(u as i32);
                    dst.push(v as i32);
                    ew.push(norm as f32);
                }
                // self loop
                src.push(u as i32);
                dst.push(u as i32);
                ew.push((1.0 / (1.0 + wdeg[u as usize])) as f32);
            }
        }
        ModelKind::Sage => {
            src.reserve(2 * g.num_edges());
            for v in 0..nl as NodeId {
                let d = wdeg[v as usize].max(f64::MIN_POSITIVE);
                for (i, &u) in g.neighbors(v).iter().enumerate() {
                    let w = g.weight_at(v, i) as f64;
                    // u→v mean aggregation
                    src.push(u as i32);
                    dst.push(v as i32);
                    ew.push((w / d) as f32);
                }
            }
        }
    }

    // ---- labels + mask ---------------------------------------------------
    let y = match &dataset.labels {
        Labels::Multiclass { labels, .. } => LabelSlice::Multiclass(
            sub.nodes.iter().map(|&v| labels[v as usize]).collect(),
        ),
        Labels::Multilabel { tasks, targets } => {
            let mut t = Vec::with_capacity(nl * tasks);
            for &v in &sub.nodes {
                t.extend_from_slice(&targets[v as usize * tasks..(v as usize + 1) * tasks]);
            }
            LabelSlice::Multilabel { tasks: *tasks, targets: t }
        }
    };
    let train_mask: Vec<f32> = sub
        .nodes
        .iter()
        .enumerate()
        .map(|(local, &global)| {
            // replicas never contribute to the loss
            (sub.is_owned(local) && dataset.train_mask[global as usize]) as u8 as f32
        })
        .collect();

    Ok(PartitionBatch {
        sub,
        src,
        dst,
        ew,
        x,
        feat_dim: f,
        y,
        train_mask,
    })
}

/// Reusable padding buffers for [`pad_to_bucket_with`].
///
/// Padded tensors are `Arc`-backed; the scratch keeps one reference to
/// every buffer it hands out. When the previous call's [`PaddedTensors`]
/// have been dropped (refcount back to one) and the bucket size matches,
/// the allocation is rewritten **in place** — only the stale pad tail is
/// re-zeroed — so coordinator retries and workers that train several
/// partitions against the same bucket stop reallocating multi-megabyte
/// padded buffers per job. While previously handed-out tensors are still
/// alive the scratch falls back to a fresh allocation, which is what
/// keeps the tensors themselves immutable. Either way the output is
/// byte-identical to a fresh [`pad_to_bucket`] (property-tested).
pub struct PadScratch {
    x: Arc<[f32]>,
    src: Arc<[i32]>,
    dst: Arc<[i32]>,
    ew: Arc<[f32]>,
    y_f: Arc<[f32]>,
    y_i: Arc<[i32]>,
    mask: Arc<[f32]>,
}

impl PadScratch {
    pub fn new() -> PadScratch {
        PadScratch {
            x: Arc::from(Vec::new()),
            src: Arc::from(Vec::new()),
            dst: Arc::from(Vec::new()),
            ew: Arc::from(Vec::new()),
            y_f: Arc::from(Vec::new()),
            y_i: Arc::from(Vec::new()),
            mask: Arc::from(Vec::new()),
        }
    }
}

impl Default for PadScratch {
    fn default() -> Self {
        PadScratch::new()
    }
}

/// Hand out a uniquely owned `len`-element buffer from `slot`, reusing
/// the existing allocation when the size matches and no previous tensor
/// still references it. The caller overwrites exactly `[..live]`; the pad
/// tail `[live..]` is zeroed here.
fn reuse_slot<T: Copy + Default>(
    slot: &mut Arc<[T]>,
    len: usize,
    live: usize,
) -> &mut [T] {
    if slot.len() != len || Arc::get_mut(slot).is_none() {
        *slot = vec![T::default(); len].into();
    }
    // lint: allow(panic_in_lib) — infallible: the branch above replaces any shared or wrong-size allocation with a fresh unique one
    let buf = Arc::get_mut(slot).expect("uniquely owned after the reset above");
    buf[live..].fill(T::default());
    buf
}

/// Pad the batch tensors to artifact buckets `(n_bucket, e_bucket)` and
/// return them in the artifact's input layout (x, src, dst, ew, y, mask).
///
/// Allocates fresh buffers every call; hot paths (the coordinator worker
/// loop) use [`pad_to_bucket_with`] and a per-worker [`PadScratch`].
pub fn pad_to_bucket(
    batch: &PartitionBatch,
    n_bucket: usize,
    e_bucket: usize,
    classes: usize,
) -> Result<PaddedTensors> {
    pad_to_bucket_with(batch, n_bucket, e_bucket, classes, &mut PadScratch::new())
}

/// [`pad_to_bucket`] against a caller-provided [`PadScratch`] so repeat
/// pads against the same bucket reuse their allocations.
pub fn pad_to_bucket_with(
    batch: &PartitionBatch,
    n_bucket: usize,
    e_bucket: usize,
    classes: usize,
    scratch: &mut PadScratch,
) -> Result<PaddedTensors> {
    let nl = batch.num_local();
    let el = batch.num_directed_edges();
    if nl > n_bucket || el > e_bucket {
        return Err(Error::Runtime(format!(
            "partition ({nl} nodes / {el} edges) exceeds bucket \
             ({n_bucket} / {e_bucket})"
        )));
    }
    let f = batch.feat_dim;
    let x = reuse_slot(&mut scratch.x, n_bucket * f, nl * f);
    x[..nl * f].copy_from_slice(&batch.x);
    let src = reuse_slot(&mut scratch.src, e_bucket, el);
    src[..el].copy_from_slice(&batch.src);
    let dst = reuse_slot(&mut scratch.dst, e_bucket, el);
    dst[..el].copy_from_slice(&batch.dst);
    let ew = reuse_slot(&mut scratch.ew, e_bucket, el);
    ew[..el].copy_from_slice(&batch.ew);
    let mask = reuse_slot(&mut scratch.mask, n_bucket, nl);
    mask[..nl].copy_from_slice(&batch.train_mask);
    let y = match &batch.y {
        LabelSlice::Multiclass(labels) => {
            let yy = reuse_slot(&mut scratch.y_i, n_bucket, nl);
            yy[..nl].copy_from_slice(labels);
            Tensor::I32(Arc::clone(&scratch.y_i))
        }
        LabelSlice::Multilabel { tasks, targets } => {
            debug_assert_eq!(*tasks, classes);
            let yy = reuse_slot(&mut scratch.y_f, n_bucket * classes, nl * classes);
            yy[..nl * classes].copy_from_slice(targets);
            Tensor::F32(Arc::clone(&scratch.y_f))
        }
    };
    Ok(PaddedTensors {
        x: Tensor::F32(Arc::clone(&scratch.x)),
        src: Tensor::I32(Arc::clone(&scratch.src)),
        dst: Tensor::I32(Arc::clone(&scratch.dst)),
        ew: Tensor::F32(Arc::clone(&scratch.ew)),
        y,
        mask: Tensor::F32(Arc::clone(&scratch.mask)),
    })
}

/// Bucket-padded artifact inputs.
pub struct PaddedTensors {
    pub x: Tensor,
    pub src: Tensor,
    pub dst: Tensor,
    pub ew: Tensor,
    pub y: Tensor,
    pub mask: Tensor,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::karate_dataset;

    #[test]
    fn gcn_weights_sum_to_one_per_destination() {
        let ds = karate_dataset(0);
        let members: Vec<NodeId> = (0..34).collect();
        let b = build_batch(&ds, &members, Mode::Inner, ModelKind::Gcn).unwrap();
        // sym-norm weights are positive; the self-loop weight is 1/(1+d_v)
        let g = &ds.graph;
        let mut self_w = vec![0f32; 34];
        for (i, (&s, &d)) in b.src.iter().zip(&b.dst).enumerate() {
            assert!(b.ew[i] > 0.0, "nonpositive weight at edge {i}");
            if s == d {
                self_w[s as usize] = b.ew[i];
            }
        }
        for v in 0..34u32 {
            let expect = 1.0 / (1.0 + g.degree(v) as f32);
            assert!((self_w[v as usize] - expect).abs() < 1e-6, "node {v}");
        }
        // self loops included: e = 2m + n
        assert_eq!(b.num_directed_edges(), 2 * 78 + 34);
    }

    #[test]
    fn sage_weights_are_means() {
        let ds = karate_dataset(0);
        let members: Vec<NodeId> = (0..34).collect();
        let b = build_batch(&ds, &members, Mode::Inner, ModelKind::Sage).unwrap();
        assert_eq!(b.num_directed_edges(), 2 * 78);
        let mut sums = vec![0f64; 34];
        for (i, &d) in b.dst.iter().enumerate() {
            sums[d as usize] += b.ew[i] as f64;
        }
        for (v, &s) in sums.iter().enumerate() {
            assert!((s - 1.0).abs() < 1e-5, "node {v}: mean weights sum {s}");
        }
    }

    #[test]
    fn repli_mask_excludes_replicas_and_non_train() {
        let ds = karate_dataset(0);
        let members: Vec<NodeId> = (0..10).collect();
        let b = build_batch(&ds, &members, Mode::Repli, ModelKind::Gcn).unwrap();
        assert!(b.sub.num_replicas() > 0);
        for local in b.sub.num_owned..b.num_local() {
            assert_eq!(b.train_mask[local], 0.0, "replica {local} in mask");
        }
        for local in 0..b.sub.num_owned {
            let global = b.sub.nodes[local] as usize;
            assert_eq!(b.train_mask[local] > 0.5, ds.train_mask[global]);
        }
    }

    #[test]
    fn features_copied_per_local_node() {
        let ds = karate_dataset(0);
        let members = vec![5u32, 17, 2];
        let b = build_batch(&ds, &members, Mode::Inner, ModelKind::Gcn).unwrap();
        for (local, &global) in b.sub.nodes.iter().enumerate() {
            assert_eq!(
                &b.x[local * b.feat_dim..(local + 1) * b.feat_dim],
                ds.feature_row(global)
            );
        }
    }

    #[test]
    fn padding_layout() {
        let ds = karate_dataset(0);
        let members: Vec<NodeId> = (0..34).collect();
        let b = build_batch(&ds, &members, Mode::Inner, ModelKind::Gcn).unwrap();
        let p = pad_to_bucket(&b, 64, 256, 2).unwrap();
        assert_eq!(p.x.len(), 64 * 8);
        assert_eq!(p.src.len(), 256);
        // pad region zeros
        let ew = p.ew.as_f32().unwrap();
        assert!(ew[b.num_directed_edges()..].iter().all(|&w| w == 0.0));
        let mask = p.mask.as_f32().unwrap();
        assert!(mask[34..].iter().all(|&m| m == 0.0));
        // too-small bucket errors
        assert!(pad_to_bucket(&b, 16, 256, 2).is_err());
    }

    fn assert_padded_eq(a: &PaddedTensors, b: &PaddedTensors) -> Result<(), String> {
        for (name, x, y) in [
            ("x", &a.x, &b.x),
            ("src", &a.src, &b.src),
            ("dst", &a.dst, &b.dst),
            ("ew", &a.ew, &b.ew),
            ("y", &a.y, &b.y),
            ("mask", &a.mask, &b.mask),
        ] {
            if x != y {
                return Err(format!("{name} differs between scratch and fresh pad"));
            }
        }
        Ok(())
    }

    #[test]
    fn pad_scratch_reuse_is_byte_identical_to_fresh() {
        // One scratch carried across random batches, modes, models, and
        // bucket sizes (size changes force reallocation mid-sequence) must
        // produce exactly what a fresh allocation produces.
        let ds = karate_dataset(0);
        let scratch = std::cell::RefCell::new(PadScratch::new());
        crate::testing::prop::check(
            "pad-scratch-reuse",
            40,
            11,
            |rng| {
                let n = 4 + rng.index(30);
                let mut members: Vec<NodeId> = (0..34).collect();
                for i in 0..n {
                    let j = i + rng.index(34 - i);
                    members.swap(i, j);
                }
                members.truncate(n);
                let mode = if rng.index(2) == 0 { Mode::Inner } else { Mode::Repli };
                let model =
                    if rng.index(2) == 0 { ModelKind::Gcn } else { ModelKind::Sage };
                let nb = 64 + 32 * rng.index(3);
                let eb = 512 + 256 * rng.index(2);
                (members, mode, model, nb, eb)
            },
            |(members, mode, model, nb, eb)| {
                let b = build_batch(&ds, members, *mode, *model)
                    .map_err(|e| e.to_string())?;
                let fresh = pad_to_bucket(&b, *nb, *eb, 2).map_err(|e| e.to_string())?;
                let reused =
                    pad_to_bucket_with(&b, *nb, *eb, 2, &mut scratch.borrow_mut())
                        .map_err(|e| e.to_string())?;
                assert_padded_eq(&reused, &fresh)
            },
        );
    }

    #[test]
    fn pad_scratch_reuses_allocation_when_tensors_dropped() {
        let ds = karate_dataset(0);
        let members: Vec<NodeId> = (0..34).collect();
        let b = build_batch(&ds, &members, Mode::Inner, ModelKind::Gcn).unwrap();
        let mut scratch = PadScratch::new();
        let first = pad_to_bucket_with(&b, 64, 256, 2, &mut scratch).unwrap();
        let first_ptr = first.x.as_f32().unwrap().as_ptr();
        drop(first);
        // previous tensors gone → same allocation comes back
        let second = pad_to_bucket_with(&b, 64, 256, 2, &mut scratch).unwrap();
        assert_eq!(
            second.x.as_f32().unwrap().as_ptr(),
            first_ptr,
            "scratch did not reuse the dropped buffer"
        );
        // previous tensors alive → fresh allocation, old tensor untouched
        let snapshot = second.x.as_f32().unwrap().to_vec();
        let third = pad_to_bucket_with(&b, 64, 256, 2, &mut scratch).unwrap();
        assert!(
            !third.x.shares_storage(&second.x),
            "live tensor must not be rewritten in place"
        );
        assert_eq!(second.x.as_f32().unwrap(), &snapshot[..]);
    }
}
