//! Named counters, gauges, and log-bucketed histograms in a process-wide
//! registry, snapshotable as JSON and Prometheus-style text.
//!
//! Handles are `Arc`-backed and lock-free to update: counters and gauges
//! are single relaxed atomics, histograms add one `ln` plus two atomic
//! adds per sample. The [`Registry`] keeps every instance alive forever
//! (Prometheus-style — metrics never disappear mid-run).
//!
//! ## Shared vs owned instances
//!
//! `registry().counter("x")` returns a handle to **the** instance named
//! `x` — every caller shares it. `owned_counter("x")` appends a **fresh**
//! instance under the same name and hands it out exclusively; snapshots
//! sum (counters) or merge (histograms) across instances. Owned instances
//! are how per-object stats (each serve `Engine`, each `ExecSession`)
//! keep their local view — `EngineStats` reads its own instances — while
//! `repro metrics` still sees one aggregate per name.
//!
//! ## Histogram error bound
//!
//! Buckets are logarithmic: bucket `i` covers `[MIN·γ^i, MIN·γ^(i+1))`
//! with `γ = 1.0201` and representative value `MIN·γ^(i+0.5)`. For any
//! recorded `v` in `[MIN, MAX]`, the representative `r` of its bucket
//! satisfies `γ^-0.5 < r/v ≤ γ^0.5`, i.e. relative error ≤ `√γ − 1 =
//! 1%` exactly (1.01² = 1.0201). Quantiles pick the same rank as a
//! sorted oracle (`round((n−1)·q)`), so a quantile estimate is within 1%
//! of the exact order statistic — property-tested below. Values below
//! `MIN = 1e-9` s clamp to bucket 0, values above `MAX = 1e6` s clamp to
//! the last bucket; outside `[MIN, MAX]` the bound does not apply.

use crate::util::json::{num, Json};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Smallest representable sample (seconds): 1 ns.
const MIN: f64 = 1e-9;
/// Bucket growth factor; √γ − 1 = exactly 1% relative error.
const GAMMA: f64 = 1.0201;
/// `ceil(ln(1e6 / 1e-9) / ln γ)` — buckets spanning 1 ns ..= ~11.6 days.
const NBUCKETS: usize = 1736;

/// Monotone counter. Cloning shares the underlying cell.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn new() -> Self {
        Counter(Arc::new(AtomicU64::new(0)))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

/// Last-write-wins float value (f64 bits in an atomic).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn new() -> Self {
        Gauge(Arc::new(AtomicU64::new(0.0f64.to_bits())))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

/// CAS-accumulate `v` into an f64 stored as bits.
fn add_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

struct HistCore {
    count: AtomicU64,
    sum_bits: AtomicU64,
    buckets: Vec<AtomicU64>,
}

/// Log-bucketed histogram of seconds (see module docs for the 1%
/// relative-error bound). Cloning shares the underlying buckets.
#[derive(Clone)]
pub struct Histogram(Arc<HistCore>);

fn bucket_index(v: f64) -> usize {
    if v <= MIN {
        return 0;
    }
    let i = ((v / MIN).ln() / GAMMA.ln()).floor();
    (i as usize).min(NBUCKETS - 1)
}

fn representative(i: usize) -> f64 {
    MIN * ((i as f64 + 0.5) * GAMMA.ln()).exp()
}

impl Histogram {
    pub fn new() -> Self {
        Histogram(Arc::new(HistCore {
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }))
    }

    /// Record one sample (seconds).
    #[inline]
    pub fn record(&self, v: f64) {
        self.0.count.fetch_add(1, Ordering::Relaxed);
        add_f64(&self.0.sum_bits, v);
        self.0.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Exact running sum of all recorded samples (seconds) — this is the
    /// one histogram read that carries no bucketing error, which is why
    /// stage-seconds fields (`EngineStats`, `ExecStats`) can be views
    /// over histograms without changing their reported totals.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Copy out a consistent-enough snapshot (relaxed reads; exact once
    /// writers are quiescent).
    pub fn snapshot(&self) -> HistogramData {
        HistogramData {
            count: self.count(),
            sum: self.sum(),
            buckets: self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }

    /// Quantile estimate over everything recorded so far (0 if empty).
    pub fn quantile(&self, q: f64) -> f64 {
        self.snapshot().quantile(q)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Plain-data histogram snapshot; merging snapshots is exactly the
/// histogram of the concatenated samples (property-tested below).
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramData {
    pub count: u64,
    pub sum: f64,
    pub buckets: Vec<u64>,
}

impl HistogramData {
    pub fn empty() -> Self {
        HistogramData { count: 0, sum: 0.0, buckets: vec![0; NBUCKETS] }
    }

    pub fn merge(&mut self, other: &HistogramData) {
        self.count += other.count;
        self.sum += other.sum;
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }

    /// Representative value of the bucket holding the rank-`round((n−1)q)`
    /// sample — the same rank rule as `benchkit::Stats`, so estimates are
    /// comparable to a sorted oracle.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((self.count - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen > target {
                return representative(i);
            }
        }
        representative(NBUCKETS - 1)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

enum Family {
    Counter(Vec<Counter>),
    Gauge(Vec<Gauge>),
    Histogram(Vec<Histogram>),
}

impl Family {
    fn kind(&self) -> &'static str {
        match self {
            Family::Counter(_) => "counter",
            Family::Gauge(_) => "gauge",
            Family::Histogram(_) => "histogram",
        }
    }
}

/// Named metric families. One process-wide instance lives behind
/// [`registry`]; `Registry::new()` exists for tests.
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry { families: Mutex::new(BTreeMap::new()) }
    }

    fn with_family<T>(
        &self,
        name: &str,
        make: impl FnOnce() -> Family,
        pick: impl FnOnce(&mut Family) -> T,
    ) -> T {
        // recover a poisoned registry: families hold only complete
        // metric handles, and metrics must survive a panicking worker
        let mut fams = self
            .families
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let fam = fams.entry(name.to_string()).or_insert_with(make);
        pick(fam)
    }

    /// The shared counter named `name` (created on first use). Panics if
    /// `name` is already registered as a different metric kind — names
    /// are static strings in code, so that is a programming error.
    pub fn counter(&self, name: &str) -> Counter {
        self.with_family(name, || Family::Counter(vec![Counter::new()]), |f| match f {
            Family::Counter(v) => v[0].clone(),
            // lint: allow(panic_in_lib) — kind mismatch on a static metric name is a programming error, caught by any test touching the path
            other => panic!("metric {name:?} is a {}, not a counter", other.kind()),
        })
    }

    /// A fresh counter instance under `name`, exclusive to the caller;
    /// snapshots report the sum over all instances.
    pub fn owned_counter(&self, name: &str) -> Counter {
        self.with_family(name, || Family::Counter(Vec::new()), |f| match f {
            Family::Counter(v) => {
                let c = Counter::new();
                v.push(c.clone());
                c
            }
            // lint: allow(panic_in_lib) — kind mismatch on a static metric name is a programming error, caught by any test touching the path
            other => panic!("metric {name:?} is a {}, not a counter", other.kind()),
        })
    }

    /// The shared gauge named `name` (created on first use).
    pub fn gauge(&self, name: &str) -> Gauge {
        self.with_family(name, || Family::Gauge(vec![Gauge::new()]), |f| match f {
            Family::Gauge(v) => v[0].clone(),
            // lint: allow(panic_in_lib) — kind mismatch on a static metric name is a programming error, caught by any test touching the path
            other => panic!("metric {name:?} is a {}, not a gauge", other.kind()),
        })
    }

    /// The shared histogram named `name` (created on first use).
    pub fn histogram(&self, name: &str) -> Histogram {
        self.with_family(name, || Family::Histogram(vec![Histogram::new()]), |f| match f {
            Family::Histogram(v) => v[0].clone(),
            // lint: allow(panic_in_lib) — kind mismatch on a static metric name is a programming error, caught by any test touching the path
            other => panic!("metric {name:?} is a {}, not a histogram", other.kind()),
        })
    }

    /// A fresh histogram instance under `name`; snapshots merge all
    /// instances (merge == histogram of concatenation).
    pub fn owned_histogram(&self, name: &str) -> Histogram {
        self.with_family(name, || Family::Histogram(Vec::new()), |f| match f {
            Family::Histogram(v) => {
                let h = Histogram::new();
                v.push(h.clone());
                h
            }
            // lint: allow(panic_in_lib) — kind mismatch on a static metric name is a programming error, caught by any test touching the path
            other => panic!("metric {name:?} is a {}, not a histogram", other.kind()),
        })
    }

    /// Aggregate every family: counters sum, gauges sum, histograms
    /// merge.
    fn aggregate(&self) -> Vec<(String, Aggregated)> {
        let fams = self
            .families
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        fams.iter()
            .map(|(name, fam)| {
                let agg = match fam {
                    Family::Counter(v) => {
                        Aggregated::Counter(v.iter().map(|c| c.get()).sum())
                    }
                    Family::Gauge(v) => Aggregated::Gauge(v.iter().map(|g| g.get()).sum()),
                    Family::Histogram(v) => {
                        let mut data = HistogramData::empty();
                        for h in v {
                            data.merge(&h.snapshot());
                        }
                        Aggregated::Histogram(data)
                    }
                };
                (name.clone(), agg)
            })
            .collect()
    }

    /// JSON snapshot: `{"counters": {..}, "gauges": {..},
    /// "histograms": {name: {count, sum, mean, p50, p95, p99, p999}}}`.
    pub fn snapshot_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut hists = BTreeMap::new();
        for (name, agg) in self.aggregate() {
            match agg {
                Aggregated::Counter(n) => {
                    counters.insert(name, num(n as f64));
                }
                Aggregated::Gauge(v) => {
                    gauges.insert(name, num(v));
                }
                Aggregated::Histogram(d) => {
                    let h = Json::Obj(BTreeMap::from([
                        ("count".to_string(), num(d.count as f64)),
                        ("sum".to_string(), num(d.sum)),
                        ("mean".to_string(), num(d.mean())),
                        ("p50".to_string(), num(d.quantile(0.50))),
                        ("p95".to_string(), num(d.quantile(0.95))),
                        ("p99".to_string(), num(d.quantile(0.99))),
                        ("p999".to_string(), num(d.quantile(0.999))),
                    ]));
                    hists.insert(name, h);
                }
            }
        }
        Json::Obj(BTreeMap::from([
            ("counters".to_string(), Json::Obj(counters)),
            ("gauges".to_string(), Json::Obj(gauges)),
            ("histograms".to_string(), Json::Obj(hists)),
        ]))
    }

    /// Prometheus-style exposition text: counters and gauges as single
    /// samples, histograms as summaries with quantile labels.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, agg) in self.aggregate() {
            let pname = sanitize(&name);
            match agg {
                Aggregated::Counter(n) => {
                    out.push_str(&format!("# TYPE {pname} counter\n{pname} {n}\n"));
                }
                Aggregated::Gauge(v) => {
                    out.push_str(&format!("# TYPE {pname} gauge\n{pname} {v}\n"));
                }
                Aggregated::Histogram(d) => {
                    out.push_str(&format!("# TYPE {pname} summary\n"));
                    for (label, q) in
                        [("0.5", 0.5), ("0.95", 0.95), ("0.99", 0.99), ("0.999", 0.999)]
                    {
                        out.push_str(&format!(
                            "{pname}{{quantile=\"{label}\"}} {}\n",
                            d.quantile(q)
                        ));
                    }
                    out.push_str(&format!("{pname}_sum {}\n", d.sum));
                    out.push_str(&format!("{pname}_count {}\n", d.count));
                }
            }
        }
        out
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

enum Aggregated {
    Counter(u64),
    Gauge(f64),
    Histogram(HistogramData),
}

/// Prometheus metric names allow `[a-zA-Z0-9_:]`; map everything else
/// (our `.`-separated names) to `_`.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect()
}

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::check;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(-2.5);
        assert_eq!(g.get(), -2.5);
    }

    #[test]
    fn histogram_sum_is_exact_and_count_tracks() {
        let h = Histogram::new();
        for v in [0.001, 0.002, 0.003] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        assert!((h.sum() - 0.006).abs() < 1e-12);
        let p50 = h.quantile(0.5);
        assert!((p50 - 0.002).abs() / 0.002 <= 0.0101, "p50 {p50}");
    }

    #[test]
    fn histogram_clamps_out_of_range() {
        let h = Histogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(1e12);
        assert_eq!(h.count(), 3);
        let d = h.snapshot();
        assert_eq!(d.buckets[0], 2);
        assert_eq!(d.buckets[NBUCKETS - 1], 1);
    }

    #[test]
    fn empty_histogram_quantile_is_zero() {
        assert_eq!(Histogram::new().quantile(0.5), 0.0);
        assert_eq!(HistogramData::empty().quantile(0.99), 0.0);
    }

    // Satellite: quantile estimates vs an exact sorted oracle, within the
    // documented √γ − 1 = 1% relative-error bound.
    #[test]
    fn prop_quantiles_match_sorted_oracle_within_bound() {
        check(
            "hist-quantile-vs-oracle",
            40,
            11,
            |rng| {
                let n = 1 + rng.index(200);
                // log-uniform over ~1e-8 .. 1e4 seconds
                (0..n).map(|_| 10f64.powf(rng.f64() * 12.0 - 8.0)).collect::<Vec<f64>>()
            },
            |samples| {
                let h = Histogram::new();
                for &v in samples {
                    h.record(v);
                }
                let mut sorted = samples.clone();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let n = sorted.len();
                for q in [0.5, 0.95, 0.99, 0.999] {
                    let oracle = sorted[((n as f64 - 1.0) * q).round() as usize];
                    let est = h.quantile(q);
                    let rel = (est - oracle).abs() / oracle;
                    if rel > 0.0101 {
                        return Err(format!(
                            "q={q}: est {est} vs oracle {oracle} (rel err {rel:.4})"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    // Satellite: merge-of-histograms == histogram-of-concatenation,
    // exactly on counts and buckets (sum is float-add-order sensitive,
    // so approximately there).
    #[test]
    fn prop_merge_equals_concatenation() {
        check(
            "hist-merge-vs-concat",
            40,
            23,
            |rng| {
                let n = rng.index(150);
                let split = if n == 0 { 0 } else { rng.index(n + 1) };
                let all: Vec<f64> =
                    (0..n).map(|_| 10f64.powf(rng.f64() * 12.0 - 8.0)).collect();
                (all, split)
            },
            |(all, split)| {
                let (h1, h2, hcat) = (Histogram::new(), Histogram::new(), Histogram::new());
                for (i, &v) in all.iter().enumerate() {
                    if i < *split {
                        h1.record(v);
                    } else {
                        h2.record(v);
                    }
                    hcat.record(v);
                }
                let mut merged = h1.snapshot();
                merged.merge(&h2.snapshot());
                let cat = hcat.snapshot();
                if merged.count != cat.count {
                    return Err(format!("count {} vs {}", merged.count, cat.count));
                }
                if merged.buckets != cat.buckets {
                    return Err("bucket mismatch".to_string());
                }
                let tol = 1e-9 * cat.sum.abs().max(1e-30);
                if (merged.sum - cat.sum).abs() > tol {
                    return Err(format!("sum {} vs {}", merged.sum, cat.sum));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn registry_shared_vs_owned_instances() {
        let reg = Registry::new();
        let a = reg.counter("shared.hits");
        let b = reg.counter("shared.hits");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "shared handles alias one cell");

        let o1 = reg.owned_counter("owned.hits");
        let o2 = reg.owned_counter("owned.hits");
        o1.add(3);
        o2.add(4);
        assert_eq!(o1.get(), 3, "owned instances are private");
        assert_eq!(o2.get(), 4);

        let snap = reg.snapshot_json();
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.get("shared.hits").unwrap().as_f64(), Some(2.0));
        assert_eq!(counters.get("owned.hits").unwrap().as_f64(), Some(7.0));
    }

    #[test]
    fn registry_merges_owned_histograms_in_snapshot() {
        let reg = Registry::new();
        let h1 = reg.owned_histogram("stage.secs");
        let h2 = reg.owned_histogram("stage.secs");
        h1.record(0.010);
        h2.record(0.020);
        let snap = reg.snapshot_json();
        let h = snap.get("histograms").unwrap().get("stage.secs").unwrap();
        assert_eq!(h.get("count").unwrap().as_f64(), Some(2.0));
        assert!((h.get("sum").unwrap().as_f64().unwrap() - 0.030).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "not a counter")]
    fn registry_rejects_kind_mismatch() {
        let reg = Registry::new();
        let _ = reg.histogram("x");
        let _ = reg.counter("x");
    }

    #[test]
    fn prometheus_rendering_shape() {
        let reg = Registry::new();
        reg.counter("serve.requests").add(9);
        reg.gauge("parts").set(4.0);
        let h = reg.histogram("serve.gather_secs");
        h.record(0.001);
        h.record(0.002);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE serve_requests counter\nserve_requests 9\n"));
        assert!(text.contains("# TYPE parts gauge\nparts 4\n"));
        assert!(text.contains("# TYPE serve_gather_secs summary\n"));
        assert!(text.contains("serve_gather_secs{quantile=\"0.5\"}"));
        assert!(text.contains("serve_gather_secs_count 2\n"));
    }

    #[test]
    fn snapshot_json_parses_back() {
        let reg = Registry::new();
        reg.counter("a.b").inc();
        reg.histogram("c.d").record(0.5);
        let text = reg.snapshot_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        assert!(parsed.get("counters").is_some());
        assert!(parsed.get("histograms").unwrap().get("c.d").unwrap().get("p999").is_some());
    }
}
