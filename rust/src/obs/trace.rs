//! Thread-aware span tracer with Chrome `trace_event` export.
//!
//! A [`Span`] is an RAII guard: [`span`] stamps the start time, `Drop`
//! stamps the duration and records a complete ("X") event. Each thread
//! appends to its own fixed-capacity ring buffer; a full buffer — or the
//! thread exiting — drains into the global collector, so the hot path
//! never contends on a lock. [`write_chrome_trace`] serializes the
//! collector as `{"traceEvents": [...]}`, loadable in `about:tracing` or
//! Perfetto; nesting is reconstructed from timestamps per thread id.
//!
//! Tracing is off unless [`set_enabled`]`(true)` ran (the CLI does this
//! when `--trace-out` / `[obs] trace` is set). The off path is a single
//! relaxed atomic load: no clock read, no allocation, no thread-local
//! access (`micro_hotpath`'s "obs span (disabled)" entry measures it,
//! next to the bare-load floor it is specified against). Spans
//! only observe the instrumented code — timestamps never feed back into
//! results — so determinism contracts hold with tracing enabled.

use crate::util::json::{obj, num, Json};
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Global on/off switch; every recording call checks this first.
static ENABLED: AtomicBool = AtomicBool::new(false);
/// t=0 of the trace, set when tracing is first enabled.
static EPOCH: OnceLock<Instant> = OnceLock::new();
/// Completed events drained from per-thread buffers.
static COLLECTOR: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
/// Events discarded after [`MAX_EVENTS`] was reached.
static DROPPED: AtomicU64 = AtomicU64::new(0);
/// Monotonic thread-id source (0 is reserved so tids start at 1).
static NEXT_TID: AtomicU32 = AtomicU32::new(1);

/// Per-thread ring capacity before draining into the collector.
const RING_CAPACITY: usize = 256;
/// Collector cap — beyond this, events are counted as dropped, not kept.
const MAX_EVENTS: usize = 1 << 20;

/// One recorded event in Chrome `trace_event` terms: `ph` is `"X"` for a
/// complete span (has `dur`) or `"i"` for an instant event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: String,
    pub cat: &'static str,
    pub ph: char,
    /// Microseconds since the trace epoch.
    pub ts_us: f64,
    /// Span duration in microseconds (0 for instant events).
    pub dur_us: f64,
    pub tid: u32,
    pub args: Vec<(&'static str, Json)>,
}

struct LocalRing {
    tid: u32,
    events: Vec<TraceEvent>,
}

impl LocalRing {
    fn flush(&mut self) {
        if self.events.is_empty() {
            return;
        }
        // a panicking thread flushes its ring on unwind — recover the
        // poisoned collector (it only ever holds complete events) rather
        // than double-panicking and aborting
        let mut global = COLLECTOR
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let room = MAX_EVENTS.saturating_sub(global.len());
        let take = self.events.len().min(room);
        let dropped = self.events.len() - take;
        global.extend(self.events.drain(..take));
        self.events.clear();
        if dropped > 0 {
            DROPPED.fetch_add(dropped as u64, Ordering::Relaxed);
        }
    }

    fn push(&mut self, ev: TraceEvent) {
        self.events.push(ev);
        if self.events.len() >= RING_CAPACITY {
            self.flush();
        }
    }
}

impl Drop for LocalRing {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static RING: RefCell<LocalRing> = RefCell::new(LocalRing {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        events: Vec::with_capacity(RING_CAPACITY),
    });
}

/// Turn tracing on or off. The first enable fixes the trace epoch.
pub fn set_enabled(on: bool) {
    if on {
        EPOCH.get_or_init(Instant::now);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether tracing is currently recording.
#[inline]
pub fn tracing_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn now_us() -> f64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e6
}

/// RAII span guard. Inert (all-`None`) when tracing is disabled; records
/// a complete event covering `span(..)`→`Drop` otherwise.
pub struct Span {
    data: Option<SpanData>,
}

struct SpanData {
    name: String,
    cat: &'static str,
    start_us: f64,
    args: Vec<(&'static str, Json)>,
}

impl Span {
    /// Attach an attribute (rendered under `args` in the trace). No-op on
    /// an inert span, so callers never pay for attribute construction
    /// inside — only for building the `Json` argument, which should be
    /// cheap scalars on hot paths.
    pub fn attr(&mut self, key: &'static str, value: Json) {
        if let Some(d) = &mut self.data {
            d.args.push((key, value));
        }
    }

    /// Builder-style [`Span::attr`].
    pub fn with(mut self, key: &'static str, value: Json) -> Self {
        self.attr(key, value);
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(d) = self.data.take() {
            let end_us = now_us();
            let ev = TraceEvent {
                name: d.name,
                cat: d.cat,
                ph: 'X',
                ts_us: d.start_us,
                dur_us: (end_us - d.start_us).max(0.0),
                tid: 0, // stamped below from the thread-local ring
                args: d.args,
            };
            RING.with(|r| {
                let mut r = r.borrow_mut();
                let tid = r.tid;
                r.push(TraceEvent { tid, ..ev });
            });
        }
    }
}

/// Open a span. Returns an inert guard (one relaxed atomic load, nothing
/// else) when tracing is disabled.
#[inline]
pub fn span(cat: &'static str, name: &str) -> Span {
    if !ENABLED.load(Ordering::Relaxed) {
        return Span { data: None };
    }
    Span {
        data: Some(SpanData {
            name: name.to_string(),
            cat,
            start_us: now_us(),
            args: Vec::new(),
        }),
    }
}

/// Record an instant event (a point-in-time marker, `ph = "i"`). This is
/// how progress lines that used to be `log::info!` chatter land in the
/// trace without touching stderr.
#[inline]
pub fn event(cat: &'static str, name: &str, args: Vec<(&'static str, Json)>) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let ts_us = now_us();
    RING.with(|r| {
        let mut r = r.borrow_mut();
        let tid = r.tid;
        r.push(TraceEvent {
            name: name.to_string(),
            cat,
            ph: 'i',
            ts_us,
            dur_us: 0.0,
            tid,
            args,
        });
    });
}

/// Flush this thread's ring and take every collected event. Buffers of
/// *live* other threads are drained only when full or at thread exit, so
/// call this after worker threads have joined (the CLI writes traces
/// after engines and pools are dropped).
pub fn drain() -> Vec<TraceEvent> {
    RING.with(|r| r.borrow_mut().flush());
    let mut global = COLLECTOR
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner);
    std::mem::take(&mut *global)
}

/// Events discarded because the collector cap was reached.
pub fn dropped_events() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Render events as a Chrome `trace_event` document.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    let arr = events
        .iter()
        .map(|e| {
            let mut pairs = vec![
                ("name", Json::Str(e.name.clone())),
                ("cat", Json::Str(e.cat.to_string())),
                ("ph", Json::Str(e.ph.to_string())),
                ("ts", num(e.ts_us)),
                ("pid", num(1.0)),
                ("tid", num(e.tid as f64)),
            ];
            if e.ph == 'X' {
                pairs.push(("dur", num(e.dur_us)));
            }
            if e.ph == 'i' {
                // instant scope: thread
                pairs.push(("s", Json::Str("t".to_string())));
            }
            if !e.args.is_empty() {
                pairs.push((
                    "args",
                    obj(e.args.iter().map(|(k, v)| (*k, v.clone())).collect()),
                ));
            }
            obj(pairs)
        })
        .collect();
    obj(vec![
        ("traceEvents", Json::Arr(arr)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Drain all collected events and write them to `path` as Chrome-trace
/// JSON. Logs (debug level) how many events were written or dropped.
pub fn write_chrome_trace(path: &str) -> std::io::Result<()> {
    let events = drain();
    let doc = chrome_trace_json(&events);
    std::fs::write(path, doc.to_string())?;
    let dropped = dropped_events();
    if dropped > 0 {
        log::warn!("trace collector overflowed: {dropped} events dropped");
    }
    log::debug!("wrote {} trace events to {path}", events.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tracing state is process-global and cargo runs tests in parallel,
    // so these tests serialize on a lock, assert only on their own
    // uniquely-named events, and re-disable tracing when done.

    static LOCK: Mutex<()> = Mutex::new(());

    fn serial() -> std::sync::MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn my_events(tag: &str) -> Vec<TraceEvent> {
        drain().into_iter().filter(|e| e.name.starts_with(tag)).collect()
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _g = serial();
        set_enabled(false);
        {
            let mut s = span("test", "disabled_span_records_nothing.s");
            s.attr("k", num(1.0));
        }
        event("test", "disabled_span_records_nothing.e", vec![]);
        assert!(my_events("disabled_span_records_nothing").is_empty());
    }

    #[test]
    fn span_and_event_round_trip_through_collector() {
        let _g = serial();
        set_enabled(true);
        {
            let _s = span("test", "round_trip.outer").with("k", num(7.0));
            let _inner = span("test", "round_trip.inner");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        event("test", "round_trip.mark", vec![("part", num(3.0))]);
        set_enabled(false);
        let evs = my_events("round_trip");
        assert_eq!(evs.len(), 3);
        let outer = evs.iter().find(|e| e.name == "round_trip.outer").unwrap();
        assert_eq!(outer.ph, 'X');
        assert!(outer.dur_us > 0.0);
        assert_eq!(outer.args[0].0, "k");
        let inner = evs.iter().find(|e| e.name == "round_trip.inner").unwrap();
        // inner nests within outer: starts later, ends no later
        assert!(inner.ts_us >= outer.ts_us);
        assert!(inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us + 1.0);
        let mark = evs.iter().find(|e| e.name == "round_trip.mark").unwrap();
        assert_eq!(mark.ph, 'i');
        assert_eq!(mark.dur_us, 0.0);
    }

    #[test]
    fn threads_get_distinct_tids_and_flush_on_exit() {
        let _g = serial();
        set_enabled(true);
        let handles: Vec<_> = (0..3)
            .map(|i| {
                std::thread::spawn(move || {
                    let _s = span("test", &format!("tid_test.worker{i}"));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        set_enabled(false);
        let evs = my_events("tid_test");
        assert_eq!(evs.len(), 3);
        let mut tids: Vec<u32> = evs.iter().map(|e| e.tid).collect();
        tids.sort_unstable();
        tids.dedup();
        assert_eq!(tids.len(), 3, "each thread has its own tid");
    }

    #[test]
    fn chrome_export_is_valid_json_with_required_keys() {
        let _g = serial();
        set_enabled(true);
        {
            let _s = span("test", "export_test.phase").with("n", num(34.0));
        }
        event("test", "export_test.note", vec![]);
        set_enabled(false);
        let events = my_events("export_test");
        let doc = chrome_trace_json(&events);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let arr = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        for e in arr {
            assert!(e.get("name").unwrap().as_str().is_some());
            assert!(e.get("ts").unwrap().as_f64().is_some());
            assert!(e.get("pid").unwrap().as_f64().is_some());
            assert!(e.get("tid").unwrap().as_f64().is_some());
            let ph = e.get("ph").unwrap().as_str().unwrap();
            match ph {
                "X" => assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0),
                "i" => assert_eq!(e.get("s").unwrap().as_str(), Some("t")),
                other => panic!("unexpected ph {other:?}"),
            }
        }
    }
}
