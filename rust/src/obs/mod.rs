//! Observability: tracing spans + a metrics registry (offline build — no
//! `tracing`, no `prometheus`).
//!
//! Two halves, one switch:
//!
//! - [`trace`]: nested, thread-aware spans behind RAII guards. Each thread
//!   records into a thread-local buffer that drains into a global
//!   collector; [`trace::write_chrome_trace`] exports the collected events
//!   in Chrome `trace_event` JSON (load it in `about:tracing` / Perfetto).
//! - [`metrics`]: named counters, gauges, and log-bucketed histograms
//!   (p50/p95/p99/p999 within a documented relative-error bound) in a
//!   process-wide [`metrics::Registry`], snapshotable as JSON and as
//!   Prometheus-style text (`repro metrics`).
//!
//! Tracing is **disabled by default** and costs ~one relaxed atomic load
//! per call site when off (`micro_hotpath` proves this): `span()` returns
//! an inert guard without touching thread-local state or the clock.
//! Metric handles are always-on relaxed atomics — the same cost as the
//! ad-hoc `AtomicU64` stats they replaced in the serving engine and exec
//! session. Enabling tracing must not perturb results — the
//! byte-identical determinism contracts (partition labels, serve logits,
//! session training) hold with tracing on, because spans only observe
//! timestamps and never branch the instrumented code.

pub mod metrics;
pub mod trace;

pub use metrics::{registry, Counter, Gauge, Histogram, Registry};
pub use trace::{event, set_enabled, span, tracing_enabled, write_chrome_trace, Span};
