//! Multi-process distributed transport: a hand-rolled, dependency-free
//! TCP protocol turning the in-process coordinator into a real
//! leader/worker cluster (`repro coordinator serve` + `repro worker
//! join`).
//!
//! Layers, bottom up:
//!
//! - [`frame`] — `LFN1` length-prefixed binary frames: magic, version,
//!   frame type, payload length, and a CRC-32 over header + payload.
//!   Any damage (truncation, bit flip, bad magic/version, oversized
//!   length) is a typed `Error::Net`; fault points `net.send` /
//!   `net.recv` inject wire-level chaos here.
//! - [`wire`] — typed [`wire::Message`]s over frames: handshake
//!   (`Hello`/`Welcome`/`Reject` with the run fingerprint), job flow
//!   (`Assign`/`Result`/`Failed` with the shared [`ErrorCode`]
//!   taxonomy), liveness (`Heartbeat`), and drain (`Shutdown`/`Bye`).
//!   Shards travel as their exact on-disk `LFS1` byte image.
//! - [`server`] — the leader's accept loop plus one session proxy per
//!   joined worker: heartbeat-deadline suspicion, grace-window
//!   reconnect by session token, crash → requeue through the ordinary
//!   retry machinery, idempotent result forwarding.
//! - [`client`] — the worker: dial (+ `net.connect` fault point),
//!   fingerprint handshake, heartbeats beside blocking training calls,
//!   seeded-backoff redial on connection loss.
//!
//! The coordinator selects this transport via
//! `coordinator::Transport::Tcp`; everything above the transport seam —
//! retries, backoff, deadlines, journal, shard writes, metrics — is
//! byte-for-byte the code the local mode runs, which is what makes a
//! distributed run bit-identical to an in-process one.
//!
//! [`ErrorCode`]: crate::coordinator::ErrorCode

pub mod client;
pub mod frame;
pub mod server;
pub mod wire;

pub use client::run_worker;
pub use frame::{
    crc32, decode_frame, encode_frame, read_frame, write_frame, Frame, HEADER_LEN,
    MAX_FRAME_LEN, NET_MAGIC, NET_VERSION,
};
pub use server::TcpServer;
pub use wire::Message;
