//! Leader-side TCP transport: accepts `worker join` processes and
//! impersonates each one as a local worker toward the coordinator's
//! event loop.
//!
//! Architecture: one nonblocking accept thread (handshake + fault point
//! `net.accept`), and per session a blocking **reader thread** (socket →
//! mailbox) plus a **session proxy thread** that owns the worker slot:
//! it pops jobs from the shared [`JobQueue`], ships `Assign` frames,
//! and forwards `Result`/`Failed` frames as the same [`WorkerEvent`]s an
//! in-process worker thread would send. The coordinator's `drive` loop
//! is transport-blind — retries, backoff, deadlines, and dedupe all run
//! unchanged.
//!
//! Robustness semantics (see `DESIGN.md`, *Distributed*):
//! - **Handshake**: the first frame must be `Hello` carrying the run
//!   fingerprint; a mismatch is `Reject`ed before any slot is consumed.
//! - **Liveness**: a session that stays silent past its seeded-jitter
//!   deadline is *suspected*: its socket is closed, its in-flight job is
//!   requeued through the ordinary failure path, and the worker gets a
//!   grace window to reconnect (token-based resume). Past the window the
//!   slot is retired exactly like a local worker that lost its runtime.
//! - **Idempotent results**: every `Result` frame is forwarded; the
//!   leader dedupes by `(part_id, attempt)`, so a result racing its own
//!   requeue is harmless.
//! - **Drain**: when the queue is exhausted the proxy sends `Shutdown`,
//!   waits briefly for `Bye`, and closes; [`TcpServer::drain`] then
//!   joins every thread.

use super::wire::Message;
use crate::config::NetConfig;
use crate::coordinator::{ErrorCode, Job, JobQueue, WorkerEvent};
use crate::error::{Error, Result};
use crate::fault;
use crate::obs;
use crate::util::json::num;
use crate::util::rng::splitmix64;
use crate::util::Stopwatch;
use std::collections::BTreeMap;
use std::io::ErrorKind;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

/// Accept-loop poll tick (std has no timed accept).
const ACCEPT_TICK_MS: u64 = 20;

/// Mailbox poll tick inside a session proxy (liveness/shutdown scan).
const SESSION_TICK_MS: u64 = 50;

/// A connection must deliver its `Hello` within this window, so one
/// stalled dialer cannot block the accept loop for long.
const HANDSHAKE_TIMEOUT_MS: u64 = 2000;

/// Heartbeat intervals a session may stay silent before it is suspected
/// (plus a seeded jitter below one interval, so a fleet of sessions
/// never stampedes its deadlines in lockstep).
const LIVENESS_BEATS: u64 = 3;

/// What a session proxy delivers to its session thread.
enum SessionMsg {
    /// A reconnected worker's fresh stream (the new writer).
    Attach(TcpStream),
    /// A decoded frame from the current reader thread.
    Frame(Message),
    /// The reader thread lost the connection (error text).
    Gone(String),
}

struct Registry {
    /// token → (worker slot, session mailbox). Tokens are deterministic
    /// (seed ^ fingerprint ^ slot through splitmix64) — this transport
    /// trusts its network boundary like the rest of the crate trusts its
    /// inputs; the token resumes sessions, it does not authenticate.
    sessions: BTreeMap<u64, (u32, Sender<SessionMsg>)>,
    /// Next unassigned worker slot.
    next_slot: usize,
    /// Sessions ever joined (monotone; disables the join deadline).
    joined: usize,
}

struct Shared {
    net: NetConfig,
    seed: u64,
    fingerprint: u64,
    slots: usize,
    queue: Arc<JobQueue>,
    tx: Sender<WorkerEvent>,
    shutdown: AtomicBool,
    registry: Mutex<Registry>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Shared {
    fn registry(&self) -> MutexGuard<'_, Registry> {
        // sessions map updates are single-step inserts/removes — a
        // poisoned lock cannot hold a half-applied registry
        self.registry.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn track(&self, handle: JoinHandle<()>) {
        self.handles.lock().unwrap_or_else(PoisonError::into_inner).push(handle);
    }
}

/// The leader's listening endpoint. Started by the coordinator when
/// `transport = tcp`; [`TcpServer::drain`] must be called after the
/// event loop ends (the queue must already be shut down by then).
pub struct TcpServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind, write the port file (if configured), and start accepting
    /// `worker join` connections for `slots` worker slots.
    pub fn start(
        net: &NetConfig,
        seed: u64,
        fingerprint: u64,
        slots: usize,
        queue: Arc<JobQueue>,
        tx: Sender<WorkerEvent>,
    ) -> Result<TcpServer> {
        let listener = TcpListener::bind(&net.bind)
            .map_err(|e| Error::Net(format!("cannot bind {}: {e}", net.bind)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Net(format!("cannot read bound address: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Net(format!("cannot configure listener: {e}")))?;
        if let Some(path) = &net.port_file {
            // written after bind so a script polling the file never reads
            // a port nobody listens on
            std::fs::write(path, format!("{}\n", addr.port()))?;
        }
        let shared = Arc::new(Shared {
            net: net.clone(),
            seed,
            fingerprint,
            slots,
            queue,
            tx,
            shutdown: AtomicBool::new(false),
            registry: Mutex::new(Registry {
                sessions: BTreeMap::new(),
                next_slot: 0,
                joined: 0,
            }),
            handles: Mutex::new(Vec::new()),
        });
        log::info!("coordinator listening on {addr} ({slots} worker slot(s))");
        obs::event(
            "net",
            "serve.start",
            vec![("port", num(addr.port() as f64)), ("slots", num(slots as f64))],
        );
        let sh = Arc::clone(&shared);
        // lint: allow(spawn_outside_parallel) — long-lived accept loop for the TCP transport, not a fork-join computation
        let accept = std::thread::spawn(move || accept_loop(&sh, listener));
        Ok(TcpServer { shared, addr, accept: Some(accept) })
    }

    /// The bound address (port resolved, even when `bind` asked for 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wake every session, and join all transport
    /// threads. The job queue must already be shut down, so session
    /// proxies fall out of `pop` and drain their workers.
    pub fn drain(mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // sessions push reader handles while we join — keep taking until
        // the vec stays empty
        loop {
            let batch: Vec<JoinHandle<()>> = {
                let mut held =
                    self.shared.handles.lock().unwrap_or_else(PoisonError::into_inner);
                std::mem::take(&mut *held)
            };
            if batch.is_empty() {
                break;
            }
            for h in batch {
                let _ = h.join();
            }
        }
    }
}

fn accept_loop(sh: &Arc<Shared>, listener: TcpListener) {
    let sw = Stopwatch::start();
    loop {
        if sh.shutdown.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                if let Some(inj) = fault::point("net.accept").fire() {
                    // no corruptible payload at accept: fail and corrupt
                    // alike drop the connection pre-handshake — the
                    // worker's dial-side retry absorbs it
                    log::warn!("net.accept: dropping connection from {peer}: {}", inj.error());
                    drop(stream);
                    continue;
                }
                if let Err(e) = handshake(sh, stream) {
                    log::warn!("handshake with {peer} failed: {e}");
                }
            }
            Err(e) => {
                if e.kind() != ErrorKind::WouldBlock {
                    log::warn!("accept error: {e}");
                }
                let deadline = sh.net.join_timeout_secs;
                if deadline > 0.0 && sh.registry().joined == 0 && sw.secs() > deadline {
                    // nobody ever joined: retire every slot so the leader
                    // aborts with its ordinary "all workers retired"
                    // diagnosis instead of waiting forever
                    log::error!("no worker joined within {deadline:.0}s; giving up");
                    for wid in 0..sh.slots {
                        let _ = sh.tx.send(WorkerEvent::Retired {
                            worker: wid,
                            error: format!("no worker joined within {deadline:.0}s"),
                        });
                    }
                    return;
                }
                // lint: allow(sleep_outside_backoff) — std has no timed accept; bounded poll tick, not a retry loop
                std::thread::sleep(Duration::from_millis(ACCEPT_TICK_MS));
            }
        }
    }
}

/// Run the `Hello` → `Welcome`/`Reject` exchange on a fresh connection
/// and hand the stream to a (new or resumed) session.
fn handshake(sh: &Arc<Shared>, mut stream: TcpStream) -> Result<()> {
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(Duration::from_millis(HANDSHAKE_TIMEOUT_MS)))
        .map_err(|e| Error::Net(format!("cannot arm handshake timeout: {e}")))?;
    let hello = Message::read_from(&mut stream)?;
    let Message::Hello { token, fingerprint } = hello else {
        return Err(Error::Net(format!(
            "expected hello, got frame type {}",
            hello.ftype()
        )));
    };
    if fingerprint != sh.fingerprint {
        let reason = format!(
            "run fingerprint mismatch: worker {fingerprint:016x}, leader {:016x} — \
             dataset, partitioning, seed, or training config differ",
            sh.fingerprint
        );
        let _ = Message::Reject { reason: reason.clone() }.write_to(&mut stream);
        return Err(Error::Net(reason));
    }
    // handshake timeout off: from here on the reader blocks freely and
    // liveness is the session proxy's business
    stream
        .set_read_timeout(None)
        .map_err(|e| Error::Net(format!("cannot clear handshake timeout: {e}")))?;
    if token == 0 {
        join_session(sh, stream)
    } else {
        resume_session(sh, stream, token)
    }
}

fn join_session(sh: &Arc<Shared>, mut stream: TcpStream) -> Result<()> {
    let (wid, token) = {
        let mut reg = sh.registry();
        if reg.next_slot >= sh.slots {
            drop(reg);
            let reason = format!("cluster full: all {} worker slot(s) joined", sh.slots);
            let _ = Message::Reject { reason: reason.clone() }.write_to(&mut stream);
            return Err(Error::Net(reason));
        }
        let wid = reg.next_slot;
        reg.next_slot += 1;
        reg.joined += 1;
        // deterministic, clock-free session token; nonzero by |1 (zero
        // means "fresh join" on the wire)
        let mut state =
            sh.seed ^ sh.fingerprint ^ (wid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        (wid, splitmix64(&mut state) | 1)
    };
    if let Err(e) = (Message::Welcome {
        worker: wid as u32,
        token,
        heartbeat_ms: sh.net.heartbeat_ms,
    })
    .write_to(&mut stream)
    {
        // the slot was reserved but its worker is gone before it ever
        // joined — retire it so the leader's live-worker accounting stays
        // exact instead of waiting on a ghost
        let _ = sh.tx.send(WorkerEvent::Retired {
            worker: wid,
            error: format!("handshake write failed: {e}"),
        });
        return Err(e);
    }
    let (stx, srx) = mpsc::channel::<SessionMsg>();
    sh.registry().sessions.insert(token, (wid as u32, stx.clone()));
    obs::registry().counter("net.sessions_joined").inc();
    obs::event("net", "session.joined", vec![("worker", num(wid as f64))]);
    log::info!("worker {wid} joined (session {token:016x})");
    let reader = stream
        .try_clone()
        .map_err(|e| Error::Net(format!("cannot clone session stream: {e}")))?;
    spawn_reader(sh, reader, stx);
    let sh2 = Arc::clone(sh);
    // lint: allow(spawn_outside_parallel) — one long-lived proxy thread per remote worker session, not a fork-join computation
    let handle = std::thread::spawn(move || {
        Session::new(sh2, wid, token, srx, stream).run();
    });
    sh.track(handle);
    Ok(())
}

fn resume_session(sh: &Arc<Shared>, mut stream: TcpStream, token: u64) -> Result<()> {
    let entry = sh.registry().sessions.get(&token).cloned();
    let Some((wid, stx)) = entry else {
        let reason = "unknown session token (session retired or never existed)".to_string();
        let _ = Message::Reject { reason: reason.clone() }.write_to(&mut stream);
        return Err(Error::Net(reason));
    };
    // welcome first: the session proxy may write an Assign the moment the
    // stream is attached, and the worker expects Welcome before anything
    (Message::Welcome { worker: wid, token, heartbeat_ms: sh.net.heartbeat_ms })
        .write_to(&mut stream)?;
    let reader = stream
        .try_clone()
        .map_err(|e| Error::Net(format!("cannot clone session stream: {e}")))?;
    if stx.send(SessionMsg::Attach(stream)).is_err() {
        let reason = "session just retired".to_string();
        let mut via_reader = reader;
        let _ = Message::Reject { reason: reason.clone() }.write_to(&mut via_reader);
        return Err(Error::Net(reason));
    }
    obs::registry().counter("net.reconnects").inc();
    obs::event("net", "session.reconnected", vec![("worker", num(wid as f64))]);
    log::info!("worker {wid} reconnected (session {token:016x})");
    spawn_reader(sh, reader, stx);
    Ok(())
}

/// Blocking frame pump: socket → session mailbox. Exits on any read
/// error (`Gone`) or once the session is over (mailbox closed).
fn spawn_reader(sh: &Arc<Shared>, mut stream: TcpStream, to_session: Sender<SessionMsg>) {
    // lint: allow(spawn_outside_parallel) — blocking socket reader pumping frames into the session mailbox, not a fork-join computation
    let handle = std::thread::spawn(move || loop {
        match Message::read_from(&mut stream) {
            Ok(msg) => {
                if to_session.send(SessionMsg::Frame(msg)).is_err() {
                    return;
                }
            }
            Err(e) => {
                let _ = to_session.send(SessionMsg::Gone(e.to_string()));
                return;
            }
        }
    });
    sh.track(handle);
}

/// Why an assignment round ended without a forwarded outcome.
enum AssignEnd {
    /// Result or Failed for this job was forwarded to the leader.
    Done,
    /// The job must be requeued (connection trouble); the session may
    /// still be alive (reattached) or awaiting its grace window.
    Requeue(String),
    /// The server is shutting down.
    Shutdown,
}

/// One remote worker's slot proxy: owns the mailbox, the current writer
/// stream, and the liveness clock.
struct Session {
    sh: Arc<Shared>,
    wid: usize,
    token: u64,
    rx: Receiver<SessionMsg>,
    writer: Option<TcpStream>,
    /// Silence budget before suspicion, with seeded per-slot jitter.
    liveness_ms: f64,
}

impl Session {
    fn new(
        sh: Arc<Shared>,
        wid: usize,
        token: u64,
        rx: Receiver<SessionMsg>,
        writer: TcpStream,
    ) -> Session {
        let hb = sh.net.heartbeat_ms.max(1);
        let mut state = sh.seed ^ sh.fingerprint ^ (wid as u64) ^ 0x11FE;
        let jitter = splitmix64(&mut state) % hb;
        let liveness_ms = (hb * LIVENESS_BEATS + jitter) as f64;
        Session { sh, wid, token, rx, writer: Some(writer), liveness_ms }
    }

    fn run(mut self) {
        let mut span = obs::span("net", "session");
        if obs::tracing_enabled() {
            span.attr("worker", num(self.wid as f64));
        }
        loop {
            self.drain_mailbox();
            if self.sh.shutdown.load(Ordering::Relaxed) {
                self.hangup();
                return;
            }
            if self.writer.is_none() && !self.await_reattach() {
                self.retire("connection lost");
                return;
            }
            let Some(job) = self.sh.queue.pop(self.wid) else {
                self.drain_worker();
                return;
            };
            // Started first: the leader attributes failures to the
            // attempt it believes is running, so the proxy must register
            // the attempt before anything can fail it
            let _ = self
                .sh
                .tx
                .send(WorkerEvent::Started { worker: self.wid, part_id: job.part_id });
            match self.run_assignment(&job) {
                AssignEnd::Done => {}
                AssignEnd::Requeue(why) => {
                    log::warn!(
                        "worker {}: requeueing partition {} (attempt {}): {why}",
                        self.wid,
                        job.part_id,
                        job.attempt
                    );
                    obs::registry().counter("net.jobs_requeued").inc();
                    let _ = self.sh.tx.send(WorkerEvent::Failed {
                        worker: self.wid,
                        part_id: job.part_id,
                        code: ErrorCode::Net,
                        message: why,
                    });
                }
                AssignEnd::Shutdown => {
                    self.hangup();
                    return;
                }
            }
        }
    }

    /// Ship `Assign` and pump the mailbox until this job concludes, the
    /// connection degrades, or the server shuts down.
    fn run_assignment(&mut self, job: &Job) -> AssignEnd {
        let assign = Message::Assign {
            part_id: job.part_id,
            attempt: job.attempt,
            members: job.members.clone(),
        };
        match &mut self.writer {
            Some(w) => {
                if let Err(e) = assign.write_to(w) {
                    self.suspect();
                    return AssignEnd::Requeue(format!("assign write failed: {e}"));
                }
            }
            None => return AssignEnd::Requeue("no connection at assign time".into()),
        }
        let mut idle = Stopwatch::start();
        loop {
            if self.sh.shutdown.load(Ordering::Relaxed) {
                return AssignEnd::Shutdown;
            }
            match self.rx.recv_timeout(Duration::from_millis(SESSION_TICK_MS)) {
                Ok(SessionMsg::Frame(msg)) => {
                    idle = Stopwatch::start();
                    match msg {
                        Message::Heartbeat => {}
                        Message::Result { .. } => {
                            let mine = self.forward_result(msg, job);
                            if mine {
                                return AssignEnd::Done;
                            }
                        }
                        Message::Failed { part_id, attempt: _, code, message } => {
                            let _ = self.sh.tx.send(WorkerEvent::Failed {
                                worker: self.wid,
                                part_id,
                                code,
                                message,
                            });
                            if part_id == job.part_id {
                                return AssignEnd::Done;
                            }
                        }
                        Message::Bye => {
                            // worker is leaving mid-assignment
                            self.suspect();
                            return AssignEnd::Requeue("worker said goodbye mid-job".into());
                        }
                        other => {
                            log::debug!(
                                "worker {}: ignoring unexpected frame type {}",
                                self.wid,
                                other.ftype()
                            );
                        }
                    }
                }
                Ok(SessionMsg::Attach(stream)) => {
                    // the worker reconnected: its previous connection —
                    // and with it the in-flight assignment — is gone; the
                    // retry path retrains it (bit-identically: the train
                    // seed never depends on the attempt)
                    self.writer = Some(stream);
                    return AssignEnd::Requeue("worker reconnected; assignment lost".into());
                }
                Ok(SessionMsg::Gone(e)) => {
                    self.suspect();
                    return AssignEnd::Requeue(format!("connection lost mid-job: {e}"));
                }
                Err(RecvTimeoutError::Timeout) => {
                    if idle.millis() > self.liveness_ms {
                        self.suspect();
                        return AssignEnd::Requeue(format!(
                            "liveness deadline expired ({:.0}ms silent)",
                            idle.millis()
                        ));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // unreachable while the registry holds a mailbox
                    // sender; treat as a lost connection all the same
                    self.suspect();
                    return AssignEnd::Requeue("session mailbox closed".into());
                }
            }
        }
    }

    /// Decode a `Result` frame into the same `Finished` event a local
    /// worker sends. A shard that fails its `LFS1` checksums after a
    /// CRC-valid frame is a *transient* failure — retrain, don't abort.
    /// Returns whether the frame concluded `job`.
    fn forward_result(&self, msg: Message, job: &Job) -> bool {
        let Message::Result { part_id, attempt, train_secs, num_replicas, losses, shard } = msg
        else {
            return false;
        };
        match crate::serve::decode_shard_bytes(&shard) {
            Ok((header, data)) if header.part_id == part_id => {
                let result = crate::train::TrainedPartition {
                    losses,
                    embeddings: data,
                    emb_dim: header.dim,
                    logits: Vec::new(),
                    num_classes: 0,
                    num_replicas: num_replicas as usize,
                    train_secs,
                    exec_stats: None,
                };
                let _ = self.sh.tx.send(WorkerEvent::Finished {
                    worker: self.wid,
                    part_id,
                    attempt,
                    nodes: header.nodes,
                    result,
                });
            }
            Ok((header, _)) => {
                let _ = self.sh.tx.send(WorkerEvent::Failed {
                    worker: self.wid,
                    part_id,
                    code: ErrorCode::Net,
                    message: format!(
                        "result shard labeled partition {} (expected {part_id})",
                        header.part_id
                    ),
                });
            }
            Err(e) => {
                let _ = self.sh.tx.send(WorkerEvent::Failed {
                    worker: self.wid,
                    part_id,
                    code: ErrorCode::Net,
                    message: format!("result shard rejected: {e}"),
                });
            }
        }
        part_id == job.part_id && attempt == job.attempt
    }

    /// Handle anything already in the mailbox without blocking (frames
    /// and disconnects that arrived while the proxy was between jobs).
    fn drain_mailbox(&mut self) {
        while let Ok(msg) = self.rx.try_recv() {
            match msg {
                SessionMsg::Attach(stream) => self.writer = Some(stream),
                SessionMsg::Gone(e) => {
                    log::debug!("worker {}: connection lost while idle: {e}", self.wid);
                    self.suspect();
                }
                SessionMsg::Frame(m) => {
                    // forward stale results (the leader dedupes); drop
                    // the rest — there is no assignment to conclude
                    if matches!(m, Message::Result { .. }) {
                        let never = Job { part_id: u32::MAX, members: Vec::new(), attempt: 0 };
                        self.forward_result(m, &never);
                    }
                }
            }
        }
    }

    /// Mark the connection suspect: close the socket (unblocks the
    /// reader and forces the worker's next read/write to fail fast so it
    /// reconnects) and drop the writer.
    fn suspect(&mut self) {
        obs::registry().counter("net.sessions_suspected").inc();
        if let Some(w) = self.writer.take() {
            let _ = w.shutdown(Shutdown::Both);
        }
    }

    /// Grace window: wait for the worker to reconnect (an `Attach` in
    /// the mailbox). True = reattached (or server shutdown, which the
    /// caller checks next); false = the window expired.
    fn await_reattach(&mut self) -> bool {
        let sw = Stopwatch::start();
        log::warn!(
            "worker {}: suspected; waiting {}ms for a reconnect",
            self.wid,
            self.sh.net.grace_ms
        );
        while sw.millis() < self.sh.net.grace_ms as f64 {
            if self.sh.shutdown.load(Ordering::Relaxed) {
                return true;
            }
            match self.rx.recv_timeout(Duration::from_millis(SESSION_TICK_MS)) {
                Ok(SessionMsg::Attach(stream)) => {
                    self.writer = Some(stream);
                    return true;
                }
                // stale frames/disconnects from the dead connection
                Ok(_) => {}
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return false,
            }
        }
        false
    }

    /// Graceful worker drain once the queue is exhausted: `Shutdown`,
    /// bounded wait for `Bye`, close.
    fn drain_worker(&mut self) {
        if let Some(w) = &mut self.writer {
            if Message::Shutdown.write_to(w).is_ok() {
                let sw = Stopwatch::start();
                while sw.millis() < self.sh.net.grace_ms as f64 {
                    match self.rx.recv_timeout(Duration::from_millis(SESSION_TICK_MS)) {
                        Ok(SessionMsg::Frame(Message::Bye)) | Ok(SessionMsg::Gone(_)) => break,
                        Ok(_) => {}
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
            }
        }
        log::debug!("worker {}: drained", self.wid);
        self.hangup();
    }

    /// Best-effort `Shutdown` notice (server teardown), then hang up.
    fn hangup(&mut self) {
        if let Some(w) = &mut self.writer {
            let _ = Message::Shutdown.write_to(w);
        }
        self.finish();
    }

    /// Retire this slot: the worker stayed gone past its grace window —
    /// the exact analogue of a local worker losing its runtime.
    fn retire(&mut self, why: &str) {
        obs::registry().counter("net.sessions_retired").inc();
        obs::event("net", "session.retired", vec![("worker", num(self.wid as f64))]);
        let _ = self.sh.tx.send(WorkerEvent::Retired {
            worker: self.wid,
            error: format!(
                "{why}; no reconnect within the {}ms grace window",
                self.sh.net.grace_ms
            ),
        });
        self.finish();
    }

    /// Common teardown: deregister the token and close the socket (which
    /// also unblocks this session's reader thread).
    fn finish(&mut self) {
        self.sh.registry().sessions.remove(&self.token);
        if let Some(w) = self.writer.take() {
            let _ = w.shutdown(Shutdown::Both);
        }
    }
}
