//! Typed messages over `LFN1` frames: the complete leader↔worker
//! vocabulary of the distributed coordinator.
//!
//! Each [`Message`] variant maps to one frame type; payloads are encoded
//! with the bounds-checked little-endian helpers below (length-prefixed
//! vectors and strings, `count × size ≤ remaining` guarded before any
//! allocation, trailing bytes rejected). Like the frame layer, every
//! malformed payload is a typed [`Error::Net`] — a peer speaking
//! garbage, or a CRC collision slipping a damaged frame through, can
//! never panic the process or be half-accepted.
//!
//! Trained shards travel as their exact on-disk `LFS1` byte image
//! (`serve::encode_shard` on the worker, `serve::decode_shard_bytes` on
//! the leader), so the wire inherits the shard format's own section
//! checksums on top of the frame CRC, and the leader writes bytes that
//! are bit-identical to a local run's.

use super::frame::{read_frame, write_frame, Frame};
use crate::coordinator::ErrorCode;
use crate::error::{Error, Result};
use crate::graph::NodeId;
use std::io::{Read, Write};

/// Frame type tags (the `ftype` header field).
pub const FT_HELLO: u16 = 1;
pub const FT_WELCOME: u16 = 2;
pub const FT_REJECT: u16 = 3;
pub const FT_ASSIGN: u16 = 4;
pub const FT_RESULT: u16 = 5;
pub const FT_FAILED: u16 = 6;
pub const FT_HEARTBEAT: u16 = 7;
pub const FT_SHUTDOWN: u16 = 8;
pub const FT_BYE: u16 = 9;

/// A protocol message. See `DESIGN.md` (*Distributed*) for the
/// handshake and session state machines these drive.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Worker → leader, first frame on every connection. `token == 0`
    /// asks for a fresh session; a nonzero token resumes a suspected
    /// session within its grace window. The fingerprint is the journal's
    /// run fingerprint computed from the worker's own config + locally
    /// partitioned dataset — agreement proves both processes describe
    /// the same run.
    Hello { token: u64, fingerprint: u64 },
    /// Leader → worker: session accepted. Carries the assigned worker
    /// slot, the session token to present on reconnect, and the
    /// heartbeat cadence the worker must keep.
    Welcome { worker: u32, token: u64, heartbeat_ms: u64 },
    /// Leader → worker: session refused (fingerprint mismatch, cluster
    /// full, unknown token). Permanent — the worker must not retry.
    Reject { reason: String },
    /// Leader → worker: train this partition. Members are authoritative
    /// (the worker's own partitioning is only used for the handshake
    /// fingerprint).
    Assign { part_id: u32, attempt: u32, members: Vec<NodeId> },
    /// Worker → leader: training succeeded. `shard` is the partition's
    /// `LFS1` byte image; `nodes`/losses/stats mirror the in-process
    /// `WorkerEvent::Finished` fields the leader needs.
    Result {
        part_id: u32,
        attempt: u32,
        train_secs: f64,
        num_replicas: u64,
        losses: Vec<f32>,
        shard: Vec<u8>,
    },
    /// Worker → leader: training failed with a typed [`ErrorCode`] —
    /// the same transient-vs-permanent taxonomy the in-process channel
    /// uses, now wire-portable.
    Failed { part_id: u32, attempt: u32, code: ErrorCode, message: String },
    /// Worker → leader: liveness beacon (any frame refreshes liveness;
    /// this one exists for idle periods).
    Heartbeat,
    /// Leader → worker: drain — finish nothing new, acknowledge with
    /// [`Message::Bye`], close.
    Shutdown,
    /// Worker → leader: drain acknowledged.
    Bye,
}

// ---------------------------------------------------------------------
// payload encoding

struct PayloadWriter {
    buf: Vec<u8>,
}

impl PayloadWriter {
    fn new() -> PayloadWriter {
        PayloadWriter { buf: Vec::new() }
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn bytes(&mut self, v: &[u8]) -> Result<()> {
        self.u32(checked_len(v.len())?);
        self.buf.extend_from_slice(v);
        Ok(())
    }

    fn str(&mut self, v: &str) -> Result<()> {
        self.bytes(v.as_bytes())
    }

    fn u32s(&mut self, v: &[u32]) -> Result<()> {
        self.u32(checked_len(v.len())?);
        for x in v {
            self.u32(*x);
        }
        Ok(())
    }

    fn f32s(&mut self, v: &[f32]) -> Result<()> {
        self.u32(checked_len(v.len())?);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        Ok(())
    }
}

fn checked_len(n: usize) -> Result<u32> {
    u32::try_from(n).map_err(|_| Error::Net(format!("payload collection too long: {n} items")))
}

struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
    what: &'static str,
}

impl<'a> PayloadReader<'a> {
    fn new(buf: &'a [u8], what: &'static str) -> PayloadReader<'a> {
        PayloadReader { buf, pos: 0, what }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            return Err(Error::Net(format!(
                "truncated {} payload: wanted {n} bytes, {} left",
                self.what,
                self.remaining()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Element count for a list of `size`-byte items, validated against
    /// the bytes actually present — a corrupt count can never drive an
    /// oversized allocation.
    fn count(&mut self, size: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let fits = n.checked_mul(size).is_some_and(|total| total <= self.remaining());
        if !fits {
            return Err(Error::Net(format!(
                "corrupt {} payload: {n} items of {size} bytes exceed {} remaining",
                self.what,
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.count(1)?;
        Ok(self.take(n)?.to_vec())
    }

    fn str(&mut self) -> Result<String> {
        let raw = self.bytes()?;
        String::from_utf8(raw)
            .map_err(|_| Error::Net(format!("corrupt {} payload: invalid utf-8", self.what)))
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.count(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.take(4)?;
            out.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        Ok(out)
    }

    fn finish(self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(Error::Net(format!(
                "corrupt {} payload: {} trailing bytes",
                self.what,
                self.remaining()
            )));
        }
        Ok(())
    }
}

impl Message {
    /// Frame type tag for this message.
    pub fn ftype(&self) -> u16 {
        match self {
            Message::Hello { .. } => FT_HELLO,
            Message::Welcome { .. } => FT_WELCOME,
            Message::Reject { .. } => FT_REJECT,
            Message::Assign { .. } => FT_ASSIGN,
            Message::Result { .. } => FT_RESULT,
            Message::Failed { .. } => FT_FAILED,
            Message::Heartbeat => FT_HEARTBEAT,
            Message::Shutdown => FT_SHUTDOWN,
            Message::Bye => FT_BYE,
        }
    }

    /// Encode this message's payload (frame header added by the caller).
    pub fn encode_payload(&self) -> Result<Vec<u8>> {
        let mut w = PayloadWriter::new();
        match self {
            Message::Hello { token, fingerprint } => {
                w.u64(*token);
                w.u64(*fingerprint);
            }
            Message::Welcome { worker, token, heartbeat_ms } => {
                w.u32(*worker);
                w.u64(*token);
                w.u64(*heartbeat_ms);
            }
            Message::Reject { reason } => w.str(reason)?,
            Message::Assign { part_id, attempt, members } => {
                w.u32(*part_id);
                w.u32(*attempt);
                w.u32s(members)?;
            }
            Message::Result { part_id, attempt, train_secs, num_replicas, losses, shard } => {
                w.u32(*part_id);
                w.u32(*attempt);
                w.f64(*train_secs);
                w.u64(*num_replicas);
                w.f32s(losses)?;
                w.bytes(shard)?;
            }
            Message::Failed { part_id, attempt, code, message } => {
                w.u32(*part_id);
                w.u32(*attempt);
                w.u16(code.as_u16());
                w.str(message)?;
            }
            Message::Heartbeat | Message::Shutdown | Message::Bye => {}
        }
        Ok(w.buf)
    }

    /// Decode a message from a CRC-verified frame. Unknown frame types
    /// and malformed payloads are [`Error::Net`].
    pub fn decode(frame: &Frame) -> Result<Message> {
        let msg = match frame.ftype {
            FT_HELLO => {
                let mut r = PayloadReader::new(&frame.payload, "hello");
                let m = Message::Hello { token: r.u64()?, fingerprint: r.u64()? };
                r.finish()?;
                m
            }
            FT_WELCOME => {
                let mut r = PayloadReader::new(&frame.payload, "welcome");
                let m = Message::Welcome {
                    worker: r.u32()?,
                    token: r.u64()?,
                    heartbeat_ms: r.u64()?,
                };
                r.finish()?;
                m
            }
            FT_REJECT => {
                let mut r = PayloadReader::new(&frame.payload, "reject");
                let m = Message::Reject { reason: r.str()? };
                r.finish()?;
                m
            }
            FT_ASSIGN => {
                let mut r = PayloadReader::new(&frame.payload, "assign");
                let m = Message::Assign {
                    part_id: r.u32()?,
                    attempt: r.u32()?,
                    members: r.u32s()?,
                };
                r.finish()?;
                m
            }
            FT_RESULT => {
                let mut r = PayloadReader::new(&frame.payload, "result");
                let m = Message::Result {
                    part_id: r.u32()?,
                    attempt: r.u32()?,
                    train_secs: r.f64()?,
                    num_replicas: r.u64()?,
                    losses: r.f32s()?,
                    shard: r.bytes()?,
                };
                r.finish()?;
                m
            }
            FT_FAILED => {
                let mut r = PayloadReader::new(&frame.payload, "failed");
                let part_id = r.u32()?;
                let attempt = r.u32()?;
                let raw = r.u16()?;
                let code = ErrorCode::from_u16(raw).ok_or_else(|| {
                    Error::Net(format!("corrupt failed payload: unknown error code {raw}"))
                })?;
                let m = Message::Failed { part_id, attempt, code, message: r.str()? };
                r.finish()?;
                m
            }
            FT_HEARTBEAT | FT_SHUTDOWN | FT_BYE => {
                let r = PayloadReader::new(
                    &frame.payload,
                    match frame.ftype {
                        FT_HEARTBEAT => "heartbeat",
                        FT_SHUTDOWN => "shutdown",
                        _ => "bye",
                    },
                );
                r.finish()?;
                match frame.ftype {
                    FT_HEARTBEAT => Message::Heartbeat,
                    FT_SHUTDOWN => Message::Shutdown,
                    _ => Message::Bye,
                }
            }
            other => return Err(Error::Net(format!("unknown frame type {other}"))),
        };
        Ok(msg)
    }

    /// Encode and write this message as one frame.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        let payload = self.encode_payload()?;
        write_frame(w, self.ftype(), &payload)
    }

    /// Read and decode one message from the stream.
    pub fn read_from(r: &mut impl Read) -> Result<Message> {
        let frame = read_frame(r)?;
        Message::decode(&frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::frame::encode_frame;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn roundtrip(msg: &Message) -> Message {
        let mut buf: Vec<u8> = Vec::new();
        msg.write_to(&mut buf).unwrap();
        let mut r: &[u8] = &buf;
        Message::read_from(&mut r).unwrap()
    }

    #[test]
    fn every_variant_roundtrips() {
        let msgs = vec![
            Message::Hello { token: 0, fingerprint: 0xDEAD_BEEF_CAFE },
            Message::Welcome { worker: 3, token: u64::MAX, heartbeat_ms: 500 },
            Message::Reject { reason: "fingerprint mismatch".into() },
            Message::Assign { part_id: 7, attempt: 2, members: vec![0, 5, 9, u32::MAX] },
            Message::Result {
                part_id: 1,
                attempt: 0,
                train_secs: 0.125,
                num_replicas: 42,
                losses: vec![1.5, f32::NAN, -0.0],
                shard: vec![1, 2, 3, 255],
            },
            Message::Failed {
                part_id: 9,
                attempt: 3,
                code: ErrorCode::Fault,
                message: "injected fault at worker.train".into(),
            },
            Message::Heartbeat,
            Message::Shutdown,
            Message::Bye,
        ];
        for msg in &msgs {
            let back = roundtrip(msg);
            // NaN-safe comparison: compare at the bit level via re-encode
            assert_eq!(
                back.encode_payload().unwrap(),
                msg.encode_payload().unwrap(),
                "payload mismatch for {msg:?}"
            );
            assert_eq!(back.ftype(), msg.ftype());
        }
    }

    #[test]
    fn rejects_unknown_frame_type_and_code() {
        let frame = Frame { ftype: 999, payload: vec![] };
        assert!(matches!(Message::decode(&frame), Err(Error::Net(_))));
        // Failed with an unmapped error code: reject, don't guess
        let mut bad = Vec::new();
        bad.extend_from_slice(&7u32.to_le_bytes());
        bad.extend_from_slice(&0u32.to_le_bytes());
        bad.extend_from_slice(&999u16.to_le_bytes());
        bad.extend_from_slice(&0u32.to_le_bytes()); // empty message string
        let frame = Frame { ftype: FT_FAILED, payload: bad };
        assert!(matches!(Message::decode(&frame), Err(Error::Net(_))));
    }

    #[test]
    fn rejects_trailing_and_truncated_payloads() {
        let hello = Message::Hello { token: 1, fingerprint: 2 };
        let mut payload = hello.encode_payload().unwrap();
        payload.push(0); // trailing byte
        assert!(matches!(
            Message::decode(&Frame { ftype: FT_HELLO, payload }),
            Err(Error::Net(_))
        ));
        let mut payload = hello.encode_payload().unwrap();
        payload.truncate(11);
        assert!(matches!(
            Message::decode(&Frame { ftype: FT_HELLO, payload }),
            Err(Error::Net(_))
        ));
        // heartbeat must be empty
        assert!(matches!(
            Message::decode(&Frame { ftype: FT_HEARTBEAT, payload: vec![9] }),
            Err(Error::Net(_))
        ));
    }

    #[test]
    fn corrupt_count_cannot_drive_allocation() {
        // an Assign whose member count claims 1B entries but carries none:
        // the count-vs-remaining guard must reject before reserving
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.extend_from_slice(&1_000_000_000u32.to_le_bytes());
        assert!(matches!(
            Message::decode(&Frame { ftype: FT_ASSIGN, payload }),
            Err(Error::Net(_))
        ));
    }

    /// Property: random payload bytes under every known frame type
    /// either decode to a message that re-encodes to the same bytes, or
    /// fail as a typed `Error::Net` — never a panic.
    #[test]
    fn prop_fuzzed_payloads_never_panic() {
        const FTYPES: &[u16] = &[
            FT_HELLO, FT_WELCOME, FT_REJECT, FT_ASSIGN, FT_RESULT, FT_FAILED, FT_HEARTBEAT,
            FT_SHUTDOWN, FT_BYE, 0, 4242,
        ];
        prop::check(
            "wire-fuzz",
            80,
            0x51FE,
            |rng: &mut Rng| {
                let ftype = FTYPES[rng.index(FTYPES.len())];
                let len = rng.index(64);
                let payload: Vec<u8> = (0..len).map(|_| rng.index(256) as u8).collect();
                (ftype, payload)
            },
            |(ftype, payload)| {
                let frame = Frame { ftype: *ftype, payload: payload.clone() };
                match Message::decode(&frame) {
                    Ok(msg) => {
                        let re = msg.encode_payload().map_err(|e| format!("re-encode: {e}"))?;
                        if &re != payload {
                            return Err("accepted payload does not re-encode identically".into());
                        }
                        Ok(())
                    }
                    Err(Error::Net(_)) => Ok(()),
                    Err(other) => Err(format!("expected Error::Net, got {other}")),
                }
            },
        );
    }

    #[test]
    fn message_survives_frame_layer() {
        // a full frame encode → decode → message decode chain
        let msg = Message::Assign { part_id: 3, attempt: 1, members: vec![10, 20, 30] };
        let bytes = encode_frame(msg.ftype(), &msg.encode_payload().unwrap()).unwrap();
        let frame = crate::net::frame::decode_frame(&bytes).unwrap();
        assert_eq!(Message::decode(&frame).unwrap(), msg);
    }
}
