//! Worker-side TCP transport: `repro worker join <addr>`.
//!
//! One training machine. Dials the leader (fault point `net.connect`),
//! handshakes the run fingerprint, then serves `Assign` frames with the
//! exact job-execution path an in-process worker thread uses
//! ([`worker::run_job`]) — same runtime, same scratch reuse, same
//! attempt-independent training seed, so a partition trained here is
//! bit-identical to one trained locally.
//!
//! A background heartbeat thread keeps the session alive through long
//! training calls (the main thread cannot speak while it trains). All
//! frame writes go through one mutex so a heartbeat can never interleave
//! bytes into the middle of a result frame.
//!
//! Connection loss is survivable: the worker redials with its session
//! token inside the leader's grace window and resumes the same slot;
//! consecutive dial failures beyond `reconnect_attempts` give up. A
//! `Reject` is permanent (config mismatch — retrying cannot help).

use super::wire::Message;
use crate::config::NetConfig;
use crate::coordinator::{worker, CoordinatorConfig, ErrorCode, Job};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::fault::{self, Backoff};
use crate::graph::SubgraphScratch;
use crate::obs;
use crate::runtime::Runtime;
use crate::train::PadScratch;
use crate::util::json::num;
use std::net::TcpStream;
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::Duration;

/// How one connection ended.
enum Outcome {
    /// The leader drained us: the run is over.
    Shutdown,
    /// The connection died; redial with the session token.
    Disconnected,
}

/// Join the coordinator at `addr` and train partitions until drained.
/// `fingerprint` must be the run fingerprint this worker computed from
/// its own dataset + partitioning + config — the handshake proves both
/// processes describe the same run before any job is shipped.
pub fn run_worker(
    addr: &str,
    dataset: &Dataset,
    cfg: &CoordinatorConfig,
    net: &NetConfig,
    fingerprint: u64,
) -> Result<()> {
    let rt = worker::init_runtime(cfg)?;
    let mut scratch = SubgraphScratch::new();
    let mut pads = PadScratch::new();
    let mut token = 0u64;
    let mut backoff = Backoff::new(cfg.seed ^ 0xC0);
    let mut failures = 0u32;
    let _span = obs::span("net", "worker").with("addr", crate::util::json::s(addr));
    loop {
        let mut welcomed = false;
        let ended = connect_and_serve(
            addr,
            dataset,
            cfg,
            fingerprint,
            &rt,
            &mut scratch,
            &mut pads,
            &mut token,
            &mut welcomed,
        );
        if welcomed {
            // a served session resets the dial budget: only
            // *consecutive* failures to establish a session count
            failures = 0;
        }
        match ended {
            Ok(Outcome::Shutdown) => {
                log::info!("drained by the coordinator; exiting");
                return Ok(());
            }
            Ok(Outcome::Disconnected) => {
                failures += 1;
            }
            Err(e) if e.is_transient() => {
                failures += 1;
                log::warn!("session attempt failed: {e}");
            }
            // Reject and other permanent errors: retrying cannot help
            Err(e) => return Err(e),
        }
        if failures > net.reconnect_attempts {
            return Err(Error::Net(format!(
                "gave up after {} consecutive failed connection attempts to {addr}",
                net.reconnect_attempts
            )));
        }
        obs::registry().counter("net.worker_redials").inc();
        let slept = backoff.sleep(failures);
        log::warn!(
            "redialing {addr} (attempt {failures}/{}) after {slept}ms",
            net.reconnect_attempts
        );
    }
}

/// Dial, handshake, and serve one connection to completion.
#[allow(clippy::too_many_arguments)]
fn connect_and_serve(
    addr: &str,
    dataset: &Dataset,
    cfg: &CoordinatorConfig,
    fingerprint: u64,
    rt: &Runtime,
    scratch: &mut SubgraphScratch,
    pads: &mut PadScratch,
    token: &mut u64,
    welcomed: &mut bool,
) -> Result<Outcome> {
    if let Some(inj) = fault::point("net.connect").fire() {
        // no corruptible payload at dial time: corrupt degrades to fail
        return Err(inj.error());
    }
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Error::Net(format!("cannot connect to {addr}: {e}")))?;
    let _ = stream.set_nodelay(true);
    Message::Hello { token: *token, fingerprint }.write_to(&mut stream)?;
    let heartbeat_ms = match Message::read_from(&mut stream)? {
        Message::Welcome { worker, token: t, heartbeat_ms } => {
            log::info!("joined as worker {worker} (session {t:016x})");
            *token = t;
            *welcomed = true;
            heartbeat_ms
        }
        Message::Reject { reason } => {
            return Err(Error::Config(format!("coordinator rejected this worker: {reason}")))
        }
        other => {
            return Err(Error::Net(format!(
                "expected welcome or reject, got frame type {}",
                other.ftype()
            )))
        }
    };
    // shared writer: the heartbeat thread and the job loop both send
    // frames; the mutex keeps every frame's bytes contiguous on the wire
    let writer = Arc::new(Mutex::new(
        stream
            .try_clone()
            .map_err(|e| Error::Net(format!("cannot clone session stream: {e}")))?,
    ));
    let beat = Heartbeat::spawn(Arc::clone(&writer), heartbeat_ms);
    let outcome = serve_loop(&mut stream, &writer, dataset, cfg, rt, scratch, pads);
    beat.stop();
    outcome
}

/// Serve assignments on an established session until shutdown or error.
fn serve_loop(
    stream: &mut TcpStream,
    writer: &Arc<Mutex<TcpStream>>,
    dataset: &Dataset,
    cfg: &CoordinatorConfig,
    rt: &Runtime,
    scratch: &mut SubgraphScratch,
    pads: &mut PadScratch,
) -> Result<Outcome> {
    loop {
        match Message::read_from(stream) {
            Ok(Message::Assign { part_id, attempt, members }) => {
                let job = Job { part_id, members, attempt };
                log::debug!(
                    "assigned partition {part_id} (attempt {attempt}, {} nodes)",
                    job.members.len()
                );
                let mut job_span = obs::span("net", "train_partition");
                if obs::tracing_enabled() {
                    job_span.attr("part", num(part_id as f64));
                    job_span.attr("attempt", num(attempt as f64));
                }
                let reply = match worker::run_job(rt, dataset, &job, cfg, scratch, pads) {
                    Ok((nodes, result)) => Message::Result {
                        part_id,
                        attempt,
                        train_secs: result.train_secs,
                        num_replicas: result.num_replicas as u64,
                        losses: result.losses,
                        // the shard ships as its exact on-disk LFS1 byte
                        // image: the leader re-validates every section
                        // checksum before trusting a row
                        shard: crate::serve::encode_shard(
                            part_id,
                            &nodes,
                            &result.embeddings,
                            result.emb_dim,
                        )?,
                    },
                    Err(e) => {
                        log::warn!("partition {part_id} (attempt {attempt}) failed: {e}");
                        Message::Failed {
                            part_id,
                            attempt,
                            code: ErrorCode::of(&e),
                            message: e.to_string(),
                        }
                    }
                };
                let mut w = writer.lock().unwrap_or_else(PoisonError::into_inner);
                if let Err(e) = reply.write_to(&mut *w) {
                    log::warn!("cannot send outcome for partition {part_id}: {e}");
                    return Ok(Outcome::Disconnected);
                }
            }
            Ok(Message::Shutdown) => {
                let mut w = writer.lock().unwrap_or_else(PoisonError::into_inner);
                let _ = Message::Bye.write_to(&mut *w);
                return Ok(Outcome::Shutdown);
            }
            Ok(other) => {
                log::debug!("ignoring unexpected frame type {}", other.ftype());
            }
            Err(e) => {
                log::warn!("connection lost: {e}");
                return Ok(Outcome::Disconnected);
            }
        }
    }
}

/// Background heartbeat: one `Heartbeat` frame per interval, stopped by
/// a condvar (no polling sleep). Exits on its own if the socket dies —
/// the main loop notices the same death through its blocking read.
struct Heartbeat {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Heartbeat {
    fn spawn(writer: Arc<Mutex<TcpStream>>, interval_ms: u64) -> Heartbeat {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let stop2 = Arc::clone(&stop);
        // lint: allow(spawn_outside_parallel) — liveness beacon thread beside a blocking training loop, not a fork-join computation
        let handle = std::thread::spawn(move || {
            let (flag, cv) = &*stop2;
            let mut stopped = flag.lock().unwrap_or_else(PoisonError::into_inner);
            while !*stopped {
                let (g, _) = cv
                    .wait_timeout(stopped, Duration::from_millis(interval_ms.max(1)))
                    .unwrap_or_else(PoisonError::into_inner);
                stopped = g;
                if *stopped {
                    return;
                }
                // release the stop flag while touching the socket so
                // stop() never waits on a stalled write
                drop(stopped);
                {
                    let mut w = writer.lock().unwrap_or_else(PoisonError::into_inner);
                    if Message::Heartbeat.write_to(&mut *w).is_err() {
                        return;
                    }
                }
                stopped = flag.lock().unwrap_or_else(PoisonError::into_inner);
            }
        });
        Heartbeat { stop, handle: Some(handle) }
    }

    fn stop(mut self) {
        let (flag, cv) = &*self.stop;
        *flag.lock().unwrap_or_else(PoisonError::into_inner) = true;
        cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
