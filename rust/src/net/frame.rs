//! `LFN1` wire frames: the length-prefixed, checksummed envelope every
//! byte of the TCP transport travels in.
//!
//! Frame layout (all little-endian), mirroring the `LFS1` shard
//! discipline — validated magic/version, overflow-safe length guard
//! *before* any allocation, and a checksum that rejects any bit flip:
//!
//! ```text
//! magic    "LFN1"      4 bytes
//! version  u16         protocol version (1)
//! ftype    u16         frame type (see `wire::Message`)
//! length   u32         payload byte count (≤ MAX_FRAME_LEN)
//! crc32    u32         IEEE CRC-32 over magic‖version‖ftype‖length‖payload
//! payload  length bytes
//! ```
//!
//! Every decode failure — truncation, bad magic/version, oversized
//! length, checksum mismatch — is a typed [`Error::Net`], never a panic
//! and never a partially-accepted frame; the session layer responds by
//! dropping the connection (a byte stream cannot resync mid-frame) and
//! letting the reconnect/requeue machinery recover. The `net.send` /
//! `net.recv` fault points live here so wire-level chaos (`fail`,
//! `delay(ms)`, `corrupt`) is as deterministic and seedable as the rest
//! of the fault surface.

use crate::error::{Error, Result};
use crate::fault;
use crate::obs;
use std::io::{Read, Write};

/// Frame magic: `LFN1` (Leiden-Fusion Net, version family 1).
pub const NET_MAGIC: &[u8; 4] = b"LFN1";

/// Protocol version carried in every frame header.
pub const NET_VERSION: u16 = 1;

/// Fixed header size: magic + version + ftype + length + crc32.
pub const HEADER_LEN: usize = 16;

/// Upper bound on a frame payload. Large enough for any realistic shard
/// (a 4M-row × dim-256 partition is ~4 GiB and would be sharded further
/// upstream long before this layer), small enough that a corrupt or
/// hostile length field can never trigger a huge allocation.
pub const MAX_FRAME_LEN: usize = 256 << 20;

/// A decoded frame: type tag + raw payload (interpreted by `wire`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub ftype: u16,
    pub payload: Vec<u8>,
}

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// Incremental IEEE CRC-32 (reflected, poly `0xEDB88320`) — hand-rolled
/// and dependency-free, like every checksum in this crate. Distinct
/// from the FNV-1a the `LFS1` shard sections use: frames want the
/// stronger burst-error detection of a true CRC because they cross a
/// network, not a filesystem.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ CRC32_TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finish(self) -> u32 {
        !self.state
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// Encode a frame: header + CRC + payload, ready for the socket.
pub fn encode_frame(ftype: u16, payload: &[u8]) -> Result<Vec<u8>> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(Error::Net(format!(
            "frame payload {} bytes exceeds MAX_FRAME_LEN {MAX_FRAME_LEN}",
            payload.len()
        )));
    }
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(NET_MAGIC);
    out.extend_from_slice(&NET_VERSION.to_le_bytes());
    out.extend_from_slice(&ftype.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    let mut crc = Crc32::new();
    crc.update(&out[..12]);
    crc.update(payload);
    out.extend_from_slice(&crc.finish().to_le_bytes());
    out.extend_from_slice(payload);
    Ok(out)
}

/// Validate the fixed header alone: magic, version, and a length bound —
/// everything that must be checked *before* allocating for the payload.
/// Returns `(ftype, payload_len, stored_crc)`.
fn validate_header(header: &[u8; HEADER_LEN]) -> Result<(u16, usize, u32)> {
    if &header[..4] != NET_MAGIC {
        return Err(Error::Net("bad frame magic (not an LFN1 stream)".into()));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != NET_VERSION {
        return Err(Error::Net(format!(
            "unsupported frame version {version} (expected {NET_VERSION})"
        )));
    }
    let ftype = u16::from_le_bytes([header[6], header[7]]);
    let len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as usize;
    if len > MAX_FRAME_LEN {
        return Err(Error::Net(format!(
            "frame declares {len} payload bytes, over MAX_FRAME_LEN {MAX_FRAME_LEN}"
        )));
    }
    let crc = u32::from_le_bytes([header[12], header[13], header[14], header[15]]);
    Ok((ftype, len, crc))
}

/// Decode one complete frame from a byte slice (header validation,
/// exact-length check, CRC verification). The property-test surface:
/// any damage yields [`Error::Net`].
pub fn decode_frame(bytes: &[u8]) -> Result<Frame> {
    if bytes.len() < HEADER_LEN {
        return Err(Error::Net(format!(
            "frame truncated: {} bytes, header needs {HEADER_LEN}",
            bytes.len()
        )));
    }
    let mut header = [0u8; HEADER_LEN];
    header.copy_from_slice(&bytes[..HEADER_LEN]);
    let (ftype, len, stored) = validate_header(&header)?;
    if bytes.len() != HEADER_LEN + len {
        return Err(Error::Net(format!(
            "frame length mismatch: {} bytes, header declares {len} payload bytes",
            bytes.len()
        )));
    }
    let mut crc = Crc32::new();
    crc.update(&bytes[..12]);
    crc.update(&bytes[HEADER_LEN..]);
    if crc.finish() != stored {
        return Err(Error::Net("frame checksum mismatch (corrupt frame)".into()));
    }
    Ok(Frame { ftype, payload: bytes[HEADER_LEN..].to_vec() })
}

/// Write one frame. Fires `net.send`: `fail` surfaces as a transient
/// injected error before any byte leaves, `delay(ms)` stalls the send,
/// `corrupt` flips one deterministic bit in the encoded frame so the
/// peer's CRC check rejects it and drops the connection.
pub fn write_frame(w: &mut impl Write, ftype: u16, payload: &[u8]) -> Result<()> {
    let mut bytes = encode_frame(ftype, payload)?;
    if let Some(inj) = fault::point("net.send").fire() {
        if inj.is_corrupt() {
            let at = inj.offset(bytes.len());
            bytes[at] ^= 1 << (inj.salt & 7);
        } else {
            return Err(inj.error());
        }
    }
    w.write_all(&bytes)
        .and_then(|()| w.flush())
        .map_err(|e| Error::Net(format!("connection write failed: {e}")))?;
    obs::registry().counter("net.frames_sent").inc();
    Ok(())
}

/// Read one frame. Fires `net.recv` (`fail` → transient injected error,
/// `corrupt` → one deterministic bit flip in the received bytes, caught
/// by the same validation path real corruption hits). The header is
/// validated before the payload allocation, so a damaged length field
/// can never provoke a huge `vec!`; every failure is [`Error::Net`].
pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
    let inj = fault::point("net.recv").fire();
    if let Some(i) = &inj {
        if !i.is_corrupt() {
            return Err(i.error());
        }
    }
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)
        .map_err(|e| Error::Net(format!("connection read failed: {e}")))?;
    let (_, len, _) = validate_header(&header)?;
    let mut bytes = vec![0u8; HEADER_LEN + len];
    bytes[..HEADER_LEN].copy_from_slice(&header);
    r.read_exact(&mut bytes[HEADER_LEN..])
        .map_err(|e| Error::Net(format!("connection read failed: {e}")))?;
    if let Some(i) = inj {
        // flip after the wire read, before validation: indistinguishable
        // from genuine line noise, rejected by the same guards
        let at = i.offset(bytes.len());
        bytes[at] ^= 1 << (i.salt & 7);
    }
    let frame = decode_frame(&bytes)?;
    obs::registry().counter("net.frames_received").inc();
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    #[test]
    fn crc32_matches_known_vectors() {
        // canonical IEEE CRC-32 check values
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn roundtrip_simple() {
        let bytes = encode_frame(7, b"hello").unwrap();
        assert_eq!(bytes.len(), HEADER_LEN + 5);
        let frame = decode_frame(&bytes).unwrap();
        assert_eq!(frame.ftype, 7);
        assert_eq!(frame.payload, b"hello");
        // empty payload is a legal frame
        let empty = encode_frame(1, b"").unwrap();
        assert_eq!(decode_frame(&empty).unwrap(), Frame { ftype: 1, payload: vec![] });
    }

    #[test]
    fn read_write_over_a_stream() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, 3, b"abc").unwrap();
        write_frame(&mut buf, 9, b"").unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(read_frame(&mut r).unwrap(), Frame { ftype: 3, payload: b"abc".to_vec() });
        assert_eq!(read_frame(&mut r).unwrap(), Frame { ftype: 9, payload: vec![] });
        // stream exhausted → clean Error::Net, not a panic
        assert!(matches!(read_frame(&mut r), Err(Error::Net(_))));
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let mut bytes = encode_frame(2, b"xy").unwrap();
        bytes[0] = b'X';
        assert!(matches!(decode_frame(&bytes), Err(Error::Net(_))));
        let mut bytes = encode_frame(2, b"xy").unwrap();
        bytes[4] = 99; // version
        assert!(matches!(decode_frame(&bytes), Err(Error::Net(_))));
    }

    #[test]
    fn rejects_oversized_length_without_allocating() {
        // header declaring a u32::MAX payload must be rejected by the
        // length guard before any allocation happens
        let mut bytes = encode_frame(1, b"").unwrap();
        bytes[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode_frame(&bytes), Err(Error::Net(_))));
        let mut r: &[u8] = &bytes;
        assert!(matches!(read_frame(&mut r), Err(Error::Net(_))));
        assert!(encode_frame(1, &vec![0u8; MAX_FRAME_LEN + 1]).is_err());
    }

    /// Property: encode→decode round-trips bit-exactly for arbitrary
    /// payloads and frame types (mirrors `prop_roundtrip_bit_exact` in
    /// the LFS1 suite).
    #[test]
    fn prop_roundtrip_bit_exact() {
        prop::check(
            "lfn1-roundtrip",
            60,
            0xF4A3,
            |rng: &mut Rng| {
                let len = rng.index(600);
                let ftype = rng.index(u16::MAX as usize) as u16;
                let payload: Vec<u8> =
                    (0..len).map(|_| rng.index(256) as u8).collect();
                (ftype, payload)
            },
            |(ftype, payload)| {
                let bytes =
                    encode_frame(*ftype, payload).map_err(|e| format!("encode: {e}"))?;
                let frame = decode_frame(&bytes).map_err(|e| format!("decode: {e}"))?;
                if frame.ftype != *ftype || &frame.payload != payload {
                    return Err("frame mismatch after round-trip".into());
                }
                // and via the stream path
                let mut r: &[u8] = &bytes;
                let frame2 = read_frame(&mut r).map_err(|e| format!("read: {e}"))?;
                if frame2 != frame {
                    return Err("stream read disagrees with slice decode".into());
                }
                Ok(())
            },
        );
    }

    /// Property: any strict prefix of a valid frame is rejected as a
    /// typed `Error::Net` — a partial read is never accepted.
    #[test]
    fn prop_rejects_truncation() {
        prop::check(
            "lfn1-truncation",
            40,
            0x7B22,
            |rng: &mut Rng| {
                let len = 1 + rng.index(300);
                let payload: Vec<u8> = (0..len).map(|i| (i * 7) as u8).collect();
                let cut = rng.f64();
                (payload, cut)
            },
            |(payload, cut)| {
                let bytes = encode_frame(5, payload).map_err(|e| format!("encode: {e}"))?;
                let keep = ((bytes.len() - 1) as f64 * cut) as usize;
                match decode_frame(&bytes[..keep]) {
                    Ok(_) => return Err(format!("decode accepted {keep}/{} bytes", bytes.len())),
                    Err(Error::Net(_)) => {}
                    Err(other) => return Err(format!("expected Error::Net, got {other}")),
                }
                let mut r: &[u8] = &bytes[..keep];
                match read_frame(&mut r) {
                    Ok(_) => Err(format!("read accepted {keep}/{} bytes", bytes.len())),
                    Err(Error::Net(_)) => Ok(()),
                    Err(other) => Err(format!("expected Error::Net, got {other}")),
                }
            },
        );
    }

    /// Property: flipping any single bit anywhere in a frame is rejected
    /// as `Error::Net` — never a panic, never a silently-altered frame.
    /// The CRC covers header and payload, so there is no blind spot.
    #[test]
    fn prop_rejects_single_bit_flips() {
        prop::check(
            "lfn1-bitflip",
            100,
            0xB1F0,
            |rng: &mut Rng| {
                let len = rng.index(120);
                let payload: Vec<u8> = (0..len).map(|_| rng.index(256) as u8).collect();
                let where_ = rng.f64();
                (payload, where_)
            },
            |(payload, where_)| {
                let mut bytes =
                    encode_frame(11, payload).map_err(|e| format!("encode: {e}"))?;
                let bit = ((bytes.len() * 8 - 1) as f64 * where_) as usize;
                bytes[bit / 8] ^= 1 << (bit % 8);
                match decode_frame(&bytes) {
                    Ok(_) => return Err(format!("decode accepted bit flip {bit}")),
                    Err(Error::Net(_)) => {}
                    Err(other) => {
                        return Err(format!("bit {bit}: expected Error::Net, got {other}"))
                    }
                }
                let mut r: &[u8] = &bytes;
                match read_frame(&mut r) {
                    // a length-field flip can leave the stream short; both
                    // rejections must still be typed Error::Net
                    Ok(_) => Err(format!("read accepted bit flip {bit}")),
                    Err(Error::Net(_)) => Ok(()),
                    Err(other) => Err(format!("bit {bit}: expected Error::Net, got {other}")),
                }
            },
        );
    }

    #[test]
    fn send_and_recv_fault_points_fire() {
        use crate::fault::{install_scoped, FaultPlan};
        {
            let _g = install_scoped(FaultPlan::parse("net.send:fail").unwrap());
            let mut buf: Vec<u8> = Vec::new();
            assert!(matches!(
                write_frame(&mut buf, 1, b"x"),
                Err(Error::Fault(_))
            ));
            assert!(buf.is_empty(), "no bytes leave on an injected send failure");
        }
        {
            let _g = install_scoped(FaultPlan::parse("net.send:corrupt").unwrap());
            let mut buf: Vec<u8> = Vec::new();
            write_frame(&mut buf, 1, b"payload").unwrap();
            // the corrupted frame must be rejected by the receiver's CRC
            let mut r: &[u8] = &buf;
            assert!(matches!(read_frame(&mut r), Err(Error::Net(_))));
        }
        {
            let good = encode_frame(1, b"payload").unwrap();
            let _g = install_scoped(FaultPlan::parse("net.recv:corrupt").unwrap());
            let mut r: &[u8] = &good;
            assert!(matches!(read_frame(&mut r), Err(Error::Net(_))));
        }
    }
}
