//! Versioned serving bundles: content addressing, crash-safe publish,
//! and the atomic hot-swap handle.
//!
//! A *bundle* is a shard directory plus its `shards.json` manifest. This
//! module gives it a lifecycle:
//!
//! * **publish** — the coordinator stamps every shard (and the classifier
//!   checkpoint) with its SHA-256 content address, bumps the manifest
//!   version past the live one, and lands the manifest crash-safely:
//!   write `shards.json.tmp` → fsync → self-check → rename → fsync dir.
//!   A crash at any point leaves either the old complete manifest or the
//!   new complete manifest — never a torn file (the `bundle.publish`
//!   fault point injects failures between the fsync and the rename to
//!   prove it).
//! * **validate** — every recorded digest is recomputed from the bytes on
//!   disk before a candidate is trusted. A digest names exactly one byte
//!   sequence, so a half-overwritten or foreign shard cannot slip in.
//! * **swap** — [`BundleHandle`] holds the serving generation (store +
//!   engine) behind an `Arc` that readers clone per request. A watcher
//!   notices `v+1` on disk, validates it, builds the *entire* next
//!   generation off to the side (open, warm, engine), and only then flips
//!   the handle — in-flight requests finish against `v` on their own
//!   `Arc`, and the old engine drains its workers and frees its slabs
//!   when the last reference drops. Any validation or build failure
//!   rejects the candidate (`serve.swap_rejected`), remembers it so it is
//!   not retried every tick, and keeps serving `v` — rollback is simply
//!   "never flip".
//!
//! Swap decisions are journaled to `swap_journal.jsonl` in the bundle
//! directory so operators (and the nightly chaos sweep) can audit every
//! flip and rejection.

use super::engine::{Engine, EngineConfig, NodeStatus};
use super::http::{Backend, ReadyInfo};
use super::shard::ShardManifest;
use super::store::ShardedEmbeddingStore;
use crate::error::{Error, Result};
use crate::fault;
use crate::graph::NodeId;
use crate::obs;
use crate::util::json::{num, obj, s};
use crate::util::sha256;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, PoisonError, RwLock};
use std::thread::JoinHandle;

/// Swap-watcher poll cadence (`repro serve --watch`).
pub const WATCH_TICK_MS: u64 = 500;

/// Name of the append-only swap audit log inside the bundle directory.
pub const SWAP_JOURNAL_FILE: &str = "swap_journal.jsonl";

/// SHA-256 content address (lowercase hex) of a file's bytes.
pub fn file_digest(path: &Path) -> Result<String> {
    let bytes = std::fs::read(path).map_err(|e| {
        Error::Serve(format!("cannot read {} for digest: {e}", path.display()))
    })?;
    Ok(sha256::digest_hex(&bytes))
}

/// Fill in the manifest's content addresses from the bytes on disk
/// (every shard entry plus the classifier checkpoint).
pub fn stamp_digests(dir: &Path, manifest: &mut ShardManifest) -> Result<()> {
    for entry in &mut manifest.shards {
        entry.sha256 = file_digest(&dir.join(&entry.file))?;
    }
    manifest.classifier_sha256 = file_digest(&dir.join(&manifest.classifier_file))?;
    Ok(())
}

/// The version of the bundle currently live in `dir`, or 0 when no
/// readable manifest exists. Reads the file directly (no fault point):
/// version discovery must not consume injections aimed at the serving
/// load path.
pub fn live_version(dir: &Path) -> usize {
    std::fs::read_to_string(ShardManifest::path_in(dir))
        .ok()
        .and_then(|text| ShardManifest::from_json_text(&text).ok())
        .map(|m| m.version)
        .unwrap_or(0)
}

/// Recompute every content address recorded in `manifest` against the
/// bytes in `dir`. An entry without a digest (pre-versioned bundle) is
/// only checked for existence — the store's LFS1 checksums still guard
/// its contents at load time.
pub fn validate(dir: &Path, manifest: &ShardManifest) -> Result<()> {
    for entry in &manifest.shards {
        let path = dir.join(&entry.file);
        let got = file_digest(&path)?;
        if !entry.sha256.is_empty() && got != entry.sha256 {
            return Err(Error::Serve(format!(
                "{}: content digest mismatch (manifest {}, file {got})",
                path.display(),
                entry.sha256
            )));
        }
    }
    let clf = dir.join(&manifest.classifier_file);
    let got = file_digest(&clf)?;
    if !manifest.classifier_sha256.is_empty() && got != manifest.classifier_sha256 {
        return Err(Error::Serve(format!(
            "{}: content digest mismatch (manifest {}, file {got})",
            clf.display(),
            manifest.classifier_sha256
        )));
    }
    Ok(())
}

/// Land `manifest` in `dir` crash-safely: write `shards.json.tmp`, fsync
/// it, re-read and parse it back (the self-check — a torn or damaged
/// candidate is caught *before* it can replace the live file), rename it
/// over `shards.json`, and fsync the directory so the rename itself is
/// durable. The `bundle.publish` fault point fires between the fsync and
/// the self-check: `fail`/`delay` model a crash or stall mid-publish,
/// `corrupt` damages the candidate bytes on disk — in every case the
/// live manifest is untouched.
pub fn publish(dir: &Path, manifest: &ShardManifest) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let live = ShardManifest::path_in(dir);
    let tmp = dir.join(SHARD_MANIFEST_TMP);
    let text = manifest.to_json_text();
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(text.as_bytes())?;
        f.sync_all()?;
    }
    if let Some(inj) = fault::point("bundle.publish").fire() {
        if inj.is_corrupt() {
            // model a torn write: damage one candidate byte on disk; the
            // self-check below must reject it and leave the live file alone
            let mut bytes = std::fs::read(&tmp)?;
            if !bytes.is_empty() {
                let at = inj.offset(bytes.len());
                bytes[at] ^= 0x01;
                std::fs::write(&tmp, &bytes)?;
            }
        } else {
            let _ = std::fs::remove_file(&tmp);
            return Err(inj.error());
        }
    }
    // self-check: the candidate must round-trip to exactly the manifest
    // we intended to publish
    let back = std::fs::read_to_string(&tmp)
        .map_err(Error::from)
        .and_then(|t| ShardManifest::from_json_text(&t));
    match back {
        Ok(m) if m == *manifest => {}
        Ok(_) => {
            let _ = std::fs::remove_file(&tmp);
            return Err(Error::Serve(
                "publish self-check failed: candidate manifest does not match \
                 the intended one; live version untouched"
                    .into(),
            ));
        }
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            return Err(Error::Serve(format!(
                "publish self-check failed: candidate manifest unreadable \
                 ({e}); live version untouched"
            )));
        }
    }
    std::fs::rename(&tmp, &live)?;
    // make the rename durable (best-effort: not all filesystems let a
    // directory handle be fsynced)
    let _ = std::fs::File::open(dir).and_then(|d| d.sync_all());
    Ok(())
}

/// Temp name `publish` stages the candidate manifest under.
pub const SHARD_MANIFEST_TMP: &str = "shards.json.tmp";

/// One immutable serving generation: the store and engine built from a
/// validated bundle version. Swapping replaces the whole generation.
pub struct Generation {
    pub version: usize,
    pub store: Arc<ShardedEmbeddingStore>,
    pub engine: Engine,
}

/// What a swap attempt decided.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SwapOutcome {
    /// On-disk version is not newer than the serving one.
    NoNewVersion,
    Swapped { from: usize, to: usize },
    /// Candidate failed validation or failed to build; `v` keeps serving
    /// and the candidate version is quarantined (not retried) until an
    /// even newer version appears.
    Rejected { candidate: usize, reason: String },
}

/// The hot-swappable bundle handle: readers take an `Arc` to the current
/// [`Generation`] per request; [`BundleHandle::try_swap`] flips it.
pub struct BundleHandle {
    dir: PathBuf,
    engine_cfg: EngineConfig,
    current: RwLock<Arc<Generation>>,
    /// Last rejected candidate version — quarantined so the watcher does
    /// not re-validate (and re-count) it every tick.
    rejected: AtomicUsize,
}

impl BundleHandle {
    pub fn new(dir: &Path, engine_cfg: EngineConfig, initial: Generation) -> Self {
        obs::registry().gauge("serve.bundle_version").set(initial.version as f64);
        BundleHandle {
            dir: dir.to_path_buf(),
            engine_cfg,
            current: RwLock::new(Arc::new(initial)),
            rejected: AtomicUsize::new(0),
        }
    }

    /// The serving generation. Cloning the `Arc` pins it for the caller:
    /// a concurrent swap cannot free slabs under an in-flight request.
    pub fn current(&self) -> Arc<Generation> {
        Arc::clone(&self.current.read().unwrap_or_else(PoisonError::into_inner))
    }

    pub fn version(&self) -> usize {
        self.current().version
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Attempt one swap to whatever version is on disk. Never panics and
    /// never degrades the serving generation: every failure path leaves
    /// `current` untouched. `Err` is reserved for transient conditions
    /// (an injected `bundle.swap` failure, an unreadable manifest) that
    /// the watcher should simply retry; a *rejected* candidate comes back
    /// as `Ok(SwapOutcome::Rejected)` and is remembered.
    pub fn try_swap(&self) -> Result<SwapOutcome> {
        let from = self.version();
        if let Some(inj) = fault::point("bundle.swap").fire() {
            if !inj.is_corrupt() {
                return Err(inj.error());
            }
            // `corrupt`: the candidate is treated as damaged without
            // touching disk — the rejection path must keep `v` serving
            let candidate = live_version(&self.dir);
            return Ok(self.reject(candidate, "injected corrupt candidate"));
        }
        let manifest = ShardManifest::load(&self.dir)?;
        if manifest.version <= from {
            return Ok(SwapOutcome::NoNewVersion);
        }
        if self.rejected.load(Ordering::Relaxed) == manifest.version {
            return Ok(SwapOutcome::NoNewVersion);
        }
        if let Err(e) = validate(&self.dir, &manifest) {
            return Ok(self.reject(manifest.version, &e.to_string()));
        }
        let built = self.build_generation(manifest.version);
        match built {
            Ok(next) => {
                let to = next.version;
                {
                    let mut cur =
                        self.current.write().unwrap_or_else(PoisonError::into_inner);
                    *cur = Arc::new(next);
                }
                obs::registry().counter("serve.swaps").inc();
                obs::registry().gauge("serve.bundle_version").set(to as f64);
                self.journal(obj(vec![
                    ("event", s("swapped")),
                    ("from", num(from as f64)),
                    ("to", num(to as f64)),
                ]));
                log::info!("bundle hot-swap: v{from} -> v{to}");
                Ok(SwapOutcome::Swapped { from, to })
            }
            Err(e) => Ok(self.reject(manifest.version, &e.to_string())),
        }
    }

    /// Build the candidate generation completely off to the side: open,
    /// warm every slab (the digest check runs during the loads), and
    /// construct the engine. The serving generation is not touched.
    fn build_generation(&self, version: usize) -> Result<Generation> {
        let store = Arc::new(ShardedEmbeddingStore::open(&self.dir)?);
        store.warm(self.engine_cfg.workers.max(1))?;
        if store.quarantined_shards() > 0 {
            return Err(Error::Serve(format!(
                "candidate v{version} has {} quarantined shard(s)",
                store.quarantined_shards()
            )));
        }
        let engine = Engine::new(self.engine_cfg.clone(), Arc::clone(&store))?;
        Ok(Generation { version, store, engine })
    }

    fn reject(&self, candidate: usize, reason: &str) -> SwapOutcome {
        self.rejected.store(candidate, Ordering::Relaxed);
        obs::registry().counter("serve.swap_rejected").inc();
        self.journal(obj(vec![
            ("event", s("rejected")),
            ("candidate", num(candidate as f64)),
            ("serving", num(self.version() as f64)),
            ("reason", s(reason)),
        ]));
        log::warn!(
            "bundle swap rejected: candidate v{candidate} ({reason}); \
             keeping v{}",
            self.version()
        );
        SwapOutcome::Rejected { candidate, reason: reason.to_string() }
    }

    /// Append one line to the swap journal (best-effort: auditing must
    /// never take down serving).
    fn journal(&self, line: crate::util::json::Json) {
        let path = self.dir.join(SWAP_JOURNAL_FILE);
        let res = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .and_then(|mut f| writeln!(f, "{}", line.to_string()));
        if let Err(e) = res {
            log::warn!("cannot append swap journal {}: {e}", path.display());
        }
    }

    /// Watch the bundle directory for a published `v+1` and hot-swap to
    /// it. Polling is cheap (one manifest read per tick) and only an
    /// on-disk version *newer* than the serving one triggers a swap
    /// attempt — so `bundle.swap` injections fire on real candidates, not
    /// on idle ticks. Runs until `shutdown` is set.
    pub fn spawn_watcher(
        self: &Arc<Self>,
        tick_ms: u64,
        shutdown: Arc<AtomicBool>,
    ) -> std::io::Result<JoinHandle<()>> {
        let handle = Arc::clone(self);
        // lint: allow(spawn_outside_parallel) — long-lived watcher thread with its own lifecycle, not fork-join data parallelism
        std::thread::Builder::new().name("lf-bundle-watch".into()).spawn(move || {
            while !shutdown.load(Ordering::Relaxed) {
                // lint: allow(sleep_outside_backoff) — bounded poll tick for new bundle versions, not a retry loop
                std::thread::sleep(std::time::Duration::from_millis(tick_ms.max(1)));
                let disk = live_version(&handle.dir);
                if disk <= handle.version()
                    || disk == handle.rejected.load(Ordering::Relaxed)
                {
                    continue;
                }
                match handle.try_swap() {
                    Ok(_) => {}
                    Err(e) => {
                        // transient (injected failure, racing publish):
                        // the next tick retries the same candidate
                        log::debug!("swap attempt for v{disk} failed: {e}");
                    }
                }
            }
        })
    }
}

impl Backend for BundleHandle {
    fn classify(&self, nodes: &[NodeId]) -> Result<Vec<NodeStatus>> {
        // pin the generation for the whole request: a swap mid-request
        // frees the old slabs only after this Arc drops
        self.current().engine.query_status(nodes)
    }

    fn ready(&self) -> ReadyInfo {
        let g = self.current();
        ReadyInfo {
            version: g.version,
            dataset: g.store.manifest().dataset.clone(),
            nodes: g.store.num_nodes(),
            quarantined: g.store.quarantined_shards(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultPlan;
    use crate::serve::shard::{shard_file_name, write_shard, ShardEntry, CLASSIFIER_FILE};

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("lf_bundle_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// A minimal on-disk bundle (one shard + a placeholder classifier)
    /// with digests stamped and version `v` published.
    fn make_bundle(dir: &Path, version: usize, emb0: f32) -> ShardManifest {
        let nodes = vec![0u32, 1, 2];
        let emb = vec![emb0, 1.0, 2.0, 3.0, 4.0, 5.0];
        write_shard(&dir.join(shard_file_name(0)), 0, &nodes, &emb, 2).unwrap();
        std::fs::write(dir.join(CLASSIFIER_FILE), b"not a real checkpoint").unwrap();
        let mut m = ShardManifest {
            version,
            dataset: "test".into(),
            task: "multiclass".into(),
            num_nodes: 3,
            dim: 2,
            classes: 2,
            classifier_file: CLASSIFIER_FILE.into(),
            classifier_sha256: String::new(),
            shards: vec![ShardEntry {
                file: shard_file_name(0),
                part_id: 0,
                rows: 3,
                sha256: String::new(),
            }],
        };
        stamp_digests(dir, &mut m).unwrap();
        publish(dir, &m).unwrap();
        m
    }

    #[test]
    fn publish_roundtrips_and_validates() {
        let _quiet = fault::exclusive();
        let dir = tmp_dir("publish");
        let m = make_bundle(&dir, 1, 10.0);
        assert!(!m.shards[0].sha256.is_empty());
        assert!(!m.classifier_sha256.is_empty());
        let back = ShardManifest::load(&dir).unwrap();
        assert_eq!(back, m);
        validate(&dir, &back).unwrap();
        assert_eq!(live_version(&dir), 1);
        assert!(!dir.join(SHARD_MANIFEST_TMP).exists(), "tmp cleaned up");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn validate_rejects_foreign_shard_bytes() {
        let _quiet = fault::exclusive();
        let dir = tmp_dir("validate");
        let m = make_bundle(&dir, 1, 10.0);
        // overwrite with a same-shape shard from a "different run"
        write_shard(
            &dir.join(shard_file_name(0)),
            0,
            &[0, 1, 2],
            &[9.0; 6],
            2,
        )
        .unwrap();
        let err = validate(&dir, &m).unwrap_err();
        assert!(err.to_string().contains("content digest mismatch"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn publish_fail_injection_leaves_live_version_untouched() {
        let dir = tmp_dir("pubfail");
        let v1 = make_bundle(&dir, 1, 10.0);
        {
            let _g =
                fault::install_scoped(FaultPlan::parse("bundle.publish:times=1:fail").unwrap());
            let mut v2 = v1.clone();
            v2.version = 2;
            let err = publish(&dir, &v2).unwrap_err();
            assert!(err.is_transient(), "{err}");
        }
        assert_eq!(live_version(&dir), 1, "live manifest untouched");
        assert_eq!(ShardManifest::load(&dir).unwrap(), v1);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn publish_corrupt_injection_is_caught_by_self_check() {
        let dir = tmp_dir("pubcorrupt");
        let v1 = make_bundle(&dir, 1, 10.0);
        {
            let _g = fault::install_scoped(
                FaultPlan::parse("bundle.publish:times=1:corrupt").unwrap(),
            );
            let mut v2 = v1.clone();
            v2.version = 2;
            let err = publish(&dir, &v2).unwrap_err();
            assert!(err.to_string().contains("self-check"), "{err}");
        }
        assert_eq!(live_version(&dir), 1, "damaged candidate never went live");
        assert_eq!(ShardManifest::load(&dir).unwrap(), v1);
        // plan exhausted: the retry lands v2 cleanly
        let mut v2 = v1.clone();
        v2.version = 2;
        publish(&dir, &v2).unwrap();
        assert_eq!(live_version(&dir), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn live_version_is_zero_without_a_manifest() {
        let dir = tmp_dir("nolive");
        assert_eq!(live_version(&dir), 0);
        std::fs::remove_dir_all(dir).ok();
    }
}
