//! Embedding serving subsystem: from training output to answered queries.
//!
//! The paper stops at offline evaluation; this layer carries the same
//! per-partition independence through to inference, turning a coordinator
//! run into a queryable model:
//!
//! 1. **Shards** ([`shard`]) — the coordinator writes each partition's
//!    owned-node embeddings as a versioned `LFS1` binary file the moment
//!    that partition finishes training, plus a JSON manifest
//!    (`shards.json`) and the trained integration-MLP checkpoint.
//!    Leiden-Fusion partitions are disjoint connected components, so the
//!    shards are an exact, communication-free cover of the node set.
//! 2. **Index** ([`index`]) — [`OwnershipIndex`] resolves `NodeId →
//!    (shard, row)` with a direct-indexed dense table on compact id
//!    spaces (one load, no hashing) and a sorted binary-search fallback
//!    on sparse ones.
//! 3. **Store** ([`store`]) — [`ShardedEmbeddingStore`] opens a shard
//!    directory, builds the index from headers alone, and loads each
//!    shard once into an immutable `Arc<[f32]>` slab: after first touch
//!    (or an eager parallel [`ShardedEmbeddingStore::warm`]) row gathers
//!    are lock-free and allocation-free. Shards that fail their `LFS1`
//!    section checksums — or are truncated or missing — are
//!    **quarantined**, not fatal: the store keeps serving every healthy
//!    shard and [`engine::NodeStatus::Unavailable`] reports the holes
//!    per row (see *Robustness* in `DESIGN.md`).
//! 4. **Engine** ([`engine`]) — a worker thread pool batches
//!    node-classification queries (up to `batch_size` per PJRT forward)
//!    against the trained MLP, behind a striped, single-flight
//!    [`ResultCache`]: cache hits answer on the client thread, concurrent
//!    misses for one node coalesce into a single forward, and completions
//!    wake only that node's waiters. Batched logits are bit-identical to
//!    the offline `classify` path because the MLP is row-wise.
//!
//! 5. **Bundle** ([`bundle`]) — content-addressed, versioned serving
//!    bundles: `shards.json` carries a monotonically increasing
//!    `version` and a sha256 per shard (and for the classifier), the
//!    coordinator publishes crash-safely (temp + fsync + rename), and
//!    [`bundle::BundleHandle`] hot-swaps a running server to a newly
//!    published version — validating every checksum first, draining
//!    in-flight queries against the old generation, and rolling back
//!    (quarantining the candidate, keep serving) if validation fails.
//! 6. **HTTP front-end** ([`http`]) — a dependency-free HTTP/1.1 server
//!    (`repro serve --http`) with keep-alive, incremental parsing that
//!    turns every malformed input into a typed error, bounded admission
//!    with explicit backpressure (429/503/408), and `/healthz`,
//!    `/readyz`, `/metrics` endpoints.
//!
//! Driven by the `serve` / `query` CLI subcommands and measured by
//! `benches/bench_serve.rs` (QPS, p50/p99 latency, hit rate, per-stage
//! breakdown → `BENCH_serve.json`).

pub mod bundle;
pub mod cache;
pub mod engine;
pub mod http;
pub mod index;
pub mod shard;
pub mod store;

pub use bundle::{BundleHandle, Generation, SwapOutcome};
pub use cache::{Flight, Lookup, LruCache, ResultCache, MAX_LRU_CAPACITY};
pub use engine::{Engine, EngineConfig, EngineStats, NodeStatus, Prediction};
pub use index::{IndexLayout, OwnershipIndex};
pub use shard::{
    decode_shard_bytes, encode_shard, read_shard, read_shard_header, shard_file_name,
    write_shard, ShardEntry, ShardHeader, ShardManifest, CLASSIFIER_FILE, SHARD_MANIFEST_FILE,
};
pub use http::{format_status_line, Backend, HttpServer, HttpServerConfig, ReadyInfo};
pub use store::ShardedEmbeddingStore;
