//! Embedding serving subsystem: from training output to answered queries.
//!
//! The paper stops at offline evaluation; this layer carries the same
//! per-partition independence through to inference, turning a coordinator
//! run into a queryable model:
//!
//! 1. **Shards** ([`shard`]) — the coordinator writes each partition's
//!    owned-node embeddings as a versioned `LFS1` binary file the moment
//!    that partition finishes training, plus a JSON manifest
//!    (`shards.json`) and the trained integration-MLP checkpoint.
//!    Leiden-Fusion partitions are disjoint connected components, so the
//!    shards are an exact, communication-free cover of the node set.
//! 2. **Store** ([`store`]) — [`ShardedEmbeddingStore`] opens a shard
//!    directory, builds a `NodeId → (shard, row)` ownership index from
//!    headers alone, and loads embedding rows lazily on first touch.
//! 3. **Engine** ([`engine`]) — a worker thread pool batches
//!    node-classification queries (up to `batch_size` per PJRT forward)
//!    against the trained MLP, with an LRU result cache ([`cache`]) in
//!    front. Batched logits are bit-identical to the offline `classify`
//!    path because the MLP is row-wise.
//!
//! Driven by the `serve` / `query` CLI subcommands and measured by
//! `benches/bench_serve.rs` (QPS, p50/p99 latency).

pub mod cache;
pub mod engine;
pub mod shard;
pub mod store;

pub use cache::LruCache;
pub use engine::{Engine, EngineConfig, EngineStats, Prediction};
pub use shard::{
    read_shard, read_shard_header, shard_file_name, write_shard, ShardEntry, ShardHeader,
    ShardManifest, CLASSIFIER_FILE, SHARD_MANIFEST_FILE,
};
pub use store::ShardedEmbeddingStore;
