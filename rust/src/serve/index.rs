//! Ownership index: `NodeId → (shard, row)` resolution without a hash
//! probe on the hot path.
//!
//! Shards are disjoint by construction (one per Leiden-Fusion partition),
//! so every served node has exactly one `(shard, row)` location. The
//! pre-overhaul store resolved it through a `HashMap<NodeId, (u32, u32)>`
//! — a hash + probe + 12-byte entry per node on every single query. This
//! module replaces it with a packed **global row** scheme:
//!
//! * rows are numbered globally in shard order — shard `s` owns global
//!   rows `offsets[s]..offsets[s + 1]` — so one `u32` encodes both the
//!   shard and the row within it;
//! * when the id space is dense (node ids are compact `u32`s, the normal
//!   case: datasets number nodes `0..n`), the index is a direct-indexed
//!   `Vec<u32>` — a lookup is one bounds-checked load;
//! * when the id space is sparse (external ids, partial bundles), the
//!   index falls back to a sorted-slice binary search: two cache-friendly
//!   parallel arrays instead of a `HashMap`'s scattered buckets.
//!
//! Both layouts sit behind [`OwnershipIndex`]; callers never branch on
//! the representation. Lookups allocate nothing.

use crate::error::{Error, Result};
use crate::graph::NodeId;

/// Sentinel for "node not owned" in the dense layout.
const NONE: u32 = u32::MAX;

/// Dense layout is chosen when the id space is at least this full:
/// `max_id + 1 <= DENSE_MAX_SPREAD * num_rows`. At spread 2 the dense
/// table costs at most 8 bytes per served node — always cheaper than the
/// `HashMap` it replaced — while genuinely sparse id spaces (e.g. a
/// partial bundle of high external ids) fall back to binary search
/// instead of allocating `max_id` slots.
const DENSE_MAX_SPREAD: u64 = 2;

/// Force a representation (tests and benches; production uses `Auto`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexLayout {
    Auto,
    Dense,
    Sparse,
}

enum Repr {
    /// `rows[v]` = global row of node `v`, [`NONE`] when unowned.
    Dense(Vec<u32>),
    /// Parallel arrays sorted by id: `ids[i]` is served at global row
    /// `rows[i]`.
    Sparse { ids: Vec<NodeId>, rows: Vec<u32> },
}

/// Immutable node → location index built once from shard headers.
pub struct OwnershipIndex {
    /// `offsets[s]` = first global row of shard `s`; `offsets[k]` = total
    /// rows. Monotone non-decreasing (empty shards repeat a value).
    offsets: Vec<u32>,
    repr: Repr,
}

impl OwnershipIndex {
    /// Build from per-shard node-id lists (row order), picking the layout
    /// automatically. Rejects nodes owned by two shards.
    pub fn build(shards: &[&[NodeId]]) -> Result<OwnershipIndex> {
        Self::build_with_layout(shards, IndexLayout::Auto)
    }

    /// [`OwnershipIndex::build`] with a forced layout (equivalence tests
    /// and micro-benches; `Auto` everywhere else).
    pub fn build_with_layout(
        shards: &[&[NodeId]],
        layout: IndexLayout,
    ) -> Result<OwnershipIndex> {
        let mut offsets = Vec::with_capacity(shards.len() + 1);
        let mut total: u64 = 0;
        let mut max_id: u64 = 0;
        offsets.push(0u32);
        for nodes in shards {
            total += nodes.len() as u64;
            if total >= NONE as u64 {
                return Err(Error::Serve(format!(
                    "shard bundle has {total} rows — the packed row index holds \
                     at most {}",
                    NONE - 1
                )));
            }
            offsets.push(total as u32);
            for &v in *nodes {
                max_id = max_id.max(v as u64);
            }
        }
        let dense = match layout {
            IndexLayout::Dense => true,
            IndexLayout::Sparse => false,
            IndexLayout::Auto => total > 0 && max_id + 1 <= DENSE_MAX_SPREAD * total,
        };
        let repr = if dense {
            let slots = if total == 0 { 0 } else { max_id as usize + 1 };
            let mut rows = vec![NONE; slots];
            for (s, nodes) in shards.iter().enumerate() {
                let base = offsets[s];
                for (r, &v) in nodes.iter().enumerate() {
                    let slot = &mut rows[v as usize];
                    if *slot != NONE {
                        return Err(dup_err(v));
                    }
                    *slot = base + r as u32;
                }
            }
            Repr::Dense(rows)
        } else {
            let mut pairs: Vec<(NodeId, u32)> = Vec::with_capacity(total as usize);
            for (s, nodes) in shards.iter().enumerate() {
                let base = offsets[s];
                for (r, &v) in nodes.iter().enumerate() {
                    pairs.push((v, base + r as u32));
                }
            }
            pairs.sort_unstable();
            for w in pairs.windows(2) {
                if w[0].0 == w[1].0 {
                    return Err(dup_err(w[0].0));
                }
            }
            let ids = pairs.iter().map(|&(v, _)| v).collect();
            let rows = pairs.iter().map(|&(_, r)| r).collect();
            Repr::Sparse { ids, rows }
        };
        Ok(OwnershipIndex { offsets, repr })
    }

    /// Total served nodes.
    pub fn len(&self) -> usize {
        self.offsets.last().copied().unwrap_or(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn num_shards(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Whether the direct-indexed layout was chosen.
    pub fn is_dense(&self) -> bool {
        matches!(self.repr, Repr::Dense(_))
    }

    /// Global row of `v`, `None` when unowned. Allocation- and hash-free:
    /// one load on the dense layout, a binary search on the sparse one.
    #[inline]
    pub fn global_row(&self, v: NodeId) -> Option<u32> {
        match &self.repr {
            Repr::Dense(rows) => rows.get(v as usize).copied().filter(|&r| r != NONE),
            Repr::Sparse { ids, rows } => {
                ids.binary_search(&v).ok().map(|i| rows[i])
            }
        }
    }

    /// Shard owning global row `gr` (which must be `< len()`).
    #[inline]
    pub fn shard_of_row(&self, gr: u32) -> u32 {
        // offsets is sorted; the owner is the last shard starting at or
        // before gr. partition_point over ~k+1 entries — k is the
        // partition count, so this touches one or two cache lines.
        (self.offsets.partition_point(|&o| o <= gr) - 1) as u32
    }

    /// Resolve `v` to `(shard, row-within-shard)`.
    #[inline]
    pub fn locate(&self, v: NodeId) -> Option<(u32, u32)> {
        let gr = self.global_row(v)?;
        let s = self.shard_of_row(gr);
        Some((s, gr - self.offsets[s as usize]))
    }

    /// Every served node id, in unspecified order.
    pub fn node_ids(&self) -> Box<dyn Iterator<Item = NodeId> + '_> {
        match &self.repr {
            Repr::Dense(rows) => Box::new(
                rows.iter()
                    .enumerate()
                    .filter(|(_, &r)| r != NONE)
                    .map(|(v, _)| v as NodeId),
            ),
            Repr::Sparse { ids, .. } => Box::new(ids.iter().copied()),
        }
    }
}

fn dup_err(v: NodeId) -> Error {
    Error::Serve(format!(
        "node {v} owned by two shards (partitions must be disjoint)"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;
    use std::collections::HashMap;

    fn build(shards: &[Vec<NodeId>], layout: IndexLayout) -> Result<OwnershipIndex> {
        let views: Vec<&[NodeId]> = shards.iter().map(|s| s.as_slice()).collect();
        OwnershipIndex::build_with_layout(&views, layout)
    }

    #[test]
    fn dense_layout_resolves_compact_ids() {
        let idx = build(&[vec![0, 2, 4], vec![1, 3]], IndexLayout::Auto).unwrap();
        assert!(idx.is_dense());
        assert_eq!(idx.len(), 5);
        assert_eq!(idx.num_shards(), 2);
        assert_eq!(idx.locate(0), Some((0, 0)));
        assert_eq!(idx.locate(4), Some((0, 2)));
        assert_eq!(idx.locate(1), Some((1, 0)));
        assert_eq!(idx.locate(3), Some((1, 1)));
        assert_eq!(idx.locate(5), None);
        assert_eq!(idx.locate(999), None);
    }

    #[test]
    fn sparse_ids_fall_back_to_binary_search() {
        // two nodes with ids in the millions: dense would allocate 2M
        // slots for 2 rows — Auto must pick the sorted layout
        let idx = build(&[vec![2_000_000], vec![1_000]], IndexLayout::Auto).unwrap();
        assert!(!idx.is_dense());
        assert_eq!(idx.locate(2_000_000), Some((0, 0)));
        assert_eq!(idx.locate(1_000), Some((1, 0)));
        assert_eq!(idx.locate(0), None);
        assert_eq!(idx.locate(1_999_999), None);
    }

    #[test]
    fn empty_shards_do_not_shift_ownership() {
        let idx =
            build(&[vec![0, 1], vec![], vec![2]], IndexLayout::Auto).unwrap();
        assert_eq!(idx.len(), 3);
        assert_eq!(idx.locate(2), Some((2, 0)));
        assert_eq!(idx.node_ids().count(), 3);
    }

    #[test]
    fn duplicate_ownership_is_rejected_in_both_layouts() {
        for layout in [IndexLayout::Dense, IndexLayout::Sparse] {
            let err = build(&[vec![0, 1], vec![1, 2]], layout).unwrap_err();
            assert!(err.to_string().contains("two shards"), "{layout:?}: {err}");
            // duplicate within one shard too
            let err = build(&[vec![3, 3]], layout).unwrap_err();
            assert!(err.to_string().contains("two shards"), "{layout:?}: {err}");
        }
    }

    #[test]
    fn empty_bundle_is_empty_index() {
        let idx = build(&[], IndexLayout::Auto).unwrap();
        assert_eq!(idx.len(), 0);
        assert!(idx.is_empty());
        assert_eq!(idx.locate(0), None);
        assert_eq!(idx.node_ids().count(), 0);
    }

    /// Property: dense and sparse layouts answer identically to each other
    /// and to a HashMap oracle, on random shard layouts (shuffled ids,
    /// uneven shard sizes, empty shards, id-space gaps).
    #[test]
    fn prop_dense_sparse_equivalent() {
        prop::check(
            "ownership-dense-vs-sparse",
            40,
            0x0DE5,
            |rng: &mut Rng| {
                let k = 1 + rng.index(6);
                let n = rng.index(200);
                // spread controls density: 1 = compact ids, 8 = very sparse
                let spread = 1 + rng.index(8);
                let mut ids: Vec<NodeId> = Vec::with_capacity(n);
                let mut used = std::collections::HashSet::new();
                while ids.len() < n {
                    let v = rng.index(n.max(1) * spread) as NodeId;
                    if used.insert(v) {
                        ids.push(v);
                    }
                }
                let mut shards: Vec<Vec<NodeId>> = vec![Vec::new(); k];
                for v in ids {
                    shards[rng.index(k)].push(v);
                }
                shards
            },
            |shards| {
                let dense = build(shards, IndexLayout::Dense)
                    .map_err(|e| format!("dense build: {e}"))?;
                let sparse = build(shards, IndexLayout::Sparse)
                    .map_err(|e| format!("sparse build: {e}"))?;
                let auto = build(shards, IndexLayout::Auto)
                    .map_err(|e| format!("auto build: {e}"))?;
                let mut oracle: HashMap<NodeId, (u32, u32)> = HashMap::new();
                for (s, nodes) in shards.iter().enumerate() {
                    for (r, &v) in nodes.iter().enumerate() {
                        oracle.insert(v, (s as u32, r as u32));
                    }
                }
                let max_probe = shards
                    .iter()
                    .flatten()
                    .copied()
                    .max()
                    .map(|m| m as usize + 3)
                    .unwrap_or(8);
                for v in 0..max_probe as NodeId {
                    let want = oracle.get(&v).copied();
                    for (name, idx) in
                        [("dense", &dense), ("sparse", &sparse), ("auto", &auto)]
                    {
                        if idx.locate(v) != want {
                            return Err(format!(
                                "{name} layout: node {v}: {:?} != oracle {:?}",
                                idx.locate(v),
                                want
                            ));
                        }
                    }
                }
                if dense.len() != oracle.len() || sparse.len() != oracle.len() {
                    return Err("len diverged from oracle".into());
                }
                let mut a: Vec<NodeId> = dense.node_ids().collect();
                let mut b: Vec<NodeId> = sparse.node_ids().collect();
                a.sort_unstable();
                b.sort_unstable();
                if a != b {
                    return Err("node_ids diverged between layouts".into());
                }
                Ok(())
            },
        );
    }
}
