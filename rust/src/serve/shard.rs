//! `LFS1` embedding shards: the on-disk contract between training and
//! serving.
//!
//! Because Leiden-Fusion partitions are disjoint connected components, the
//! global embedding matrix shards naturally by partition: each shard holds
//! the owned-node rows of exactly one partition, written by the coordinator
//! the moment that partition finishes training. A JSON shard manifest
//! (`shards.json`) ties the shard files together with the trained
//! integration-classifier checkpoint (`LFC1`, see `train/checkpoint.rs`,
//! whose idiom this format follows).
//!
//! Shard file layout (all little-endian):
//!
//! ```text
//! magic     "LFS1"            4 bytes
//! part_id   u32               owning partition
//! rows      u64               node count
//! dim       u32               embedding width
//! nodes     rows × u32        global node ids, row order
//! nodes_crc u64               FNV-1a over part_id‖rows‖dim‖nodes bytes
//! data      rows·dim × f32    embeddings, row-major
//! data_crc  u64               FNV-1a over data bytes
//! trailer   u64               == rows (truncation guard)
//! ```
//!
//! The two per-section checksums close the single-bit-flip hole the
//! pure length/trailer guards left open: *any* flip anywhere in the
//! file is rejected — magic flips by the magic check, `rows`/`dim`
//! flips by the length check, node-id and header flips by `nodes_crc`,
//! embedding flips by `data_crc`, checksum flips by their own mismatch,
//! trailer flips by the trailer check. A damaged shard therefore
//! surfaces as a clean [`Error::Serve`] for the store to quarantine —
//! never a panic, never silently-wrong embeddings
//! (`prop_rejects_single_bit_flips` pins this).

use crate::error::{Error, Result};
use crate::fault;
use crate::graph::NodeId;
use crate::util::json::{num, obj, s, Json};
use crate::util::Fnv64;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

pub const SHARD_MAGIC: &[u8; 4] = b"LFS1";

/// Manifest file name inside a shard directory (distinct from the runtime's
/// `manifest.json` to keep the two contracts visually separate).
pub const SHARD_MANIFEST_FILE: &str = "shards.json";

/// Classifier checkpoint file name inside a shard directory.
pub const CLASSIFIER_FILE: &str = "classifier.lfc";

/// Canonical shard file name for a partition.
pub fn shard_file_name(part_id: u32) -> String {
    format!("part{part_id}.lfs")
}

/// Header of one shard: everything except the embedding rows.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardHeader {
    pub part_id: u32,
    pub rows: usize,
    pub dim: usize,
    /// Global node ids in row order.
    pub nodes: Vec<NodeId>,
}

/// FNV-1a over the header fields + node-id bytes (the `nodes_crc`
/// section coverage).
fn header_crc(part_id: u32, rows: u64, dim: u32, nodes: &[NodeId]) -> u64 {
    let mut h = Fnv64::new();
    h.write(&part_id.to_le_bytes());
    h.write(&rows.to_le_bytes());
    h.write(&dim.to_le_bytes());
    for &v in nodes {
        h.write(&v.to_le_bytes());
    }
    h.finish()
}

/// Encode one partition's owned-node embeddings as `LFS1` bytes — the
/// exact byte sequence [`write_shard`] puts on disk. Shared by the file
/// writer and the net transport, which ships trained shards over the
/// wire through this same checksummed format so the leader validates
/// remote results with the very path serving trusts.
pub fn encode_shard(part_id: u32, nodes: &[NodeId], emb: &[f32], dim: usize) -> Result<Vec<u8>> {
    if emb.len() != nodes.len() * dim {
        return Err(Error::Serve(format!(
            "shard block {} != {} nodes × dim {dim}",
            emb.len(),
            nodes.len()
        )));
    }
    let mut out: Vec<u8> =
        Vec::with_capacity(20 + nodes.len() * 4 + 8 + emb.len() * 4 + 8 + 8);
    out.extend_from_slice(SHARD_MAGIC);
    out.extend_from_slice(&part_id.to_le_bytes());
    out.extend_from_slice(&(nodes.len() as u64).to_le_bytes());
    out.extend_from_slice(&(dim as u32).to_le_bytes());
    for &v in nodes {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.extend_from_slice(
        &header_crc(part_id, nodes.len() as u64, dim as u32, nodes).to_le_bytes(),
    );
    let mut data_crc = Fnv64::new();
    for &x in emb {
        let bytes = x.to_le_bytes();
        data_crc.write(&bytes);
        out.extend_from_slice(&bytes);
    }
    out.extend_from_slice(&data_crc.finish().to_le_bytes());
    out.extend_from_slice(&(nodes.len() as u64).to_le_bytes()); // trailer
    Ok(out)
}

/// Decode and fully validate `LFS1` bytes: the in-memory equivalent of
/// [`read_shard`] — same magic/length/checksum/trailer guards, same
/// clean [`Error::Serve`] on any damage, no filesystem and no
/// `shard.read` fault point (wire transport has its own `net.*`
/// domain).
pub fn decode_shard_bytes(bytes: &[u8]) -> Result<(ShardHeader, Vec<f32>)> {
    let mut r: &[u8] = bytes;
    let header = read_header_impl(&mut r, "inline shard", bytes.len() as u64, false)?;
    let data = read_body_impl(&mut r, "inline shard", &header)?;
    Ok((header, data))
}

/// Write one partition's owned-node embeddings as an `LFS1` shard.
pub fn write_shard(
    path: &Path,
    part_id: u32,
    nodes: &[NodeId],
    emb: &[f32],
    dim: usize,
) -> Result<()> {
    let injection = fault::point("shard.write").part(part_id).fire();
    if let Some(inj) = injection {
        if !inj.is_corrupt() {
            return Err(inj.error());
        }
    }
    let encoded = encode_shard(part_id, nodes, emb, dim)?;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = BufWriter::new(std::fs::File::create(path)?);
    out.write_all(&encoded)?;
    out.flush()?;
    drop(out);
    if let Some(inj) = injection {
        // `corrupt`: model a torn/bit-rotten write — the shard lands on
        // disk with one deterministic bit flipped, for the read-side
        // checksums to catch and the store to quarantine
        let mut bytes = std::fs::read(path)?;
        if !bytes.is_empty() {
            let at = inj.offset(bytes.len());
            bytes[at] ^= 1 << (inj.salt & 7);
            std::fs::write(path, &bytes)?;
        }
    }
    Ok(())
}

/// Read and validate the fixed-size part of the header, then the node ids.
///
/// `file_len` is the on-disk size: the declared `rows`/`dim` are checked
/// against it (with overflow-safe arithmetic) *before* any allocation, so
/// a corrupt or malicious header cannot trigger a huge `vec!` or a
/// capacity panic — it gets a clean `Error::Serve` instead. This doubles
/// as the truncation guard: a file shorter than the header implies fails
/// here, before any embedding bytes are touched.
fn read_header(r: &mut impl Read, path: &Path, file_len: u64) -> Result<ShardHeader> {
    read_header_impl(r, &path.display().to_string(), file_len, true)
}

fn read_header_impl(
    r: &mut impl Read,
    label: &str,
    total_len: u64,
    fire_fault: bool,
) -> Result<ShardHeader> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != SHARD_MAGIC {
        return Err(Error::Serve(format!("{label}: not an LFS1 shard")));
    }
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b4)?;
    let part_id = u32::from_le_bytes(b4);
    if fire_fault {
        if let Some(inj) = fault::point("shard.read").part(part_id).fire() {
            if !inj.is_corrupt() {
                return Err(inj.error());
            }
            // `corrupt`: poison the declared row count — every downstream
            // guard (length check) sees a damaged header
            return Err(Error::Serve(format!(
                "{label}: shard corrupt or truncated (injected read corruption)"
            )));
        }
    }
    r.read_exact(&mut b8)?;
    let rows64 = u64::from_le_bytes(b8);
    r.read_exact(&mut b4)?;
    let dim64 = u32::from_le_bytes(b4) as u64;
    // header (magic+part+rows+dim) + nodes + nodes_crc + data + data_crc
    // + trailer, overflow-safe
    let expect = rows64
        .checked_mul(4)
        .and_then(|ids| rows64.checked_mul(dim64)?.checked_mul(4)?.checked_add(ids))
        .and_then(|body| body.checked_add((4 + 4 + 8 + 4) + 8 + 8 + 8));
    match expect {
        Some(e) if e == total_len => {}
        _ => {
            return Err(Error::Serve(format!(
                "{label}: shard corrupt or truncated ({total_len} bytes, header declares \
                 {rows64} rows × dim {dim64})"
            )))
        }
    }
    let rows = rows64 as usize;
    let dim = dim64 as usize;
    let mut nodes = vec![0 as NodeId; rows];
    for v in nodes.iter_mut() {
        r.read_exact(&mut b4)?;
        *v = NodeId::from_le_bytes(b4);
    }
    r.read_exact(&mut b8)?;
    if u64::from_le_bytes(b8) != header_crc(part_id, rows64, dim64 as u32, &nodes) {
        return Err(Error::Serve(format!(
            "{label}: shard header checksum mismatch (corrupt node ids or header)"
        )));
    }
    Ok(ShardHeader { part_id, rows, dim, nodes })
}

/// Read the embedding rows + data checksum + trailer that follow a
/// validated header (shared by the file reader and the wire decoder).
fn read_body_impl(r: &mut impl Read, label: &str, header: &ShardHeader) -> Result<Vec<f32>> {
    let mut b4 = [0u8; 4];
    let mut data = vec![0f32; header.rows * header.dim];
    let mut crc = Fnv64::new();
    for x in data.iter_mut() {
        r.read_exact(&mut b4)?;
        crc.write(&b4);
        *x = f32::from_le_bytes(b4);
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    if u64::from_le_bytes(b8) != crc.finish() {
        return Err(Error::Serve(format!(
            "{label}: shard data checksum mismatch (corrupt embedding bytes)"
        )));
    }
    r.read_exact(&mut b8)?;
    if u64::from_le_bytes(b8) as usize != header.rows {
        return Err(Error::Serve(format!("{label}: shard truncated")));
    }
    Ok(data)
}

/// Read only the header + ownership ids of a shard (the length-based
/// corruption/truncation guard runs before any allocation; embedding
/// bytes stay untouched).
pub fn read_shard_header(path: &Path) -> Result<ShardHeader> {
    let file = std::fs::File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    read_header(&mut r, path, file_len)
}

/// Read a full shard: header, embedding rows, and trailer check.
pub fn read_shard(path: &Path) -> Result<(ShardHeader, Vec<f32>)> {
    let file = std::fs::File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let header = read_header(&mut r, path, file_len)?;
    let data = read_body_impl(&mut r, &path.display().to_string(), &header)?;
    Ok((header, data))
}

/// One shard file as listed in the manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    pub file: String,
    pub part_id: u32,
    pub rows: usize,
    /// Content address: lowercase-hex SHA-256 of the shard file bytes.
    /// Recorded by `bundle::publish`; empty = unrecorded (a pre-versioned
    /// bundle), in which case validation falls back to a full LFS1 decode
    /// and the lazy-load digest check is skipped.
    pub sha256: String,
}

/// `shards.json` — inventory of a serving bundle: shard files, global
/// dimensions, and the classifier checkpoint the engine must load.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardManifest {
    pub version: usize,
    pub dataset: String,
    /// `multiclass` | `multilabel` — selects the pred artifact family.
    pub task: String,
    /// Total owned nodes across all shards. Equals the dataset's node
    /// count only for a full-coverage run — an `on_failure = skip` run
    /// writes a bundle covering the surviving partitions only.
    pub num_nodes: usize,
    /// Embedding width; must match the MLP artifact's `f`.
    pub dim: usize,
    /// Logit columns of the classifier artifact (bucketed class dim).
    pub classes: usize,
    pub classifier_file: String,
    /// Content address of the classifier checkpoint (lowercase-hex
    /// SHA-256); empty = unrecorded, as for [`ShardEntry::sha256`].
    pub classifier_sha256: String,
    pub shards: Vec<ShardEntry>,
}

impl ShardManifest {
    pub fn path_in(dir: &Path) -> std::path::PathBuf {
        dir.join(SHARD_MANIFEST_FILE)
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(Self::path_in(dir), self.to_json_text())?;
        Ok(())
    }

    /// The manifest's canonical JSON text — what [`Self::save`] writes and
    /// `bundle::publish` stages into the temp candidate file.
    pub fn to_json_text(&self) -> String {
        let shards = Json::Arr(
            self.shards
                .iter()
                .map(|e| {
                    obj(vec![
                        ("file", s(&e.file)),
                        ("part_id", num(e.part_id as f64)),
                        ("rows", num(e.rows as f64)),
                        ("sha256", s(&e.sha256)),
                    ])
                })
                .collect(),
        );
        let root = obj(vec![
            ("version", num(self.version as f64)),
            ("dataset", s(&self.dataset)),
            ("task", s(&self.task)),
            ("num_nodes", num(self.num_nodes as f64)),
            ("dim", num(self.dim as f64)),
            ("classes", num(self.classes as f64)),
            ("classifier_file", s(&self.classifier_file)),
            ("classifier_sha256", s(&self.classifier_sha256)),
            ("shards", shards),
        ]);
        root.to_string()
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = Self::path_in(dir);
        let mut text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Serve(format!(
                "cannot read {} (run `repro train --shards <dir>` first?): {e}",
                path.display()
            ))
        })?;
        if let Some(inj) = fault::point("manifest.load").fire() {
            if !inj.is_corrupt() {
                return Err(inj.error());
            }
            // `corrupt`: garble the manifest text mid-stream — the JSON
            // parse (or a missing-field check) rejects it downstream
            text.truncate(inj.offset(text.len()));
        }
        Self::from_json_text(&text)
    }

    /// Parse a manifest from its JSON text (the `shards.json` contents).
    /// Split out of [`Self::load`] so `bundle::publish` can self-check a
    /// candidate file before atomically renaming it over the live one.
    pub fn from_json_text(text: &str) -> Result<Self> {
        let root = Json::parse(text)?;
        let gets = |k: &str| -> Result<String> {
            root.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| Error::Serve(format!("shard manifest missing {k:?}")))
        };
        let getn = |k: &str| -> Result<usize> {
            root.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Serve(format!("shard manifest missing {k:?}")))
        };
        let shards = root
            .get("shards")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Serve("shard manifest missing shards array".into()))?
            .iter()
            .map(|e| {
                Ok(ShardEntry {
                    file: e
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| Error::Serve("shard entry missing file".into()))?
                        .to_string(),
                    part_id: e
                        .get("part_id")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| Error::Serve("shard entry missing part_id".into()))?
                        as u32,
                    rows: e
                        .get("rows")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| Error::Serve("shard entry missing rows".into()))?,
                    // absent in pre-versioned manifests: empty means
                    // "no content address recorded"
                    sha256: e
                        .get("sha256")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardManifest {
            version: getn("version")?,
            dataset: gets("dataset")?,
            task: gets("task")?,
            num_nodes: getn("num_nodes")?,
            dim: getn("dim")?,
            classes: getn("classes")?,
            classifier_file: gets("classifier_file")?,
            classifier_sha256: root
                .get("classifier_sha256")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            shards,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("lf_shard_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_simple() {
        let path = tmp("simple.lfs");
        let nodes = vec![4, 0, 9];
        let emb = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        write_shard(&path, 7, &nodes, &emb, 2).unwrap();
        let header = read_shard_header(&path).unwrap();
        assert_eq!(header.part_id, 7);
        assert_eq!(header.rows, 3);
        assert_eq!(header.dim, 2);
        assert_eq!(header.nodes, nodes);
        let (h2, data) = read_shard(&path).unwrap();
        assert_eq!(h2, header);
        assert_eq!(data, emb);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_block_size_mismatch() {
        let path = tmp("bad_block.lfs");
        assert!(write_shard(&path, 0, &[1, 2], &[0.0; 3], 2).is_err());
    }

    #[test]
    fn rejects_absurd_header_without_allocating() {
        // magic + part_id + rows = u64::MAX + dim: must be a clean error,
        // not a capacity panic / OOM from trusting the declared size
        let path = tmp("absurd.lfs");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(SHARD_MAGIC);
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&8u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_shard_header(&path).is_err());
        assert!(read_shard(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("magic.lfs");
        std::fs::write(&path, b"LFC1\x00\x00\x00\x00").unwrap();
        assert!(read_shard_header(&path).is_err());
        assert!(read_shard(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    /// Property: save→load preserves every embedding bit-exactly, for
    /// arbitrary shapes including empty shards, NaN, ±0.0, subnormals, ∞.
    #[test]
    fn prop_roundtrip_bit_exact() {
        prop::check(
            "lfs1-roundtrip",
            40,
            0xEED5,
            |rng: &mut Rng| {
                let rows = rng.index(50);
                let dim = 1 + rng.index(16);
                let nodes: Vec<NodeId> =
                    (0..rows).map(|_| rng.index(1 << 20) as NodeId).collect();
                let emb: Vec<f32> = (0..rows * dim)
                    .map(|i| match rng.index(8) {
                        0 => f32::NAN,
                        1 => f32::INFINITY,
                        2 => -0.0,
                        3 => f32::MIN_POSITIVE / 2.0, // subnormal
                        _ => (rng.f64() * 2.0 - 1.0) as f32 * (i as f32 + 1.0),
                    })
                    .collect();
                let part = rng.index(64) as u32;
                (part, dim, nodes, emb)
            },
            |(part, dim, nodes, emb)| {
                let path = tmp(&format!("prop_{part}_{}_{}.lfs", dim, nodes.len()));
                write_shard(&path, *part, nodes, emb, *dim)
                    .map_err(|e| format!("write: {e}"))?;
                let (header, data) = read_shard(&path).map_err(|e| format!("read: {e}"))?;
                std::fs::remove_file(&path).ok();
                if header.part_id != *part || header.dim != *dim || header.nodes != *nodes {
                    return Err("header mismatch".into());
                }
                if data.len() != emb.len() {
                    return Err(format!("len {} != {}", data.len(), emb.len()));
                }
                for (i, (a, b)) in data.iter().zip(emb).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("row bit mismatch at {i}: {a:?} != {b:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Property: any strict prefix of a valid shard file is rejected by
    /// both the eager reader and the header-only open path (mirrors the
    /// LFC1 checkpoint truncation guard).
    #[test]
    fn prop_rejects_truncation() {
        prop::check(
            "lfs1-truncation",
            25,
            0x7A11,
            |rng: &mut Rng| {
                let rows = 1 + rng.index(20);
                let dim = 1 + rng.index(8);
                let nodes: Vec<NodeId> = (0..rows).map(|v| v as NodeId).collect();
                let emb: Vec<f32> = (0..rows * dim).map(|i| i as f32 * 0.5).collect();
                let cut = rng.f64();
                (dim, nodes, emb, cut)
            },
            |(dim, nodes, emb, cut)| {
                let path = tmp(&format!("trunc_{}_{}.lfs", dim, nodes.len()));
                write_shard(&path, 3, nodes, emb, *dim).map_err(|e| format!("write: {e}"))?;
                let full = std::fs::read(&path).map_err(|e| e.to_string())?;
                // cut somewhere strictly inside the file
                let keep = 1 + ((full.len() - 2) as f64 * cut) as usize;
                std::fs::write(&path, &full[..keep]).map_err(|e| e.to_string())?;
                let eager = read_shard(&path);
                let lazy = read_shard_header(&path);
                std::fs::remove_file(&path).ok();
                if eager.is_ok() {
                    return Err(format!("read_shard accepted {keep}/{} bytes", full.len()));
                }
                if lazy.is_ok() {
                    return Err(format!(
                        "read_shard_header accepted {keep}/{} bytes",
                        full.len()
                    ));
                }
                Ok(())
            },
        );
    }

    /// Property: flipping any single bit anywhere in a shard file is
    /// rejected by the full read as a clean `Error::Serve` — never a
    /// panic, never silently-wrong embeddings. The lazy header read may
    /// legitimately accept flips past the header section, but must
    /// never panic and never return altered ids. Pins the per-section
    /// checksum scheme.
    #[test]
    fn prop_rejects_single_bit_flips() {
        prop::check(
            "lfs1-bitflip",
            80,
            0xB17F,
            |rng: &mut Rng| {
                let rows = 1 + rng.index(12);
                let dim = 1 + rng.index(6);
                let nodes: Vec<NodeId> = (0..rows).map(|v| v as NodeId * 3).collect();
                let emb: Vec<f32> =
                    (0..rows * dim).map(|i| i as f32 * 0.25 - 1.0).collect();
                let where_ = rng.f64();
                (dim, nodes, emb, where_)
            },
            |(dim, nodes, emb, where_)| {
                let path = tmp(&format!("flip_{}_{}.lfs", dim, nodes.len()));
                write_shard(&path, 5, nodes, emb, *dim).map_err(|e| format!("write: {e}"))?;
                let mut bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
                let bit = ((bytes.len() * 8 - 1) as f64 * where_) as usize;
                bytes[bit / 8] ^= 1 << (bit % 8);
                std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
                let eager = read_shard(&path);
                let lazy = read_shard_header(&path);
                std::fs::remove_file(&path).ok();
                match eager {
                    Ok(_) => return Err(format!("read_shard accepted bit flip {bit}")),
                    Err(Error::Serve(_)) => {}
                    Err(other) => {
                        return Err(format!("bit {bit}: expected Error::Serve, got {other}"))
                    }
                }
                if let Ok(h) = lazy {
                    // flips past the header region are invisible to the
                    // lazy path — but what it returns must be undamaged
                    if h.part_id != 5 || h.dim != *dim || &h.nodes != nodes {
                        return Err(format!("header read returned altered ids (bit {bit})"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Pin the on-disk checksum layout: both section checksums are
    /// FNV-1a 64 at fixed offsets, so a foreign writer can interoperate
    /// and a format drift fails loudly here.
    #[test]
    fn checksum_layout_is_pinned() {
        let path = tmp("pinned.lfs");
        let nodes: Vec<NodeId> = vec![7, 9];
        let emb = vec![1.5f32, -2.5, 0.0, 42.0];
        write_shard(&path, 3, &nodes, &emb, 2).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // layout: 20-byte fixed header, 8 node bytes, nodes_crc,
        // 16 data bytes, data_crc, trailer
        assert_eq!(bytes.len(), 20 + 8 + 8 + 16 + 8 + 8);
        let mut h = crate::util::Fnv64::new();
        h.write(&3u32.to_le_bytes());
        h.write(&2u64.to_le_bytes());
        h.write(&2u32.to_le_bytes());
        h.write(&7u32.to_le_bytes());
        h.write(&9u32.to_le_bytes());
        assert_eq!(&bytes[28..36], &h.finish().to_le_bytes());
        let mut d = crate::util::Fnv64::new();
        for x in &emb {
            d.write(&x.to_le_bytes());
        }
        assert_eq!(&bytes[52..60], &d.finish().to_le_bytes());
        assert_eq!(&bytes[60..68], &2u64.to_le_bytes());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn encode_matches_file_bytes_and_decodes() {
        // the in-memory codec and the file writer must emit the exact
        // same bytes — the wire transport relies on this equivalence
        let path = tmp("encode_eq.lfs");
        let nodes: Vec<NodeId> = vec![11, 2, 5];
        let emb = vec![0.5f32, -1.0, 3.25, f32::NAN, 0.0, -0.0];
        write_shard(&path, 9, &nodes, &emb, 2).unwrap();
        let file_bytes = std::fs::read(&path).unwrap();
        let encoded = encode_shard(9, &nodes, &emb, 2).unwrap();
        assert_eq!(file_bytes, encoded);
        let (header, data) = decode_shard_bytes(&encoded).unwrap();
        assert_eq!(header.part_id, 9);
        assert_eq!(header.nodes, nodes);
        for (a, b) in data.iter().zip(&emb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // any single damaged byte is rejected cleanly
        let mut bad = encoded.clone();
        bad[40] ^= 0x10;
        assert!(matches!(decode_shard_bytes(&bad), Err(Error::Serve(_))));
        assert!(matches!(
            decode_shard_bytes(&encoded[..encoded.len() - 3]),
            Err(_)
        ));
        assert!(encode_shard(0, &[1, 2], &[0.0; 3], 2).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn manifest_roundtrip() {
        let dir = tmp("manifest_dir");
        let m = ShardManifest {
            version: 1,
            dataset: "karate".into(),
            task: "multiclass".into(),
            num_nodes: 34,
            dim: 16,
            classes: 4,
            classifier_file: CLASSIFIER_FILE.into(),
            classifier_sha256: "ab".repeat(32),
            shards: vec![
                ShardEntry {
                    file: shard_file_name(0),
                    part_id: 0,
                    rows: 18,
                    sha256: "cd".repeat(32),
                },
                ShardEntry { file: shard_file_name(1), part_id: 1, rows: 16, sha256: String::new() },
            ],
        };
        m.save(&dir).unwrap();
        let back = ShardManifest::load(&dir).unwrap();
        assert_eq!(m, back);
        std::fs::remove_dir_all(dir).ok();
    }

    /// Manifests written before content addressing (no `sha256` /
    /// `classifier_sha256` keys) must still load, with empty digests.
    #[test]
    fn manifest_without_digests_loads() {
        let dir = tmp("manifest_compat");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join(SHARD_MANIFEST_FILE),
            r#"{"version":1,"dataset":"karate","task":"multiclass","num_nodes":34,
                "dim":16,"classes":4,"classifier_file":"classifier.ckpt",
                "shards":[{"file":"part0.lfs","part_id":0,"rows":34}]}"#,
        )
        .unwrap();
        let m = ShardManifest::load(&dir).unwrap();
        assert_eq!(m.classifier_sha256, "");
        assert_eq!(m.shards[0].sha256, "");
        assert_eq!(m.shards[0].rows, 34);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn manifest_missing_is_helpful() {
        let err = ShardManifest::load(Path::new("/nonexistent_lf")).unwrap_err();
        assert!(err.to_string().contains("--shards"));
    }
}
