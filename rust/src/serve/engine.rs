//! Concurrent query engine: worker thread pool + request batching over the
//! PJRT MLP classifier.
//!
//! Clients call [`Engine::query`] with node ids; requests land in a shared
//! queue. Each worker owns a thread-local [`Runtime`] (PJRT clients are not
//! `Send`, exactly as in the training coordinator), drains up to
//! `batch_size` requests, gathers the embedding rows from the shared
//! [`ShardedEmbeddingStore`], packs them into the classifier bucket's `x`,
//! and runs **one** MLP forward for the whole batch. The MLP is row-wise,
//! so batched logits are bit-identical to the offline `classify` path.
//!
//! An LRU result cache sits in front of the queue: hits are answered on
//! the client thread without waking a worker.

use super::cache::LruCache;
use super::store::ShardedEmbeddingStore;
use crate::error::{Error, Result};
use crate::graph::NodeId;
use crate::runtime::{ArtifactMeta, Manifest, Runtime, Tensor};
use crate::train::checkpoint::load_tensors;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Engine tuning knobs (see the `[serve]` config section).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Compiled-artifact directory (manifest + HLO text).
    pub artifacts_dir: PathBuf,
    /// Max queries folded into one MLP forward. Clamped to the artifact's
    /// node bucket.
    pub batch_size: usize,
    /// Worker threads, each with a private PJRT runtime.
    pub workers: usize,
    /// LRU result-cache entries (0 disables caching).
    pub cache_capacity: usize,
}

impl Default for EngineConfig {
    /// Knob defaults come from [`crate::config::ServeConfig`] — the one
    /// source of truth shared with the `[serve]` config section and CLI.
    fn default() -> Self {
        let d = crate::config::ServeConfig::default();
        EngineConfig {
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            batch_size: d.batch_size,
            workers: d.workers,
            cache_capacity: d.cache_capacity,
        }
    }
}

/// Answer for one queried node. `logits` is the raw MLP output row and is
/// the ground truth; `class`/`score` are conveniences derived from it.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub node: NodeId,
    /// Argmax over the logit columns. For **multiclass** bundles this is
    /// the offline `classify` evaluation rule (bucketed class dims
    /// included). For **multilabel** bundles the tasks are independent
    /// binary targets — this is merely the highest-scoring task; read
    /// per-task scores from `logits` instead.
    pub class: usize,
    /// Logit of the predicted class.
    pub score: f32,
    /// Full logit row (artifact's `c` columns; per-task scores for
    /// multilabel).
    pub logits: Vec<f32>,
}

/// Monotonic serving counters (snapshot via [`Engine::stats`]).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests: u64,
    pub cache_hits: u64,
    pub batches: u64,
    /// Requests answered by a PJRT forward (requests - cache_hits - errors).
    pub computed: u64,
}

struct Request {
    idx: usize,
    node: NodeId,
    tx: mpsc::Sender<(usize, Result<Prediction>)>,
}

struct QueueState {
    q: VecDeque<Request>,
    live_workers: usize,
    /// Set when a worker fails to initialise; poisons future queries.
    poisoned: Option<String>,
}

struct Shared {
    state: Mutex<QueueState>,
    notify: Condvar,
    shutdown: AtomicBool,
    store: Arc<ShardedEmbeddingStore>,
    cache: Mutex<LruCache<NodeId, Prediction>>,
    /// Trained integration-MLP parameters (from the shard bundle).
    params: Vec<Tensor>,
    /// Pred-artifact metadata resolved at construction time.
    meta: ArtifactMeta,
    cfg: EngineConfig,
    requests: AtomicU64,
    cache_hits: AtomicU64,
    batches: AtomicU64,
    computed: AtomicU64,
}

/// The serving engine. `&self` methods are thread-safe; clone node lists
/// into it from as many client threads as you like.
pub struct Engine {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Engine {
    /// Validate the bundle against the artifact manifest and start the
    /// worker pool. Fails fast (before any thread spawns) if the classifier
    /// checkpoint or artifact shapes don't line up with the shards.
    pub fn new(cfg: EngineConfig, store: Arc<ShardedEmbeddingStore>) -> Result<Engine> {
        let man = Manifest::load(&cfg.artifacts_dir)?;
        let sm = store.manifest();
        // prefer a bucket that fits the whole batch; otherwise take the
        // largest available and clamp the batch to it
        let meta = match man.select("mlp", &sm.task, "pred", cfg.batch_size.max(1), 0) {
            Ok(m) => m.clone(),
            Err(_) => man
                .artifacts
                .iter()
                .filter(|a| a.model == "mlp" && a.task == sm.task && a.role == "pred")
                .max_by_key(|a| a.dims.n)
                .ok_or_else(|| {
                    Error::Serve(format!("no mlp/{}/pred artifact in manifest", sm.task))
                })?
                .clone(),
        };
        if meta.dims.f != store.dim() {
            return Err(Error::Serve(format!(
                "classifier artifact expects dim {} embeddings, shards have {}",
                meta.dims.f,
                store.dim()
            )));
        }
        if meta.dims.c != sm.classes {
            return Err(Error::Serve(format!(
                "classifier artifact has {} logit columns, shard bundle trained {}",
                meta.dims.c, sm.classes
            )));
        }
        let params = load_tensors(&store.dir().join(&sm.classifier_file))?;
        if params.len() != meta.num_params() {
            return Err(Error::Serve(format!(
                "classifier checkpoint has {} tensors, artifact expects {}",
                params.len(),
                meta.num_params()
            )));
        }
        for (t, spec) in params.iter().zip(&meta.inputs) {
            if t.len() != spec.num_elements() {
                return Err(Error::Serve(format!(
                    "classifier tensor {} has {} elements, artifact expects {}",
                    spec.name,
                    t.len(),
                    spec.num_elements()
                )));
            }
        }

        let workers = cfg.workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                live_workers: workers,
                poisoned: None,
            }),
            notify: Condvar::new(),
            shutdown: AtomicBool::new(false),
            store,
            cache: Mutex::new(LruCache::new(cfg.cache_capacity)),
            params,
            meta,
            cfg,
            requests: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            computed: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let worker_shared = Arc::clone(&shared);
            match std::thread::Builder::new()
                .name(format!("lf-serve-{wid}"))
                .spawn(move || worker_loop(wid, worker_shared))
            {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // release any already-spawned workers before bailing
                    shared.shutdown.store(true, Ordering::Release);
                    shared.notify.notify_all();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::Serve(format!("cannot spawn worker: {e}")));
                }
            }
        }
        Ok(Engine { shared, workers: handles })
    }

    /// Classify a batch of nodes. Blocks until every answer arrives;
    /// results come back in input order. Unknown node ids fail the whole
    /// call (partial answers would silently skew downstream aggregation).
    pub fn query(&self, nodes: &[NodeId]) -> Result<Vec<Prediction>> {
        if nodes.is_empty() {
            return Ok(Vec::new());
        }
        self.shared.requests.fetch_add(nodes.len() as u64, Ordering::Relaxed);
        let mut out: Vec<Option<Prediction>> = vec![None; nodes.len()];

        // ---- cache fast path on the client thread -----------------------
        // a poisoned cache mutex degrades to cache-off (all misses), the
        // same way the worker insert path does — it must not fail queries
        let mut misses: Vec<(usize, NodeId)> = Vec::new();
        match self.shared.cache.lock() {
            Ok(mut cache) => {
                for (i, &v) in nodes.iter().enumerate() {
                    match cache.get(&v) {
                        Some(p) => out[i] = Some(p.clone()),
                        None => misses.push((i, v)),
                    }
                }
            }
            Err(_) => misses.extend(nodes.iter().copied().enumerate()),
        }
        let hits = nodes.len() - misses.len();
        self.shared.cache_hits.fetch_add(hits as u64, Ordering::Relaxed);

        if !misses.is_empty() {
            let (tx, rx) = mpsc::channel();
            {
                let mut st = self
                    .shared
                    .state
                    .lock()
                    .map_err(|_| Error::Serve("queue lock poisoned".into()))?;
                if let Some(msg) = &st.poisoned {
                    return Err(Error::Serve(format!("engine poisoned: {msg}")));
                }
                if self.shared.shutdown.load(Ordering::Acquire) || st.live_workers == 0 {
                    return Err(Error::Serve("engine is shut down".into()));
                }
                for &(idx, node) in &misses {
                    st.q.push_back(Request { idx, node, tx: tx.clone() });
                }
            }
            self.shared.notify.notify_all();
            drop(tx);
            for _ in 0..misses.len() {
                let (idx, res) = rx.recv().map_err(|_| {
                    Error::Serve("serving workers exited mid-query".into())
                })?;
                out[idx] = Some(res?);
            }
        }
        Ok(out.into_iter().map(|p| p.expect("every slot answered")).collect())
    }

    /// Convenience single-node query.
    pub fn query_one(&self, node: NodeId) -> Result<Prediction> {
        Ok(self.query(&[node])?.pop().expect("one answer"))
    }

    pub fn stats(&self) -> EngineStats {
        EngineStats {
            requests: self.shared.requests.load(Ordering::Relaxed),
            cache_hits: self.shared.cache_hits.load(Ordering::Relaxed),
            batches: self.shared.batches.load(Ordering::Relaxed),
            computed: self.shared.computed.load(Ordering::Relaxed),
        }
    }

    pub fn store(&self) -> &ShardedEmbeddingStore {
        &self.shared.store
    }

    /// Effective max batch (config clamped to the artifact bucket).
    pub fn max_batch(&self) -> usize {
        self.shared.cfg.batch_size.clamp(1, self.shared.meta.dims.n)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.notify.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Retires its worker on drop — including an unwind out of the batch
/// loop — so a panicking worker still decrements `live_workers` and the
/// last one to die fails queued requests instead of stranding clients.
struct RetireGuard {
    shared: Arc<Shared>,
    poison: Option<String>,
}

impl Drop for RetireGuard {
    fn drop(&mut self) {
        retire_worker(&self.shared, self.poison.take());
    }
}

/// Mark this worker dead; if it is the last one, fail queued requests so
/// no client blocks forever. `poison` carries an init-failure message.
fn retire_worker(shared: &Shared, poison: Option<String>) {
    let mut st = match shared.state.lock() {
        Ok(st) => st,
        Err(_) => return,
    };
    st.live_workers -= 1;
    if let Some(msg) = poison {
        if st.poisoned.is_none() {
            st.poisoned = Some(msg);
        }
    }
    if st.live_workers == 0 || st.poisoned.is_some() {
        let reason = st
            .poisoned
            .clone()
            .unwrap_or_else(|| "engine shut down".to_string());
        for r in st.q.drain(..) {
            let _ = r.tx.send((r.idx, Err(Error::Serve(reason.clone()))));
        }
    }
    drop(st);
    shared.notify.notify_all();
}

fn worker_loop(wid: usize, shared: Arc<Shared>) {
    // All exit paths — normal shutdown, init failure, and panics in the
    // batch loop — retire the worker through this guard.
    let mut guard = RetireGuard { shared: Arc::clone(&shared), poison: None };
    // Thread-local PJRT runtime + compiled classifier, as in the trainer.
    let init = Runtime::new(&shared.cfg.artifacts_dir)
        .and_then(|rt| rt.load(&shared.meta.name).map(|exe| (rt, exe)));
    let (_rt, exe) = match init {
        Ok(pair) => pair,
        Err(e) => {
            log::error!("serve worker {wid}: init failed: {e}");
            guard.poison = Some(e.to_string());
            return;
        }
    };
    let dims = exe.meta.dims.clone();
    let batch_cap = shared.cfg.batch_size.clamp(1, dims.n);
    log::debug!(
        "serve worker {wid} up: artifact {} (bucket n={}, f={}, c={})",
        exe.meta.name,
        dims.n,
        dims.f,
        dims.c
    );
    // Reusable PJRT input list: params are cloned once per worker, and the
    // final slot is the bucket-sized `x` buffer rewritten per batch — the
    // hot path allocates nothing.
    let mut inputs: Vec<Tensor> = shared.params.iter().cloned().collect();
    inputs.push(Tensor::F32(vec![0f32; dims.n * dims.f]));
    let mut prev_rows = 0usize;

    loop {
        let batch: Vec<Request> = {
            let mut st = match shared.state.lock() {
                Ok(st) => st,
                Err(_) => return, // guard retires
            };
            loop {
                if !st.q.is_empty() {
                    break;
                }
                if shared.shutdown.load(Ordering::Acquire) || st.poisoned.is_some() {
                    return; // guard retires after `st` unlocks
                }
                st = match shared.notify.wait(st) {
                    Ok(st) => st,
                    Err(_) => return,
                };
            }
            let take = st.q.len().min(batch_cap);
            st.q.drain(..take).collect()
        };
        process_batch(&shared, &exe, &dims, &mut inputs, &mut prev_rows, batch);
    }
}

/// Run one batch through the classifier. `inputs` is the worker's reusable
/// PJRT input list (params + trailing `x` buffer); `prev_rows` tracks how
/// many `x` rows the previous batch wrote so only the stale tail is
/// re-zeroed (the MLP is row-wise, but deterministic buffers keep unused
/// logit rows reproducible).
fn process_batch(
    shared: &Shared,
    exe: &crate::runtime::Executable,
    dims: &crate::runtime::Dims,
    inputs: &mut [Tensor],
    prev_rows: &mut usize,
    batch: Vec<Request>,
) {
    shared.batches.fetch_add(1, Ordering::Relaxed);
    let f = dims.f;
    let c = dims.c;

    // Gather embedding rows into the reusable x buffer; requests whose
    // node is unknown (or whose shard fails to load) are answered
    // individually with an error.
    let mut live: Vec<Request> = Vec::with_capacity(batch.len());
    {
        let x = match inputs.last_mut() {
            Some(Tensor::F32(x)) => x,
            _ => unreachable!("worker inputs always end with the f32 x buffer"),
        };
        for r in batch {
            let row = live.len();
            match shared.store.copy_embedding(r.node, &mut x[row * f..(row + 1) * f]) {
                Ok(()) => live.push(r),
                Err(e) => {
                    let _ = r.tx.send((r.idx, Err(e)));
                }
            }
        }
        if live.len() < *prev_rows {
            x[live.len() * f..*prev_rows * f].fill(0.0);
        }
    }
    *prev_rows = live.len();
    if live.is_empty() {
        return;
    }

    // One MLP forward for the whole batch.
    let logits = match exe.run(inputs).and_then(|out| {
        out.into_iter()
            .next()
            .ok_or_else(|| Error::Serve("pred artifact returned no outputs".into()))?
            .as_f32()
            .map(<[f32]>::to_vec)
    }) {
        Ok(l) => l,
        Err(e) => {
            let msg = e.to_string();
            for r in live {
                let _ = r.tx.send((r.idx, Err(Error::Serve(msg.clone()))));
            }
            return;
        }
    };

    let mut cache = shared.cache.lock().ok();
    for (row, r) in live.into_iter().enumerate() {
        let slice = &logits[row * c..(row + 1) * c];
        let (class, score) = slice
            .iter()
            .enumerate()
            .fold((0, f32::NEG_INFINITY), |(bi, bs), (i, &v)| {
                if v > bs { (i, v) } else { (bi, bs) }
            });
        let p = Prediction { node: r.node, class, score, logits: slice.to_vec() };
        if let Some(cache) = cache.as_mut() {
            cache.put(r.node, p.clone());
        }
        shared.computed.fetch_add(1, Ordering::Relaxed);
        let _ = r.tx.send((r.idx, Ok(p)));
    }
}
