//! Concurrent query engine: worker thread pool + request batching over the
//! PJRT MLP classifier.
//!
//! Clients call [`Engine::query`] with node ids. Each id goes through the
//! striped single-flight [`ResultCache`] first: a **hit** is answered on
//! the client thread; a **join** blocks on the id's in-flight computation
//! (one MLP forward serves every concurrent asker — no stampede); only a
//! **leader** enqueues a compute request. Workers steal whole batches
//! from the queue under one short lock, gather embedding rows straight
//! into the reusable bucket-padded `x` tensor (no per-row allocation, no
//! lock on the slab fast path — see `store.rs`), run **one** MLP forward
//! for the batch, and publish each row through its flight — waking only
//! that id's waiters, never every client.
//!
//! Each worker owns a thread-local [`Runtime`] (PJRT clients are not
//! `Send`, exactly as in the training coordinator). The MLP is row-wise,
//! so batched logits are bit-identical to the offline `classify` path —
//! `tests/serve_roundtrip.rs` asserts this at the bit level under
//! concurrent load.

use super::cache::{Flight, Lookup, ResultCache};
use super::store::ShardedEmbeddingStore;
use crate::error::{Error, Result};
use crate::graph::NodeId;
use crate::obs::{self, Counter, Histogram};
use crate::runtime::{ArtifactMeta, Manifest, Runtime, Tensor};
use crate::train::checkpoint::load_tensors;
use crate::util::json::num;
use crate::util::Stopwatch;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Engine tuning knobs (see the `[serve]` config section).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Compiled-artifact directory (manifest + HLO text).
    pub artifacts_dir: PathBuf,
    /// Max queries folded into one MLP forward. Clamped to the artifact's
    /// node bucket.
    pub batch_size: usize,
    /// Worker threads, each with a private PJRT runtime.
    pub workers: usize,
    /// LRU result-cache entries across all stripes (0 disables caching;
    /// single-flight miss coalescing stays on).
    pub cache_capacity: usize,
    /// Cache stripes (rounded up to a power of two; 0 = auto: 4 per
    /// worker). More stripes = less contention, slightly worse LRU-ness.
    pub cache_stripes: usize,
}

impl Default for EngineConfig {
    /// Knob defaults come from [`crate::config::ServeConfig`] — the one
    /// source of truth shared with the `[serve]` config section and CLI.
    fn default() -> Self {
        let d = crate::config::ServeConfig::default();
        EngineConfig {
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            batch_size: d.batch_size,
            workers: d.workers,
            cache_capacity: d.cache_capacity,
            cache_stripes: d.cache_stripes,
        }
    }
}

/// Per-row answer from [`Engine::query_status`]: the degraded-mode
/// counterpart of [`Prediction`]. A bundle with quarantined shards (see
/// `store.rs`) keeps serving healthy rows as `Ready`; rows whose shard is
/// quarantined — or whose node id is unknown — come back `Unavailable`
/// with the underlying reason instead of failing the whole batch.
#[derive(Clone, Debug)]
pub enum NodeStatus {
    Ready(Prediction),
    Unavailable { node: NodeId, reason: String },
}

impl NodeStatus {
    pub fn is_ready(&self) -> bool {
        matches!(self, NodeStatus::Ready(_))
    }

    /// The prediction, if this row was answered.
    pub fn prediction(&self) -> Option<&Prediction> {
        match self {
            NodeStatus::Ready(p) => Some(p),
            NodeStatus::Unavailable { .. } => None,
        }
    }
}

/// Answer for one queried node. `logits` is the raw MLP output row and is
/// the ground truth; `class`/`score` are conveniences derived from it.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub node: NodeId,
    /// Argmax over the logit columns. For **multiclass** bundles this is
    /// the offline `classify` evaluation rule (bucketed class dims
    /// included). For **multilabel** bundles the tasks are independent
    /// binary targets — this is merely the highest-scoring task; read
    /// per-task scores from `logits` instead.
    pub class: usize,
    /// Logit of the predicted class.
    pub score: f32,
    /// Full logit row (artifact's `c` columns; per-task scores for
    /// multilabel).
    pub logits: Vec<f32>,
}

/// Monotonic serving counters (snapshot via [`Engine::stats`]). This is
/// a *view* over the engine's owned [`obs`] registry instances: the same
/// numbers surface globally under `serve.*` in `repro metrics`, while
/// each engine still reads only its own instances here (the `*_secs`
/// totals are histogram sums, which are exact — see
/// [`obs::metrics::Histogram::sum`]).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests: u64,
    /// Requests answered from the LRU on the client thread.
    pub cache_hits: u64,
    /// Requests answered by joining another caller's in-flight forward
    /// (single-flight coalescing; no extra PJRT work).
    pub coalesced: u64,
    pub batches: u64,
    /// Requests answered by a PJRT forward (requests - cache_hits -
    /// coalesced - errors).
    pub computed: u64,
    /// Cumulative worker time gathering embedding rows into `x`.
    pub gather_secs: f64,
    /// Cumulative worker time inside the PJRT forward.
    pub forward_secs: f64,
    /// Cumulative worker time publishing predictions (argmax + cache
    /// insert + flight wakeups).
    pub publish_secs: f64,
}

/// One enqueued leader computation. Answer it with [`Request::finish`];
/// if it is dropped unanswered (a panic path missed it), the drop guard
/// error-completes the flight so waiters unblock — the stale in-flight
/// table entry this leaves is self-healed by `ResultCache::lookup`.
struct Request {
    node: NodeId,
    flight: Option<Arc<Flight<Prediction>>>,
}

impl Request {
    fn new(node: NodeId, flight: Arc<Flight<Prediction>>) -> Request {
        Request { node, flight: Some(flight) }
    }

    /// Publish the result through the cache (LRU insert on `Ok`, retire
    /// the in-flight entry, wake this node's waiters) and disarm the
    /// drop guard.
    fn finish(
        mut self,
        cache: &ResultCache<NodeId, Prediction>,
        result: std::result::Result<Prediction, String>,
    ) {
        if let Some(f) = self.flight.take() {
            cache.complete(&self.node, &f, result);
        }
    }
}

impl Drop for Request {
    fn drop(&mut self) {
        if let Some(f) = self.flight.take() {
            f.complete(Err("serve request dropped without an answer".to_string()));
        }
    }
}

struct QueueState {
    q: VecDeque<Request>,
    live_workers: usize,
    /// Set when a worker fails to initialise; poisons future queries.
    poisoned: Option<String>,
}

/// This engine's owned instances in the global metrics registry: private
/// cells for the per-engine [`EngineStats`] view, merged across engines
/// by `repro metrics` snapshots.
struct EngineMetrics {
    requests: Counter,
    cache_hits: Counter,
    coalesced: Counter,
    batches: Counter,
    computed: Counter,
    /// Per-batch gather/forward/publish latencies; sums are the
    /// cumulative stage seconds `EngineStats` reports.
    gather: Histogram,
    forward: Histogram,
    publish: Histogram,
}

impl EngineMetrics {
    fn new() -> EngineMetrics {
        let reg = obs::registry();
        EngineMetrics {
            requests: reg.owned_counter("serve.requests"),
            cache_hits: reg.owned_counter("serve.cache_hits"),
            coalesced: reg.owned_counter("serve.coalesced"),
            batches: reg.owned_counter("serve.batches"),
            computed: reg.owned_counter("serve.computed"),
            gather: reg.owned_histogram("serve.gather_secs"),
            forward: reg.owned_histogram("serve.forward_secs"),
            publish: reg.owned_histogram("serve.publish_secs"),
        }
    }
}

struct Shared {
    state: Mutex<QueueState>,
    notify: Condvar,
    shutdown: AtomicBool,
    store: Arc<ShardedEmbeddingStore>,
    cache: ResultCache<NodeId, Prediction>,
    /// Trained integration-MLP parameters (from the shard bundle).
    params: Vec<Tensor>,
    /// Pred-artifact metadata resolved at construction time.
    meta: ArtifactMeta,
    cfg: EngineConfig,
    metrics: EngineMetrics,
}

/// The serving engine. `&self` methods are thread-safe; clone node lists
/// into it from as many client threads as you like.
pub struct Engine {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Engine {
    /// Validate the bundle against the artifact manifest and start the
    /// worker pool. Fails fast (before any thread spawns) if the classifier
    /// checkpoint or artifact shapes don't line up with the shards.
    pub fn new(cfg: EngineConfig, store: Arc<ShardedEmbeddingStore>) -> Result<Engine> {
        let man = Manifest::load(&cfg.artifacts_dir)?;
        let sm = store.manifest();
        // prefer a bucket that fits the whole batch; otherwise take the
        // largest available and clamp the batch to it
        let meta = match man.select("mlp", &sm.task, "pred", cfg.batch_size.max(1), 0) {
            Ok(m) => m.clone(),
            Err(_) => man
                .artifacts
                .iter()
                .filter(|a| a.model == "mlp" && a.task == sm.task && a.role == "pred")
                .max_by_key(|a| a.dims.n)
                .ok_or_else(|| {
                    Error::Serve(format!("no mlp/{}/pred artifact in manifest", sm.task))
                })?
                .clone(),
        };
        if meta.dims.f != store.dim() {
            return Err(Error::Serve(format!(
                "classifier artifact expects dim {} embeddings, shards have {}",
                meta.dims.f,
                store.dim()
            )));
        }
        if meta.dims.c != sm.classes {
            return Err(Error::Serve(format!(
                "classifier artifact has {} logit columns, shard bundle trained {}",
                meta.dims.c, sm.classes
            )));
        }
        let params = load_tensors(&store.dir().join(&sm.classifier_file))?;
        if params.len() != meta.num_params() {
            return Err(Error::Serve(format!(
                "classifier checkpoint has {} tensors, artifact expects {}",
                params.len(),
                meta.num_params()
            )));
        }
        for (t, spec) in params.iter().zip(&meta.inputs) {
            if t.len() != spec.num_elements() {
                return Err(Error::Serve(format!(
                    "classifier tensor {} has {} elements, artifact expects {}",
                    spec.name,
                    t.len(),
                    spec.num_elements()
                )));
            }
        }

        let workers = cfg.workers.max(1);
        let stripes = if cfg.cache_stripes == 0 { workers * 4 } else { cfg.cache_stripes };
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                q: VecDeque::new(),
                live_workers: workers,
                poisoned: None,
            }),
            notify: Condvar::new(),
            shutdown: AtomicBool::new(false),
            store,
            cache: ResultCache::new(cfg.cache_capacity, stripes),
            params,
            meta,
            cfg,
            metrics: EngineMetrics::new(),
        });
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let worker_shared = Arc::clone(&shared);
            // lint: allow(spawn_outside_parallel) — long-lived named worker threads driving a condvar queue, not the fork-join kernel util::parallel models
            match std::thread::Builder::new()
                .name(format!("lf-serve-{wid}"))
                .spawn(move || worker_loop(wid, worker_shared))
            {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // release any already-spawned workers before bailing
                    shared.shutdown.store(true, Ordering::Release);
                    shared.notify.notify_all();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::Serve(format!("cannot spawn worker: {e}")));
                }
            }
        }
        Ok(Engine { shared, workers: handles })
    }

    /// Classify a batch of nodes. Blocks until every answer arrives;
    /// results come back in input order. Unknown node ids — and rows
    /// whose shard is quarantined — fail the whole call (partial answers
    /// would silently skew downstream aggregation). Callers that want
    /// per-row degradation use [`Engine::query_status`] instead.
    pub fn query(&self, nodes: &[NodeId]) -> Result<Vec<Prediction>> {
        self.run(nodes)?
            .into_iter()
            .map(|row| row.map_err(Error::Serve))
            .collect()
    }

    /// Classify a batch of nodes, degrading per row instead of per call:
    /// rows served from healthy shards come back
    /// [`NodeStatus::Ready`]; rows whose shard is quarantined (or whose
    /// node id is unknown) come back [`NodeStatus::Unavailable`] with the
    /// reason. Engine-level failures — shutdown, a poisoned worker pool —
    /// still fail the call, since no row can be answered.
    pub fn query_status(&self, nodes: &[NodeId]) -> Result<Vec<NodeStatus>> {
        Ok(self
            .run(nodes)?
            .into_iter()
            .zip(nodes)
            .map(|(row, &node)| match row {
                Ok(p) => NodeStatus::Ready(p),
                Err(reason) => NodeStatus::Unavailable { node, reason },
            })
            .collect())
    }

    /// Shared query path: cache/single-flight triage, enqueue, wait.
    /// Returns one slot per input row — `Err` carries that row's failure
    /// message. The outer `Result` is reserved for engine-level failures
    /// (shutdown, poisoned pool, lock poison) where no row was answered.
    fn run(
        &self,
        nodes: &[NodeId],
    ) -> Result<Vec<std::result::Result<Prediction, String>>> {
        if nodes.is_empty() {
            return Ok(Vec::new());
        }
        let _sp = obs::span("serve", "query").with("n", num(nodes.len() as f64));
        self.shared.metrics.requests.add(nodes.len() as u64);
        let mut out: Vec<Option<std::result::Result<Prediction, String>>> =
            vec![None; nodes.len()];

        // ---- cache / single-flight triage on the client thread ----------
        // Hits fill `out` directly; joins and leader slots both wait on a
        // flight. Only leaders enqueue work. A repeated id within one call
        // joins its own leader's flight — one forward either way.
        let mut waits: Vec<(usize, Arc<Flight<Prediction>>)> = Vec::new();
        let mut compute: Vec<Request> = Vec::new();
        let mut hits = 0u64;
        let mut joins = 0u64;
        for (i, &v) in nodes.iter().enumerate() {
            match self.shared.cache.lookup(&v) {
                Lookup::Hit(p) => {
                    hits += 1;
                    out[i] = Some(Ok(p));
                }
                Lookup::Wait(f) => {
                    joins += 1;
                    waits.push((i, f));
                }
                Lookup::Compute(f) => {
                    compute.push(Request::new(v, Arc::clone(&f)));
                    waits.push((i, f));
                }
            }
        }
        self.shared.metrics.cache_hits.add(hits);
        self.shared.metrics.coalesced.add(joins);

        if !compute.is_empty() {
            let enqueue_err = {
                match self.shared.state.lock() {
                    Ok(mut st) => {
                        if let Some(msg) = &st.poisoned {
                            Some(format!("engine poisoned: {msg}"))
                        } else if self.shared.shutdown.load(Ordering::Acquire)
                            || st.live_workers == 0
                        {
                            Some("engine is shut down".to_string())
                        } else {
                            let wake_all = compute.len() >= self.max_batch();
                            for r in compute.drain(..) {
                                st.q.push_back(r);
                            }
                            drop(st);
                            // one batch's worth of work needs one worker;
                            // spilling past the batch cap wakes them all
                            if wake_all {
                                self.shared.notify.notify_all();
                            } else {
                                self.shared.notify.notify_one();
                            }
                            None
                        }
                    }
                    Err(_) => Some("queue lock poisoned".to_string()),
                }
            };
            if let Some(msg) = enqueue_err {
                // retire the flights we created so concurrent joiners (and
                // our own waits) see the failure instead of hanging
                for r in compute {
                    r.finish(&self.shared.cache, Err(msg.clone()));
                }
                return Err(Error::Serve(msg));
            }
        }

        for (i, f) in waits {
            out[i] = Some(f.wait());
        }
        Ok(out
            .into_iter()
            .map(|p| p.unwrap_or_else(|| Err("query slot left unanswered".into())))
            .collect())
    }

    /// Convenience single-node query.
    pub fn query_one(&self, node: NodeId) -> Result<Prediction> {
        self.query(&[node])?
            .pop()
            .ok_or_else(|| Error::Serve("single-node query returned no answer".into()))
    }

    pub fn stats(&self) -> EngineStats {
        let m = &self.shared.metrics;
        EngineStats {
            requests: m.requests.get(),
            cache_hits: m.cache_hits.get(),
            coalesced: m.coalesced.get(),
            batches: m.batches.get(),
            computed: m.computed.get(),
            gather_secs: m.gather.sum(),
            forward_secs: m.forward.sum(),
            publish_secs: m.publish.sum(),
        }
    }

    pub fn store(&self) -> &ShardedEmbeddingStore {
        &self.shared.store
    }

    /// Effective max batch (config clamped to the artifact bucket).
    pub fn max_batch(&self) -> usize {
        self.shared.cfg.batch_size.clamp(1, self.shared.meta.dims.n)
    }

    /// Cache stripes actually in use (after auto-sizing and rounding).
    pub fn cache_stripes(&self) -> usize {
        self.shared.cache.num_stripes()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.notify.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Retires its worker on drop — including an unwind out of the batch
/// loop — so a panicking worker still decrements `live_workers` and the
/// last one to die fails queued requests instead of stranding clients.
struct RetireGuard {
    shared: Arc<Shared>,
    poison: Option<String>,
}

impl Drop for RetireGuard {
    fn drop(&mut self) {
        retire_worker(&self.shared, self.poison.take());
    }
}

/// Mark this worker dead; if it is the last one, fail queued requests so
/// no client blocks forever. `poison` carries an init-failure message.
fn retire_worker(shared: &Shared, poison: Option<String>) {
    let (orphans, reason): (Vec<Request>, String) = {
        let mut st = match shared.state.lock() {
            Ok(st) => st,
            Err(_) => return,
        };
        st.live_workers -= 1;
        if let Some(msg) = poison {
            if st.poisoned.is_none() {
                st.poisoned = Some(msg);
            }
        }
        if st.live_workers == 0 || st.poisoned.is_some() {
            let reason = st
                .poisoned
                .clone()
                .unwrap_or_else(|| "engine shut down".to_string());
            (st.q.drain(..).collect(), reason)
        } else {
            (Vec::new(), String::new())
        }
    };
    for r in orphans {
        r.finish(&shared.cache, Err(reason.clone()));
    }
    shared.notify.notify_all();
}

fn worker_loop(wid: usize, shared: Arc<Shared>) {
    // All exit paths — normal shutdown, init failure, and panics in the
    // batch loop — retire the worker through this guard.
    let mut guard = RetireGuard { shared: Arc::clone(&shared), poison: None };
    // Thread-local PJRT runtime + compiled classifier, as in the trainer.
    let init = Runtime::new(&shared.cfg.artifacts_dir)
        .and_then(|rt| rt.load(&shared.meta.name).map(|exe| (rt, exe)));
    let (_rt, exe) = match init {
        Ok(pair) => pair,
        Err(e) => {
            log::error!("serve worker {wid}: init failed: {e}");
            guard.poison = Some(e.to_string());
            return;
        }
    };
    let dims = exe.meta.dims.clone();
    let batch_cap = shared.cfg.batch_size.clamp(1, dims.n);
    log::debug!(
        "serve worker {wid} up: artifact {} (bucket n={}, f={}, c={})",
        exe.meta.name,
        dims.n,
        dims.f,
        dims.c
    );
    // Reusable PJRT input list: the param clones are refcount bumps on the
    // shared Arc-backed tensors, and the final slot is the bucket-sized
    // `x` buffer rewritten in place per batch (uniquely owned, so
    // `make_mut_f32` never copies) — the hot path allocates nothing.
    let mut inputs: Vec<Tensor> = shared.params.iter().cloned().collect();
    inputs.push(Tensor::f32(vec![0f32; dims.n * dims.f]));
    let mut prev_rows = 0usize;

    loop {
        // Steal a whole batch under one short lock: wait for work, drain
        // up to batch_cap requests, release. Clients never hold this lock
        // while waiting for answers (they block on per-node flights).
        let batch: Vec<Request> = {
            let mut st = match shared.state.lock() {
                Ok(st) => st,
                Err(_) => return, // guard retires
            };
            loop {
                if !st.q.is_empty() {
                    break;
                }
                if shared.shutdown.load(Ordering::Acquire) || st.poisoned.is_some() {
                    return; // guard retires after `st` unlocks
                }
                st = match shared.notify.wait(st) {
                    Ok(st) => st,
                    Err(_) => return,
                };
            }
            let take = st.q.len().min(batch_cap);
            st.q.drain(..take).collect()
        };
        process_batch(&shared, &exe, &dims, &mut inputs, &mut prev_rows, batch);
    }
}

/// Completes every still-pending request with an error if the worker
/// unwinds mid-batch (e.g. a PJRT panic), so joined clients never hang on
/// a flight whose leader died.
struct PendingBatch<'a> {
    shared: &'a Shared,
    reqs: VecDeque<Request>,
}

impl Drop for PendingBatch<'_> {
    fn drop(&mut self) {
        for r in self.reqs.drain(..) {
            r.finish(
                &self.shared.cache,
                Err("serve worker panicked mid-batch".to_string()),
            );
        }
    }
}

/// Run one batch through the classifier. `inputs` is the worker's reusable
/// PJRT input list (params + trailing `x` buffer); `prev_rows` tracks how
/// many `x` rows the previous batch wrote so only the stale tail is
/// re-zeroed (the MLP is row-wise, but deterministic buffers keep unused
/// logit rows reproducible).
fn process_batch(
    shared: &Shared,
    exe: &crate::runtime::Executable,
    dims: &crate::runtime::Dims,
    inputs: &mut [Tensor],
    prev_rows: &mut usize,
    batch: Vec<Request>,
) {
    shared.metrics.batches.inc();
    let f = dims.f;
    let c = dims.c;
    let mut sp = obs::span("serve", "batch");
    sp.attr("rows", num(batch.len() as f64));
    let mut pending = PendingBatch { shared, reqs: batch.into() };

    // Gather embedding rows into the reusable x buffer: lookup is a dense
    // load, the slab is lock-free after first touch, and rows are copied
    // straight into the bucket-padded tensor — nothing per-row is
    // allocated. Requests whose node is unknown (or whose shard fails to
    // load) are answered individually with an error.
    let t_gather = Stopwatch::start();
    {
        // a worker must never panic (it would poison the shared queue
        // mutex): a missing or non-f32 x buffer error-completes the whole
        // batch instead
        let x = match inputs.last_mut().map(Tensor::make_mut_f32) {
            Some(Ok(x)) => x,
            _ => {
                let msg = "worker x buffer missing or not f32".to_string();
                for r in pending.reqs.drain(..) {
                    r.finish(&shared.cache, Err(msg.clone()));
                }
                return;
            }
        };
        // rotate through the guard's deque (pop front, keep live at the
        // back — O(1) each way) so an unwind mid-loop still
        // error-completes everything not yet processed
        let total = pending.reqs.len();
        let mut live = 0usize;
        for _ in 0..total {
            let Some(r) = pending.reqs.pop_front() else { break };
            match shared.store.copy_embedding(r.node, &mut x[live * f..(live + 1) * f]) {
                Ok(()) => {
                    pending.reqs.push_back(r);
                    live += 1;
                }
                Err(e) => {
                    let msg = e.to_string();
                    r.finish(&shared.cache, Err(msg));
                }
            }
        }
        if pending.reqs.len() < *prev_rows {
            x[pending.reqs.len() * f..*prev_rows * f].fill(0.0);
        }
    }
    shared.metrics.gather.record(t_gather.secs());
    *prev_rows = pending.reqs.len();
    if pending.reqs.is_empty() {
        return;
    }

    // One MLP forward for the whole batch.
    let t_forward = Stopwatch::start();
    let logits = match exe.run(inputs).and_then(|out| {
        out.into_iter()
            .next()
            .ok_or_else(|| Error::Serve("pred artifact returned no outputs".into()))?
            .as_f32()
            .map(<[f32]>::to_vec)
    }) {
        Ok(l) => l,
        Err(e) => {
            let msg = e.to_string();
            for r in pending.reqs.drain(..) {
                r.finish(&shared.cache, Err(msg.clone()));
            }
            return;
        }
    };
    shared.metrics.forward.record(t_forward.secs());

    // Publish: cache insert + flight completion per row. Each completion
    // wakes only that node's waiters (per-flight condvar).
    let t_publish = Stopwatch::start();
    let mut row = 0usize;
    while let Some(r) = pending.reqs.pop_front() {
        let slice = &logits[row * c..(row + 1) * c];
        row += 1;
        let (class, score) = slice
            .iter()
            .enumerate()
            .fold((0, f32::NEG_INFINITY), |(bi, bs), (i, &v)| {
                if v > bs { (i, v) } else { (bi, bs) }
            });
        let p = Prediction { node: r.node, class, score, logits: slice.to_vec() };
        shared.metrics.computed.inc();
        r.finish(&shared.cache, Ok(p));
    }
    shared.metrics.publish.record(t_publish.secs());
}
