//! Result caching for the serving engine: a fixed-capacity LRU plus the
//! striped, single-flight front the engine actually queries through.
//!
//! Two layers:
//!
//! * [`LruCache`] — arena-backed doubly-linked list + `HashMap` index;
//!   `get`/`put` are O(1) with no allocation after the arena fills. Not
//!   thread-safe by itself.
//! * [`ResultCache`] — N independent stripes (hash of the key picks one),
//!   each a mutex over an [`LruCache`] **and** an in-flight table. One
//!   stripe lock covers "check cache + join computation" atomically, so
//!   concurrent misses for the same key coalesce into a single
//!   computation (**single-flight**) instead of stampeding the backend,
//!   and unrelated keys never contend on one global mutex.
//!
//! The single-flight protocol: [`ResultCache::lookup`] returns
//! [`Lookup::Hit`] (cached value), [`Lookup::Wait`] (someone is already
//! computing this key — block on the returned [`Flight`]), or
//! [`Lookup::Compute`] (the caller became the key's *leader*: it must
//! arrange for [`ResultCache::complete`] to be called exactly once, which
//! publishes the value, wakes only that flight's waiters — never every
//! client — and retires the flight). Errors are delivered to waiters but
//! **not** cached: the next lookup after a failure recomputes.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Condvar, Mutex};

const NIL: usize = usize::MAX;

/// Hard ceiling on any single [`LruCache`]'s capacity. `new` clamps its
/// argument to this, so the arena reservation made up front is always the
/// real capacity — a `cap` in the billions cannot promise a small arena
/// and then grow it entry by entry (the pre-fix behavior: the clamp was
/// applied to `with_capacity` only, silently breaking the "no allocation
/// after the arena fills" contract above 2^20 entries).
pub const MAX_LRU_CAPACITY: usize = 1 << 20;

struct Entry<K, V> {
    key: K,
    value: V,
    prev: usize,
    next: usize,
}

/// Least-recently-used map with a hard capacity. `cap == 0` disables
/// caching (every `get` misses, every `put` is dropped). Capacities above
/// [`MAX_LRU_CAPACITY`] are clamped — check [`Self::capacity`] for the
/// effective value.
pub struct LruCache<K: Eq + Hash + Clone, V> {
    cap: usize,
    map: HashMap<K, usize>,
    arena: Vec<Entry<K, V>>,
    head: usize,
    tail: usize,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    pub fn new(cap: usize) -> Self {
        let cap = cap.min(MAX_LRU_CAPACITY);
        LruCache {
            cap,
            map: HashMap::with_capacity(cap),
            arena: Vec::with_capacity(cap),
            head: NIL,
            tail: NIL,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Effective capacity (after the [`MAX_LRU_CAPACITY`] clamp): the
    /// arena never outgrows this many entries.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Unlink `idx` from the recency list (does not free it).
    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.arena[idx].prev, self.arena[idx].next);
        if prev != NIL {
            self.arena[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.arena[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    /// Link `idx` at the head (most recently used).
    fn link_front(&mut self, idx: usize) {
        self.arena[idx].prev = NIL;
        self.arena[idx].next = self.head;
        if self.head != NIL {
            self.arena[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Look up `key`, marking it most recently used on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let idx = *self.map.get(key)?;
        if idx != self.head {
            self.unlink(idx);
            self.link_front(idx);
        }
        Some(&self.arena[idx].value)
    }

    /// Insert or refresh `key`, evicting the LRU entry at capacity.
    pub fn put(&mut self, key: K, value: V) {
        if self.cap == 0 {
            return;
        }
        if let Some(&idx) = self.map.get(&key) {
            self.arena[idx].value = value;
            if idx != self.head {
                self.unlink(idx);
                self.link_front(idx);
            }
            return;
        }
        let idx = if self.map.len() >= self.cap {
            // reuse the LRU slot (there is no remove(), so the arena never
            // has holes — eviction always recycles the tail in place)
            let victim = self.tail;
            self.unlink(victim);
            let old_key = self.arena[victim].key.clone();
            self.map.remove(&old_key);
            self.arena[victim].key = key.clone();
            self.arena[victim].value = value;
            victim
        } else {
            self.arena.push(Entry { key: key.clone(), value, prev: NIL, next: NIL });
            self.arena.len() - 1
        };
        self.map.insert(key, idx);
        self.link_front(idx);
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.arena.clear();
        self.head = NIL;
        self.tail = NIL;
    }
}

// ---- single-flight ---------------------------------------------------------

/// A computation in flight for one key. Waiters block on [`Flight::wait`];
/// the completer publishes exactly once via [`Flight::complete`], which
/// wakes **only this flight's** waiters (per-flight condvar — completing
/// one key never causes a system-wide `notify_all`).
pub struct Flight<V> {
    slot: Mutex<Option<Result<V, String>>>,
    cv: Condvar,
}

impl<V: Clone> Flight<V> {
    pub fn new() -> Self {
        Flight { slot: Mutex::new(None), cv: Condvar::new() }
    }

    /// Publish the result and wake this flight's waiters. Idempotent-ish:
    /// a second call overwrites the slot and re-notifies, which is
    /// harmless (waiters take whichever result is present when they wake).
    pub fn complete(&self, result: Result<V, String>) {
        if let Ok(mut slot) = self.slot.lock() {
            *slot = Some(result);
        }
        self.cv.notify_all();
    }

    /// Block until the result is published.
    pub fn wait(&self) -> Result<V, String> {
        let mut slot = self
            .slot
            .lock()
            .map_err(|_| "flight lock poisoned".to_string())?;
        loop {
            if let Some(r) = slot.as_ref() {
                return r.clone();
            }
            slot = self
                .cv
                .wait(slot)
                .map_err(|_| "flight lock poisoned".to_string())?;
        }
    }

    /// Non-blocking peek (tests and diagnostics).
    pub fn try_result(&self) -> Option<Result<V, String>> {
        self.slot.lock().ok().and_then(|s| s.clone())
    }
}

impl<V: Clone> Default for Flight<V> {
    fn default() -> Self {
        Self::new()
    }
}

/// Outcome of [`ResultCache::lookup`].
pub enum Lookup<V> {
    /// Cached value, returned immediately.
    Hit(V),
    /// Another caller is computing this key; wait on the flight.
    Wait(Arc<Flight<V>>),
    /// The caller became this key's leader: compute, then call
    /// [`ResultCache::complete`] with this flight.
    Compute(Arc<Flight<V>>),
}

struct Stripe<K: Eq + Hash + Clone, V: Clone> {
    lru: LruCache<K, V>,
    inflight: HashMap<K, Arc<Flight<V>>>,
}

/// Striped LRU + single-flight table. See the module docs for the
/// protocol. All methods take `&self`; one stripe mutex per
/// `hash(key) & mask`, so disjoint keys proceed in parallel.
pub struct ResultCache<K: Eq + Hash + Clone, V: Clone> {
    stripes: Vec<Mutex<Stripe<K, V>>>,
    mask: u64,
}

impl<K: Eq + Hash + Clone, V: Clone> ResultCache<K, V> {
    /// `capacity` is the total LRU budget, split evenly (rounded up)
    /// across `stripes` (clamped to `[1, 4096]`, rounded up to a power of
    /// two). `capacity == 0` disables caching but keeps single-flight
    /// coalescing active.
    pub fn new(capacity: usize, stripes: usize) -> Self {
        let stripes = stripes.clamp(1, 1 << 12).next_power_of_two();
        let per_stripe = if capacity == 0 { 0 } else { capacity.div_ceil(stripes) };
        ResultCache {
            stripes: (0..stripes)
                .map(|_| {
                    Mutex::new(Stripe {
                        lru: LruCache::new(per_stripe),
                        inflight: HashMap::new(),
                    })
                })
                .collect(),
            mask: stripes as u64 - 1,
        }
    }

    pub fn num_stripes(&self) -> usize {
        self.stripes.len()
    }

    /// Total effective capacity (per-stripe capacity × stripes; the
    /// even split rounds up, so this can slightly exceed the requested
    /// total — never undershoot it).
    pub fn capacity(&self) -> usize {
        self.stripes.len()
            * self.stripes[0]
                .lock()
                .map(|s| s.lru.capacity())
                .unwrap_or(0)
    }

    /// Stripe index for a key (exposed so tests can model per-stripe
    /// eviction exactly).
    pub fn stripe_of(&self, key: &K) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() & self.mask) as usize
    }

    /// Cache check + single-flight join in one stripe critical section.
    pub fn lookup(&self, key: &K) -> Lookup<V> {
        match self.stripes[self.stripe_of(key)].lock() {
            Ok(mut stripe) => {
                if let Some(v) = stripe.lru.get(key) {
                    return Lookup::Hit(v.clone());
                }
                // An in-flight entry that already carries an error was
                // abandoned (its leader completed the flight directly,
                // e.g. from a drop guard, without retiring the entry) —
                // self-heal by electing a fresh leader instead of handing
                // out a permanently-failed flight.
                let stale = match stripe.inflight.get(key) {
                    Some(f) if matches!(f.try_result(), Some(Err(_))) => true,
                    Some(f) => return Lookup::Wait(Arc::clone(f)),
                    None => false,
                };
                if stale {
                    stripe.inflight.remove(key);
                }
                let f = Arc::new(Flight::new());
                stripe.inflight.insert(key.clone(), Arc::clone(&f));
                Lookup::Compute(f)
            }
            // a poisoned stripe degrades to cache-off: every caller
            // computes privately (stampede, but correct and un-stuck)
            Err(_) => Lookup::Compute(Arc::new(Flight::new())),
        }
    }

    /// Publish a leader's result: insert into the LRU (successes only —
    /// errors are never cached), retire the in-flight entry, and wake the
    /// flight's waiters. `flight` is the handle `lookup` handed the
    /// leader; it is always completed, even if the stripe lock is
    /// poisoned, so waiters cannot hang.
    pub fn complete(&self, key: &K, flight: &Arc<Flight<V>>, result: Result<V, String>) {
        let registered = match self.stripes[self.stripe_of(key)].lock() {
            Ok(mut stripe) => {
                let f = stripe.inflight.remove(key);
                if let Ok(v) = &result {
                    stripe.lru.put(key.clone(), v.clone());
                }
                f
            }
            Err(_) => None,
        };
        // normally the registered flight IS the leader's; the clone for a
        // separately-registered one (a degraded-mode caller raced in
        // between) happens only in that rare branch, keeping the per-row
        // publish path allocation-free
        if let Some(f) = registered {
            if !Arc::ptr_eq(&f, flight) {
                f.complete(result.clone());
            }
        }
        flight.complete(result);
    }

    /// Cached entries across all stripes (poisoned stripes count 0).
    pub fn len(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.lock().map(|s| s.lru.len()).unwrap_or(0))
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Keys currently being computed (diagnostics/tests).
    pub fn inflight_len(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.lock().map(|s| s.inflight.len()).unwrap_or(0))
            .sum()
    }

    /// Drop all cached entries (in-flight computations are untouched).
    pub fn clear(&self) {
        for s in &self.stripes {
            if let Ok(mut s) = s.lock() {
                s.lru.clear();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    #[test]
    fn hits_and_misses() {
        let mut c = LruCache::new(2);
        assert!(c.get(&1).is_none());
        c.put(1, "a");
        c.put(2, "b");
        assert_eq!(c.get(&1), Some(&"a"));
        assert_eq!(c.get(&2), Some(&"b"));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        c.put(1, "a");
        c.put(2, "b");
        c.get(&1); // 2 is now LRU
        c.put(3, "c");
        assert!(c.get(&2).is_none(), "LRU entry should be evicted");
        assert_eq!(c.get(&1), Some(&"a"));
        assert_eq!(c.get(&3), Some(&"c"));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn put_refreshes_existing_key() {
        let mut c = LruCache::new(2);
        c.put(1, "a");
        c.put(2, "b");
        c.put(1, "a2"); // refresh: 2 becomes LRU
        c.put(3, "c");
        assert_eq!(c.get(&1), Some(&"a2"));
        assert!(c.get(&2).is_none());
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = LruCache::new(0);
        c.put(1, "a");
        assert!(c.get(&1).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn oversized_capacity_clamps_to_the_reservation() {
        // the former bug: cap above the arena reservation ceiling was
        // kept verbatim, so the "no allocation after the arena fills"
        // promise silently broke. Now cap itself clamps and capacity()
        // reports the effective value.
        let c: LruCache<u32, u32> = LruCache::new(MAX_LRU_CAPACITY + 123);
        assert_eq!(c.capacity(), MAX_LRU_CAPACITY);
        let c: LruCache<u32, u32> = LruCache::new(64);
        assert_eq!(c.capacity(), 64);
    }

    #[test]
    fn heavy_churn_keeps_invariants() {
        let mut c = LruCache::new(8);
        for i in 0..1000u32 {
            c.put(i % 13, i);
            assert!(c.len() <= 8);
        }
        // the 8 most recently inserted distinct keys survive
        let mut present = 0;
        for k in 0..13u32 {
            if c.get(&k).is_some() {
                present += 1;
            }
        }
        assert_eq!(present, 8);
    }

    #[test]
    fn clear_resets() {
        let mut c = LruCache::new(4);
        c.put(1, 1);
        c.clear();
        assert!(c.is_empty());
        c.put(2, 2);
        assert_eq!(c.get(&2), Some(&2));
    }

    // ---- striped single-flight front ----------------------------------

    #[test]
    fn lookup_compute_complete_roundtrip() {
        let cache: ResultCache<u32, String> = ResultCache::new(16, 4);
        let leader = match cache.lookup(&7) {
            Lookup::Compute(f) => f,
            _ => panic!("first lookup must elect a leader"),
        };
        assert_eq!(cache.inflight_len(), 1);
        // a second caller joins the in-flight computation
        let joined = match cache.lookup(&7) {
            Lookup::Wait(f) => f,
            _ => panic!("second lookup must join, not recompute"),
        };
        assert!(Arc::ptr_eq(&leader, &joined));
        cache.complete(&7, &leader, Ok("v7".into()));
        assert_eq!(joined.wait().unwrap(), "v7");
        assert_eq!(cache.inflight_len(), 0);
        match cache.lookup(&7) {
            Lookup::Hit(v) => assert_eq!(v, "v7"),
            _ => panic!("completed key must be a cache hit"),
        }
    }

    #[test]
    fn errors_propagate_but_are_not_cached() {
        let cache: ResultCache<u32, String> = ResultCache::new(16, 2);
        let f = match cache.lookup(&1) {
            Lookup::Compute(f) => f,
            _ => panic!(),
        };
        cache.complete(&1, &f, Err("backend down".into()));
        assert_eq!(f.wait().unwrap_err(), "backend down");
        assert_eq!(cache.len(), 0, "errors must not be cached");
        assert!(
            matches!(cache.lookup(&1), Lookup::Compute(_)),
            "after an error the next lookup recomputes"
        );
    }

    #[test]
    fn zero_capacity_keeps_single_flight() {
        let cache: ResultCache<u32, u32> = ResultCache::new(0, 4);
        let f = match cache.lookup(&3) {
            Lookup::Compute(f) => f,
            _ => panic!(),
        };
        assert!(matches!(cache.lookup(&3), Lookup::Wait(_)));
        cache.complete(&3, &f, Ok(30));
        assert_eq!(f.wait().unwrap(), 30);
        // nothing cached, so the next lookup computes again
        assert!(matches!(cache.lookup(&3), Lookup::Compute(_)));
    }

    #[test]
    fn abandoned_errored_flight_self_heals() {
        let cache: ResultCache<u32, u32> = ResultCache::new(8, 2);
        let f = match cache.lookup(&5) {
            Lookup::Compute(f) => f,
            _ => panic!(),
        };
        // leader dies without going through complete(): the flight gets
        // an error but the in-flight entry is left behind
        f.complete(Err("leader dropped".into()));
        assert_eq!(cache.inflight_len(), 1, "entry is stale, not retired");
        // the next lookup must not hand out the dead flight forever
        let f2 = match cache.lookup(&5) {
            Lookup::Compute(f2) => f2,
            _ => panic!("stale errored flight must be replaced, not joined"),
        };
        assert!(!Arc::ptr_eq(&f, &f2));
        cache.complete(&5, &f2, Ok(50));
        assert!(matches!(cache.lookup(&5), Lookup::Hit(50)));
    }

    #[test]
    fn stripes_round_up_to_power_of_two() {
        let c: ResultCache<u32, u32> = ResultCache::new(100, 3);
        assert_eq!(c.num_stripes(), 4);
        assert!(c.capacity() >= 100);
        let c: ResultCache<u32, u32> = ResultCache::new(100, 0);
        assert_eq!(c.num_stripes(), 1);
    }

    #[test]
    fn concurrent_misses_coalesce_to_one_leader() {
        let cache: Arc<ResultCache<u32, u64>> = Arc::new(ResultCache::new(64, 8));
        let leaders = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let cache = Arc::clone(&cache);
            let leaders = Arc::clone(&leaders);
            handles.push(std::thread::spawn(move || match cache.lookup(&42) {
                Lookup::Hit(v) => v,
                Lookup::Wait(f) => f.wait().unwrap(),
                Lookup::Compute(f) => {
                    leaders.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    // simulate a slow backend so others pile in
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    cache.complete(&42, &f, Ok(4242));
                    f.wait().unwrap()
                }
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 4242);
        }
        assert_eq!(
            leaders.load(std::sync::atomic::Ordering::SeqCst),
            1,
            "exactly one thread may compute a hot key"
        );
    }

    /// A naive recency-list LRU used as the reference model: correctness
    /// is obvious by inspection (Vec scan, most recent at the back).
    struct ModelLru<K: PartialEq + Clone, V: Clone> {
        cap: usize,
        items: Vec<(K, V)>,
    }

    impl<K: PartialEq + Clone, V: Clone> ModelLru<K, V> {
        fn new(cap: usize) -> Self {
            ModelLru { cap, items: Vec::new() }
        }

        fn get(&mut self, key: &K) -> Option<V> {
            let pos = self.items.iter().position(|(k, _)| k == key)?;
            let kv = self.items.remove(pos);
            let v = kv.1.clone();
            self.items.push(kv);
            Some(v)
        }

        fn put(&mut self, key: K, value: V) {
            if self.cap == 0 {
                return;
            }
            if let Some(pos) = self.items.iter().position(|(k, _)| k == &key) {
                self.items.remove(pos);
            } else if self.items.len() >= self.cap {
                self.items.remove(0);
            }
            self.items.push((key, value));
        }
    }

    /// Property: the arena LRU behaves exactly like the naive model under
    /// random op sequences, across small capacities.
    #[test]
    fn prop_lru_matches_model() {
        prop::check(
            "lru-vs-model",
            40,
            0x11BC,
            |rng: &mut Rng| {
                let cap = rng.index(6); // includes 0 (disabled)
                let ops: Vec<(bool, u32, u32)> = (0..120)
                    .map(|i| (rng.f64() < 0.5, rng.index(10) as u32, i))
                    .collect();
                (cap, ops)
            },
            |(cap, ops)| {
                let mut real = LruCache::new(*cap);
                let mut model = ModelLru::new(*cap);
                for &(is_put, key, val) in ops {
                    if is_put {
                        real.put(key, val);
                        model.put(key, val);
                    } else {
                        let a = real.get(&key).copied();
                        let b = model.get(&key);
                        if a != b {
                            return Err(format!("get({key}): {a:?} != model {b:?}"));
                        }
                    }
                    if real.len() != model.items.len() {
                        return Err(format!(
                            "len {} != model {}",
                            real.len(),
                            model.items.len()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Property: the striped cache behaves exactly like one independent
    /// model LRU **per stripe** (striping changes eviction locality by
    /// design, so the model maps keys through the same stripe function).
    #[test]
    fn prop_striped_matches_per_stripe_models() {
        prop::check(
            "striped-vs-models",
            30,
            0x57A1,
            |rng: &mut Rng| {
                let stripes = 1 << rng.index(4); // 1, 2, 4, 8
                let capacity = 1 + rng.index(12);
                let ops: Vec<(bool, u32, u32)> = (0..150)
                    .map(|i| (rng.f64() < 0.5, rng.index(24) as u32, i))
                    .collect();
                (stripes, capacity, ops)
            },
            |(stripes, capacity, ops)| {
                let cache: ResultCache<u32, u32> = ResultCache::new(*capacity, *stripes);
                let per_stripe = capacity.div_ceil(cache.num_stripes());
                let mut models: Vec<ModelLru<u32, u32>> = (0..cache.num_stripes())
                    .map(|_| ModelLru::new(per_stripe))
                    .collect();
                for &(is_put, key, val) in ops {
                    let s = cache.stripe_of(&key);
                    if is_put {
                        // drive the put through the single-flight path the
                        // engine uses: leader computes, complete() caches
                        match cache.lookup(&key) {
                            Lookup::Hit(_) => {
                                // hit refreshes recency in both
                                models[s].get(&key);
                            }
                            Lookup::Compute(f) => {
                                cache.complete(&key, &f, Ok(val));
                                models[s].put(key, val);
                            }
                            Lookup::Wait(_) => {
                                return Err(format!(
                                    "key {key} stuck in flight in a single-threaded run"
                                ))
                            }
                        }
                    } else {
                        let got = match cache.lookup(&key) {
                            Lookup::Hit(v) => Some(v),
                            Lookup::Compute(f) => {
                                // a miss elected us leader; abandon by
                                // completing with an error (not cached)
                                cache.complete(&key, &f, Err("probe".into()));
                                None
                            }
                            Lookup::Wait(_) => {
                                return Err(format!("key {key} unexpectedly in flight"))
                            }
                        };
                        let want = models[s].get(&key);
                        if got != want {
                            return Err(format!("get({key}): {got:?} != model {want:?}"));
                        }
                    }
                }
                if cache.len() != models.iter().map(|m| m.items.len()).sum::<usize>() {
                    return Err("total len diverged".into());
                }
                Ok(())
            },
        );
    }
}
