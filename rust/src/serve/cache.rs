//! Fixed-capacity LRU cache for query results.
//!
//! Arena-backed doubly-linked list + `HashMap` index: `get`/`put` are O(1)
//! with no allocation after the arena fills. The serving engine shares one
//! cache behind a mutex; entries are whole predictions, so a hit skips the
//! PJRT forward entirely.

use std::collections::HashMap;
use std::hash::Hash;

const NIL: usize = usize::MAX;

struct Entry<K, V> {
    key: K,
    value: V,
    prev: usize,
    next: usize,
}

/// Least-recently-used map with a hard capacity. `cap == 0` disables
/// caching (every `get` misses, every `put` is dropped).
pub struct LruCache<K: Eq + Hash + Clone, V> {
    cap: usize,
    map: HashMap<K, usize>,
    arena: Vec<Entry<K, V>>,
    head: usize,
    tail: usize,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    pub fn new(cap: usize) -> Self {
        LruCache {
            cap,
            map: HashMap::with_capacity(cap.min(1 << 20)),
            arena: Vec::with_capacity(cap.min(1 << 20)),
            head: NIL,
            tail: NIL,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Unlink `idx` from the recency list (does not free it).
    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.arena[idx].prev, self.arena[idx].next);
        if prev != NIL {
            self.arena[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.arena[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    /// Link `idx` at the head (most recently used).
    fn link_front(&mut self, idx: usize) {
        self.arena[idx].prev = NIL;
        self.arena[idx].next = self.head;
        if self.head != NIL {
            self.arena[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Look up `key`, marking it most recently used on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let idx = *self.map.get(key)?;
        if idx != self.head {
            self.unlink(idx);
            self.link_front(idx);
        }
        Some(&self.arena[idx].value)
    }

    /// Insert or refresh `key`, evicting the LRU entry at capacity.
    pub fn put(&mut self, key: K, value: V) {
        if self.cap == 0 {
            return;
        }
        if let Some(&idx) = self.map.get(&key) {
            self.arena[idx].value = value;
            if idx != self.head {
                self.unlink(idx);
                self.link_front(idx);
            }
            return;
        }
        let idx = if self.map.len() >= self.cap {
            // reuse the LRU slot (there is no remove(), so the arena never
            // has holes — eviction always recycles the tail in place)
            let victim = self.tail;
            self.unlink(victim);
            let old_key = self.arena[victim].key.clone();
            self.map.remove(&old_key);
            self.arena[victim].key = key.clone();
            self.arena[victim].value = value;
            victim
        } else {
            self.arena.push(Entry { key: key.clone(), value, prev: NIL, next: NIL });
            self.arena.len() - 1
        };
        self.map.insert(key, idx);
        self.link_front(idx);
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.arena.clear();
        self.head = NIL;
        self.tail = NIL;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_and_misses() {
        let mut c = LruCache::new(2);
        assert!(c.get(&1).is_none());
        c.put(1, "a");
        c.put(2, "b");
        assert_eq!(c.get(&1), Some(&"a"));
        assert_eq!(c.get(&2), Some(&"b"));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        c.put(1, "a");
        c.put(2, "b");
        c.get(&1); // 2 is now LRU
        c.put(3, "c");
        assert!(c.get(&2).is_none(), "LRU entry should be evicted");
        assert_eq!(c.get(&1), Some(&"a"));
        assert_eq!(c.get(&3), Some(&"c"));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn put_refreshes_existing_key() {
        let mut c = LruCache::new(2);
        c.put(1, "a");
        c.put(2, "b");
        c.put(1, "a2"); // refresh: 2 becomes LRU
        c.put(3, "c");
        assert_eq!(c.get(&1), Some(&"a2"));
        assert!(c.get(&2).is_none());
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = LruCache::new(0);
        c.put(1, "a");
        assert!(c.get(&1).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn heavy_churn_keeps_invariants() {
        let mut c = LruCache::new(8);
        for i in 0..1000u32 {
            c.put(i % 13, i);
            assert!(c.len() <= 8);
        }
        // the 8 most recently inserted distinct keys survive
        let mut present = 0;
        for k in 0..13u32 {
            if c.get(&k).is_some() {
                present += 1;
            }
        }
        assert_eq!(present, 8);
    }

    #[test]
    fn clear_resets() {
        let mut c = LruCache::new(4);
        c.put(1, 1);
        c.clear();
        assert!(c.is_empty());
        c.put(2, 2);
        assert_eq!(c.get(&2), Some(&2));
    }
}
