//! Sharded embedding store: the serving-time view of the global embedding
//! matrix.
//!
//! Opens a shard directory written by the coordinator, builds the
//! `NodeId → (shard, row)` ownership index from shard *headers* only, and
//! loads each shard's embedding rows lazily on first touch. Shards are
//! disjoint by construction (one per Leiden-Fusion partition), so the
//! ownership index is an exact cover and lookups never fan out across
//! shards — the serving analogue of the paper's communication-free
//! training.
//!
//! The store is `Send + Sync`: lazy shard data sits behind per-shard
//! mutexes holding `Arc<[f32]>` blocks, so engine workers share one store.

use super::shard::{read_shard, read_shard_header, ShardHeader, ShardManifest};
use crate::error::{Error, Result};
use crate::graph::NodeId;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

struct LazyShard {
    path: PathBuf,
    header: ShardHeader,
    /// Embedding rows, populated on first access.
    data: Mutex<Option<Arc<Vec<f32>>>>,
}

/// Lazily-loaded, shard-per-partition embedding store.
pub struct ShardedEmbeddingStore {
    dir: PathBuf,
    manifest: ShardManifest,
    shards: Vec<LazyShard>,
    /// node → (shard index, row within shard)
    ownership: HashMap<NodeId, (u32, u32)>,
}

impl ShardedEmbeddingStore {
    /// Open a shard directory: parse `shards.json`, read every shard
    /// header (cheap — ids only, with a length-based truncation check),
    /// and build the ownership index. Embedding rows stay on disk.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = ShardManifest::load(dir)?;
        let mut shards = Vec::with_capacity(manifest.shards.len());
        let mut ownership = HashMap::with_capacity(manifest.num_nodes);
        for (idx, entry) in manifest.shards.iter().enumerate() {
            let path = dir.join(&entry.file);
            let header = read_shard_header(&path)?;
            if header.part_id != entry.part_id {
                return Err(Error::Serve(format!(
                    "{}: shard claims partition {}, manifest says {}",
                    path.display(),
                    header.part_id,
                    entry.part_id
                )));
            }
            if header.rows != entry.rows {
                return Err(Error::Serve(format!(
                    "{}: shard has {} rows, manifest says {}",
                    path.display(),
                    header.rows,
                    entry.rows
                )));
            }
            if header.dim != manifest.dim {
                return Err(Error::Serve(format!(
                    "{}: shard dim {} != manifest dim {}",
                    path.display(),
                    header.dim,
                    manifest.dim
                )));
            }
            for (row, &v) in header.nodes.iter().enumerate() {
                if ownership.insert(v, (idx as u32, row as u32)).is_some() {
                    return Err(Error::Serve(format!(
                        "node {v} owned by two shards (partitions must be disjoint)"
                    )));
                }
            }
            shards.push(LazyShard { path, header, data: Mutex::new(None) });
        }
        if ownership.len() != manifest.num_nodes {
            return Err(Error::Serve(format!(
                "shards cover {} nodes, manifest says {}",
                ownership.len(),
                manifest.num_nodes
            )));
        }
        Ok(ShardedEmbeddingStore { dir: dir.to_path_buf(), manifest, shards, ownership })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &ShardManifest {
        &self.manifest
    }

    pub fn dim(&self) -> usize {
        self.manifest.dim
    }

    /// Total nodes across all shards.
    pub fn num_nodes(&self) -> usize {
        self.ownership.len()
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shards whose embedding rows are currently resident.
    pub fn loaded_shards(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.data.lock().map(|d| d.is_some()).unwrap_or(false))
            .count()
    }

    /// Resolve a node to `(shard index, row)` without touching data.
    pub fn locate(&self, v: NodeId) -> Option<(u32, u32)> {
        self.ownership.get(&v).copied()
    }

    /// All node ids this store serves, in an arbitrary order.
    pub fn node_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.ownership.keys().copied()
    }

    /// Load (or fetch cached) shard data block.
    fn shard_data(&self, idx: usize) -> Result<Arc<Vec<f32>>> {
        let shard = &self.shards[idx];
        let mut slot = shard.data.lock().map_err(|_| {
            Error::Serve("shard data lock poisoned".into())
        })?;
        if let Some(data) = slot.as_ref() {
            return Ok(Arc::clone(data));
        }
        let (header, data) = read_shard(&shard.path)?;
        // open() validated the header; re-check rows defensively in case
        // the file changed underneath a running server
        if header.rows != shard.header.rows || header.dim != shard.header.dim {
            return Err(Error::Serve(format!(
                "{}: shard changed on disk while serving",
                shard.path.display()
            )));
        }
        let data = Arc::new(data);
        *slot = Some(Arc::clone(&data));
        log::debug!(
            "loaded shard {} ({} rows × {})",
            shard.path.display(),
            header.rows,
            header.dim
        );
        Ok(data)
    }

    /// Copy one node's embedding row into `out` (len == dim).
    pub fn copy_embedding(&self, v: NodeId, out: &mut [f32]) -> Result<()> {
        if out.len() != self.manifest.dim {
            return Err(Error::Serve(format!(
                "output buffer {} != dim {}",
                out.len(),
                self.manifest.dim
            )));
        }
        let (shard_idx, row) = self
            .locate(v)
            .ok_or_else(|| Error::Serve(format!("node {v} not in any shard")))?;
        let data = self.shard_data(shard_idx as usize)?;
        let dim = self.manifest.dim;
        let off = row as usize * dim;
        out.copy_from_slice(&data[off..off + dim]);
        Ok(())
    }

    /// One node's embedding row as an owned vector.
    pub fn embedding(&self, v: NodeId) -> Result<Vec<f32>> {
        let mut out = vec![0.0; self.manifest.dim];
        self.copy_embedding(v, &mut out)?;
        Ok(out)
    }

    /// Force-load every shard (used by benches to exclude cold I/O).
    pub fn prefetch_all(&self) -> Result<()> {
        for i in 0..self.shards.len() {
            self.shard_data(i)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::shard::{
        shard_file_name, write_shard, ShardEntry, CLASSIFIER_FILE, SHARD_MANIFEST_FILE,
    };

    fn bundle(name: &str, shards: &[(u32, Vec<NodeId>, usize)]) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("lf_store_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut entries = Vec::new();
        let mut total = 0;
        let dim = shards.first().map(|(_, _, d)| *d).unwrap_or(1);
        for (part, nodes, dim) in shards {
            // row value = node id so tests can verify which row came back
            let emb: Vec<f32> = nodes
                .iter()
                .flat_map(|&v| (0..*dim).map(move |j| v as f32 * 10.0 + j as f32))
                .collect();
            write_shard(&dir.join(shard_file_name(*part)), *part, nodes, &emb, *dim)
                .unwrap();
            entries.push(ShardEntry {
                file: shard_file_name(*part),
                part_id: *part,
                rows: nodes.len(),
            });
            total += nodes.len();
        }
        ShardManifest {
            version: 1,
            dataset: "test".into(),
            task: "multiclass".into(),
            num_nodes: total,
            dim,
            classes: 2,
            classifier_file: CLASSIFIER_FILE.into(),
            shards: entries,
        }
        .save(&dir)
        .unwrap();
        dir
    }

    #[test]
    fn opens_and_resolves_lazily() {
        let dir = bundle("lazy", &[(0, vec![0, 2, 4], 3), (1, vec![1, 3], 3)]);
        let store = ShardedEmbeddingStore::open(&dir).unwrap();
        assert_eq!(store.num_nodes(), 5);
        assert_eq!(store.num_shards(), 2);
        assert_eq!(store.loaded_shards(), 0, "open must not load embedding rows");

        assert_eq!(store.embedding(4).unwrap(), vec![40.0, 41.0, 42.0]);
        assert_eq!(store.loaded_shards(), 1, "only the touched shard loads");
        assert_eq!(store.embedding(3).unwrap(), vec![30.0, 31.0, 32.0]);
        assert_eq!(store.loaded_shards(), 2);

        assert_eq!(store.locate(0), Some((0, 0)));
        assert_eq!(store.locate(3), Some((1, 1)));
        assert!(store.locate(99).is_none());
        assert!(store.embedding(99).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_overlapping_shards() {
        let dir = bundle("overlap", &[(0, vec![0, 1], 2), (1, vec![1, 2], 2)]);
        let err = ShardedEmbeddingStore::open(&dir).unwrap_err();
        assert!(err.to_string().contains("two shards"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_row_count_mismatch_with_manifest() {
        let dir = bundle("rows", &[(0, vec![0, 1, 2], 2)]);
        // rewrite the shard with fewer rows than the manifest claims
        write_shard(&dir.join(shard_file_name(0)), 0, &[0, 1], &[0.0; 4], 2).unwrap();
        assert!(ShardedEmbeddingStore::open(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_truncated_shard_at_open() {
        let dir = bundle("trunc", &[(0, vec![0, 1, 2], 4)]);
        let path = dir.join(shard_file_name(0));
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        assert!(ShardedEmbeddingStore::open(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_manifest_is_an_error() {
        let dir = bundle("nomanifest", &[(0, vec![0], 1)]);
        std::fs::remove_file(dir.join(SHARD_MANIFEST_FILE)).unwrap();
        assert!(ShardedEmbeddingStore::open(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn concurrent_reads_share_one_load() {
        let dir = bundle("concurrent", &[(0, (0..64).collect(), 8)]);
        let store = std::sync::Arc::new(ShardedEmbeddingStore::open(&dir).unwrap());
        let mut handles = Vec::new();
        for t in 0..4 {
            let store = std::sync::Arc::clone(&store);
            handles.push(std::thread::spawn(move || {
                for v in 0..64u32 {
                    let e = store.embedding(v).unwrap();
                    assert_eq!(e[0], v as f32 * 10.0, "thread {t} node {v}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(store.loaded_shards(), 1);
        std::fs::remove_dir_all(dir).ok();
    }
}
