//! Sharded embedding store: the serving-time view of the global embedding
//! matrix.
//!
//! Opens a shard directory written by the coordinator, builds an
//! [`OwnershipIndex`] (`NodeId → (shard, row)`) from shard *headers* only,
//! and loads each shard's embedding rows on first touch. Shards are
//! disjoint by construction (one per Leiden-Fusion partition), so the
//! index is an exact cover and lookups never fan out across shards — the
//! serving analogue of the paper's communication-free training.
//!
//! Hot-path contract (what the engine's gather loop relies on):
//!
//! * **ownership lookup** is a direct-indexed load (dense id spaces) or a
//!   binary search (sparse) — no hashing, no allocation;
//! * **slab access** is an immutable `Arc<[f32]>` behind a [`OnceLock`]:
//!   after first touch it is one atomic load — no `Mutex`, no `Arc` clone,
//!   no copy. Two threads racing the *first* touch may both read the file;
//!   exactly one result is kept (the loser's read is dropped), which
//!   trades a rare duplicate cold read for a lock-free steady state.
//! * [`ShardedEmbeddingStore::warm`] preloads every slab (in parallel via
//!   `util/parallel`) so serving starts with the cold I/O already paid.

use super::index::OwnershipIndex;
use super::shard::{decode_shard_bytes, read_shard_header, ShardManifest};
use crate::error::{Error, Result};
use crate::fault;
use crate::graph::NodeId;
use crate::obs;
use crate::util::parallel::map_chunks;
use crate::util::sha256;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

struct Shard {
    path: PathBuf,
    rows: usize,
    /// Embedding rows, populated on first access and immutable after.
    slab: OnceLock<Arc<[f32]>>,
    /// Set when the shard is corrupt/truncated/missing — at open time
    /// (header rejected) or at first slab load (data checksum). A
    /// quarantined shard's nodes answer `Unavailable`, the rest of the
    /// bundle keeps serving, and no disk retry is attempted.
    quarantined: AtomicBool,
}

impl Shard {
    fn quarantine(&self, why: &str) {
        if !self.quarantined.swap(true, Ordering::Relaxed) {
            obs::registry().counter("serve.shards_quarantined").inc();
            log::warn!("shard {} quarantined: {why}", self.path.display());
        }
    }
}

/// Lazily-loaded, shard-per-partition embedding store.
pub struct ShardedEmbeddingStore {
    dir: PathBuf,
    manifest: ShardManifest,
    shards: Vec<Shard>,
    index: OwnershipIndex,
}

impl ShardedEmbeddingStore {
    /// Open a shard directory: parse `shards.json`, read every shard
    /// header (cheap — ids only, with length + checksum truncation/
    /// corruption checks), and build the ownership index. Embedding rows
    /// stay on disk.
    ///
    /// Graceful degradation: a shard whose header is corrupt, truncated,
    /// missing, or inconsistent with the manifest is **quarantined**, not
    /// fatal — its nodes simply aren't in the index (the engine answers
    /// `Unavailable` for them) while every healthy shard keeps serving.
    /// Only bundle-level problems (unreadable manifest, overlapping
    /// healthy shards) abort the open.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = ShardManifest::load(dir)?;
        let mut shards = Vec::with_capacity(manifest.shards.len());
        let mut headers = Vec::with_capacity(manifest.shards.len());
        for entry in &manifest.shards {
            let path = dir.join(&entry.file);
            let verdict = read_shard_header(&path).and_then(|header| {
                if header.part_id != entry.part_id {
                    Err(Error::Serve(format!(
                        "shard claims partition {}, manifest says {}",
                        header.part_id, entry.part_id
                    )))
                } else if header.rows != entry.rows {
                    Err(Error::Serve(format!(
                        "shard has {} rows, manifest says {}",
                        header.rows, entry.rows
                    )))
                } else if header.dim != manifest.dim {
                    Err(Error::Serve(format!(
                        "shard dim {} != manifest dim {}",
                        header.dim, manifest.dim
                    )))
                } else {
                    Ok(header)
                }
            });
            let shard = Shard {
                path,
                rows: entry.rows,
                slab: OnceLock::new(),
                quarantined: AtomicBool::new(false),
            };
            match verdict {
                Ok(header) => headers.push(header.nodes),
                Err(e) => {
                    shard.quarantine(&e.to_string());
                    // keep shard positions aligned with the manifest:
                    // an empty view owns no nodes
                    headers.push(Vec::new());
                }
            }
            shards.push(shard);
        }
        let quarantined = shards
            .iter()
            .filter(|s| s.quarantined.load(Ordering::Relaxed))
            .count();
        let views: Vec<&[NodeId]> = headers.iter().map(|n| n.as_slice()).collect();
        let index = OwnershipIndex::build(&views)?;
        // with quarantined shards the cover is intentionally partial;
        // the exact-cover check only applies to a fully healthy bundle
        if quarantined == 0 && index.len() != manifest.num_nodes {
            return Err(Error::Serve(format!(
                "shards cover {} nodes, manifest says {}",
                index.len(),
                manifest.num_nodes
            )));
        }
        Ok(ShardedEmbeddingStore { dir: dir.to_path_buf(), manifest, shards, index })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &ShardManifest {
        &self.manifest
    }

    pub fn dim(&self) -> usize {
        self.manifest.dim
    }

    /// Total nodes across all shards.
    pub fn num_nodes(&self) -> usize {
        self.index.len()
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The ownership index (dense direct-indexed or sorted sparse).
    pub fn index(&self) -> &OwnershipIndex {
        &self.index
    }

    /// Shards whose embedding rows are currently resident.
    pub fn loaded_shards(&self) -> usize {
        self.shards.iter().filter(|s| s.slab.get().is_some()).count()
    }

    /// Shards quarantined so far (corrupt/truncated/missing at open or
    /// at first data load).
    pub fn quarantined_shards(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.quarantined.load(Ordering::Relaxed))
            .count()
    }

    /// Whether a shard (by position in the manifest) is quarantined.
    pub fn is_quarantined(&self, idx: usize) -> bool {
        self.shards
            .get(idx)
            .is_some_and(|s| s.quarantined.load(Ordering::Relaxed))
    }

    /// Resolve a node to `(shard index, row)` without touching data.
    #[inline]
    pub fn locate(&self, v: NodeId) -> Option<(u32, u32)> {
        self.index.locate(v)
    }

    /// All node ids this store serves, in an arbitrary order.
    pub fn node_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.index.node_ids()
    }

    /// One shard's embedding slab, loading it on first touch. Steady
    /// state is a single atomic load — no lock, no refcount traffic.
    fn slab(&self, idx: usize) -> Result<&[f32]> {
        let shard = &self.shards[idx];
        if let Some(slab) = shard.slab.get() {
            return Ok(slab);
        }
        if shard.quarantined.load(Ordering::Relaxed) {
            return Err(Error::Serve(format!(
                "{}: shard quarantined",
                shard.path.display()
            )));
        }
        let entry = &self.manifest.shards[idx];
        let loaded = (|| {
            // the decode below goes through the in-memory path, so the
            // read-side injection point fires here (as `read_shard` did)
            if let Some(inj) = fault::point("shard.read").part(entry.part_id).fire() {
                if !inj.is_corrupt() {
                    return Err(inj.error());
                }
                return Err(Error::Serve(format!(
                    "{}: shard corrupt or truncated (injected read corruption)",
                    shard.path.display()
                )));
            }
            let bytes = std::fs::read(&shard.path)?;
            // content-address check before decoding: a manifest with a
            // recorded digest names exactly one byte sequence, so a shard
            // file overwritten by a different run (same shape, different
            // embeddings — invisible to the header re-check below) is
            // caught here instead of silently mixing bundle versions.
            // Pre-versioned manifests (empty digest) fall back to the
            // LFS1 checksums alone.
            if !entry.sha256.is_empty() {
                let got = sha256::digest_hex(&bytes);
                if got != entry.sha256 {
                    return Err(Error::Serve(format!(
                        "content digest mismatch (manifest {}, file {got}) — \
                         shard does not belong to this bundle version",
                        entry.sha256
                    )));
                }
            }
            decode_shard_bytes(&bytes)
        })();
        let (header, data) = match loaded {
            Ok(ok) => ok,
            Err(e) => {
                // data-section corruption first seen here (open only
                // verified the header): quarantine, no disk retry
                shard.quarantine(&e.to_string());
                return Err(Error::Serve(format!(
                    "{}: shard quarantined: {e}",
                    shard.path.display()
                )));
            }
        };
        // open() validated the header; re-check defensively in case the
        // file changed underneath a running server
        if header.rows != shard.rows || header.dim != self.manifest.dim {
            shard.quarantine("shard changed on disk while serving");
            return Err(Error::Serve(format!(
                "{}: shard changed on disk while serving",
                shard.path.display()
            )));
        }
        log::debug!(
            "loaded shard {} ({} rows × {})",
            shard.path.display(),
            header.rows,
            header.dim
        );
        // On a first-touch race both threads read the file; set() keeps
        // exactly one slab and the loser's copy is dropped here.
        let _ = shard.slab.set(Arc::from(data));
        shard
            .slab
            .get()
            .ok_or_else(|| Error::Serve("slab vanished after first-touch set".into()))
    }

    /// Copy one node's embedding row into `out` (len == dim). After the
    /// owning slab's first touch this is lookup + `copy_from_slice` —
    /// no allocation, no lock.
    pub fn copy_embedding(&self, v: NodeId, out: &mut [f32]) -> Result<()> {
        if out.len() != self.manifest.dim {
            return Err(Error::Serve(format!(
                "output buffer {} != dim {}",
                out.len(),
                self.manifest.dim
            )));
        }
        let (shard_idx, row) = self
            .locate(v)
            .ok_or_else(|| Error::Serve(format!("node {v} not in any shard")))?;
        let slab = self.slab(shard_idx as usize)?;
        let dim = self.manifest.dim;
        let off = row as usize * dim;
        out.copy_from_slice(&slab[off..off + dim]);
        Ok(())
    }

    /// One node's embedding row as an owned vector (convenience; the hot
    /// path uses [`Self::copy_embedding`]).
    pub fn embedding(&self, v: NodeId) -> Result<Vec<f32>> {
        let mut out = vec![0.0; self.manifest.dim];
        self.copy_embedding(v, &mut out)?;
        Ok(out)
    }

    /// Eagerly load every healthy shard slab, `threads`-wide
    /// (1 = sequential). Serving after `warm` never touches disk or any
    /// lock. A shard that fails to load is quarantined (and counted in
    /// `serve.shards_quarantined`), not fatal — warming a degraded
    /// bundle warms what survives.
    pub fn warm(&self, threads: usize) -> Result<()> {
        map_chunks(threads, self.shards.len(), 1, |_, range| {
            for i in range {
                if self.is_quarantined(i) {
                    continue;
                }
                // a load failure quarantines the shard inside slab();
                // the rest of the bundle still warms
                let _ = self.slab(i);
            }
            Ok(())
        })
        .into_iter()
        .collect()
    }

    /// Force-load every shard sequentially (legacy name; prefer
    /// [`Self::warm`]).
    pub fn prefetch_all(&self) -> Result<()> {
        self.warm(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::shard::{
        shard_file_name, write_shard, ShardEntry, CLASSIFIER_FILE, SHARD_MANIFEST_FILE,
    };

    fn bundle(name: &str, shards: &[(u32, Vec<NodeId>, usize)]) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("lf_store_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut entries = Vec::new();
        let mut total = 0;
        let dim = shards.first().map(|(_, _, d)| *d).unwrap_or(1);
        for (part, nodes, dim) in shards {
            // row value = node id so tests can verify which row came back
            let emb: Vec<f32> = nodes
                .iter()
                .flat_map(|&v| (0..*dim).map(move |j| v as f32 * 10.0 + j as f32))
                .collect();
            let path = dir.join(shard_file_name(*part));
            write_shard(&path, *part, nodes, &emb, *dim).unwrap();
            entries.push(ShardEntry {
                file: shard_file_name(*part),
                part_id: *part,
                rows: nodes.len(),
                // record real content addresses so store tests exercise
                // the digest check on every lazy load
                sha256: crate::util::sha256::digest_hex(&std::fs::read(&path).unwrap()),
            });
            total += nodes.len();
        }
        ShardManifest {
            version: 1,
            dataset: "test".into(),
            task: "multiclass".into(),
            num_nodes: total,
            dim,
            classes: 2,
            classifier_file: CLASSIFIER_FILE.into(),
            classifier_sha256: String::new(),
            shards: entries,
        }
        .save(&dir)
        .unwrap();
        dir
    }

    /// A shard overwritten by a *different* run with the same shape passes
    /// every header check but must fail the content-address check and be
    /// quarantined — the guard that lets a live manifest survive a
    /// concurrent retrain into the same directory.
    #[test]
    fn digest_mismatch_quarantines_on_load() {
        let dir = bundle("digest", &[(0, vec![0, 1, 2], 2)]);
        // same part_id, same rows, same dim — only the embedding values
        // differ, exactly what a retrain with a different seed produces
        let emb: Vec<f32> = vec![9.0; 6];
        write_shard(&dir.join(shard_file_name(0)), 0, &[0, 1, 2], &emb, 2).unwrap();
        let store = ShardedEmbeddingStore::open(&dir).unwrap();
        assert_eq!(store.quarantined_shards(), 0, "headers still look fine");
        let err = store.embedding(0).unwrap_err();
        assert!(err.to_string().contains("content digest mismatch"), "{err}");
        assert_eq!(store.quarantined_shards(), 1);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn opens_and_resolves_lazily() {
        let dir = bundle("lazy", &[(0, vec![0, 2, 4], 3), (1, vec![1, 3], 3)]);
        let store = ShardedEmbeddingStore::open(&dir).unwrap();
        assert_eq!(store.num_nodes(), 5);
        assert_eq!(store.num_shards(), 2);
        assert_eq!(store.loaded_shards(), 0, "open must not load embedding rows");
        assert!(store.index().is_dense(), "compact ids take the dense layout");

        assert_eq!(store.embedding(4).unwrap(), vec![40.0, 41.0, 42.0]);
        assert_eq!(store.loaded_shards(), 1, "only the touched shard loads");
        assert_eq!(store.embedding(3).unwrap(), vec![30.0, 31.0, 32.0]);
        assert_eq!(store.loaded_shards(), 2);

        assert_eq!(store.locate(0), Some((0, 0)));
        assert_eq!(store.locate(3), Some((1, 1)));
        assert!(store.locate(99).is_none());
        assert!(store.embedding(99).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sparse_id_space_is_served_via_binary_search() {
        let dir = bundle(
            "sparse",
            &[(0, vec![1_000, 500_000], 2), (1, vec![2_000_000], 2)],
        );
        let store = ShardedEmbeddingStore::open(&dir).unwrap();
        assert!(!store.index().is_dense(), "wide id space must not allocate densely");
        assert_eq!(store.embedding(2_000_000).unwrap(), vec![20_000_000.0, 20_000_001.0]);
        assert_eq!(store.locate(1_000), Some((0, 0)));
        assert!(store.locate(0).is_none());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn warm_loads_everything_in_parallel() {
        let dir = bundle(
            "warm",
            &[(0, vec![0, 1], 4), (1, vec![2], 4), (2, vec![3, 4, 5], 4)],
        );
        let store = ShardedEmbeddingStore::open(&dir).unwrap();
        store.warm(4).unwrap();
        assert_eq!(store.loaded_shards(), 3);
        let mut row = [0.0f32; 4];
        store.copy_embedding(5, &mut row).unwrap();
        assert_eq!(row, [50.0, 51.0, 52.0, 53.0]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_overlapping_shards() {
        let dir = bundle("overlap", &[(0, vec![0, 1], 2), (1, vec![1, 2], 2)]);
        let err = ShardedEmbeddingStore::open(&dir).unwrap_err();
        assert!(err.to_string().contains("two shards"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn quarantines_row_count_mismatch_with_manifest() {
        let dir = bundle("rows", &[(0, vec![0, 1, 2], 2)]);
        // rewrite the shard with fewer rows than the manifest claims:
        // inconsistent with the bundle → quarantined, open survives
        write_shard(&dir.join(shard_file_name(0)), 0, &[0, 1], &[0.0; 4], 2).unwrap();
        let store = ShardedEmbeddingStore::open(&dir).unwrap();
        assert_eq!(store.quarantined_shards(), 1);
        assert!(store.embedding(0).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn quarantines_truncated_shard_at_open_and_serves_the_rest() {
        let dir = bundle("trunc", &[(0, vec![0, 1, 2], 4), (1, vec![3, 4], 4)]);
        let path = dir.join(shard_file_name(0));
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        let store = ShardedEmbeddingStore::open(&dir).unwrap();
        assert_eq!(store.quarantined_shards(), 1);
        assert!(store.is_quarantined(0));
        assert!(!store.is_quarantined(1));
        // dead shard's nodes are gone from the index; healthy rows serve
        assert!(store.locate(1).is_none());
        let err = store.embedding(1).unwrap_err();
        assert!(matches!(err, Error::Serve(_)), "{err}");
        assert_eq!(store.embedding(4).unwrap(), vec![40.0, 41.0, 42.0, 43.0]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn quarantines_missing_shard_file() {
        let dir = bundle("missing", &[(0, vec![0], 2), (1, vec![1], 2)]);
        std::fs::remove_file(dir.join(shard_file_name(0))).unwrap();
        let store = ShardedEmbeddingStore::open(&dir).unwrap();
        assert_eq!(store.quarantined_shards(), 1);
        assert_eq!(store.embedding(1).unwrap(), vec![10.0, 11.0]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn quarantines_data_corruption_at_first_load() {
        let dir = bundle("databits", &[(0, vec![0, 1], 2), (1, vec![2], 2)]);
        let path = dir.join(shard_file_name(0));
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one bit inside the data section (after the 20-byte fixed
        // header + 8 node bytes + 8 crc bytes)
        let off = 20 + 8 + 8 + 3;
        bytes[off] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        // header is intact → open succeeds with the shard healthy
        let store = ShardedEmbeddingStore::open(&dir).unwrap();
        assert_eq!(store.quarantined_shards(), 0);
        assert_eq!(store.locate(0), Some((0, 0)));
        // first data touch trips the data checksum → quarantine
        assert!(store.embedding(0).is_err());
        assert_eq!(store.quarantined_shards(), 1);
        // no disk retry: still an error, still exactly one quarantine
        assert!(store.embedding(1).is_err());
        assert_eq!(store.quarantined_shards(), 1);
        // the healthy shard keeps serving
        assert_eq!(store.embedding(2).unwrap(), vec![20.0, 21.0]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn warm_tolerates_quarantined_shards() {
        let dir = bundle("warmq", &[(0, vec![0], 3), (1, vec![1], 3)]);
        let path = dir.join(shard_file_name(1));
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 1]).unwrap();
        let store = ShardedEmbeddingStore::open(&dir).unwrap();
        store.warm(2).unwrap();
        assert_eq!(store.loaded_shards(), 1);
        assert_eq!(store.quarantined_shards(), 1);
        assert_eq!(store.embedding(0).unwrap(), vec![0.0, 1.0, 2.0]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_manifest_is_an_error() {
        let dir = bundle("nomanifest", &[(0, vec![0], 1)]);
        std::fs::remove_file(dir.join(SHARD_MANIFEST_FILE)).unwrap();
        assert!(ShardedEmbeddingStore::open(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn concurrent_reads_agree_and_slab_loads_once_per_shard() {
        let dir = bundle("concurrent", &[(0, (0..64).collect(), 8)]);
        let store = std::sync::Arc::new(ShardedEmbeddingStore::open(&dir).unwrap());
        let mut handles = Vec::new();
        for t in 0..4 {
            let store = std::sync::Arc::clone(&store);
            handles.push(std::thread::spawn(move || {
                for v in 0..64u32 {
                    let e = store.embedding(v).unwrap();
                    assert_eq!(e[0], v as f32 * 10.0, "thread {t} node {v}");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(store.loaded_shards(), 1);
        std::fs::remove_dir_all(dir).ok();
    }
}
