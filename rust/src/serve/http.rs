//! Hand-rolled HTTP/1.1 front-end over the serve engine — the network
//! face of the bundle platform (`repro serve --http <addr>`).
//!
//! Design mirrors the LFN1 transport in `net/`: raw `std::net` sockets
//! (this file and `net/` are the only places the `raw_socket_io` lint
//! rule allows them), a nonblocking accept loop with a bounded poll
//! tick, and a hard rule that **every malformed, truncated, oversized,
//! or slow input becomes a typed [`Error::Serve`] and a well-formed
//! response or close — never a panic, never a hung connection**.
//!
//! Surface:
//!
//! * `GET /healthz` — liveness (the process accepts connections).
//! * `GET /readyz` — readiness: the serving bundle version, node count,
//!   and quarantine state (`ready v=N nodes=M quarantined=Q`).
//! * `GET /metrics` — Prometheus text from the [`obs`] registry.
//! * `GET|POST /classify?nodes=0,5,9[&format=text|json]` — batched node
//!   classification. Node ids also accepted as a comma-separated POST
//!   body. `format=text` emits one [`format_status_line`] per node with
//!   logits as exact f32 bit patterns — byte-comparable against
//!   `repro query --logits-out` (the tier-1 hot-swap drill does exactly
//!   that `cmp`).
//!
//! Overload behaviour is explicit, not emergent: admission to the engine
//! is bounded by `max_inflight` (excess requests get `429` +
//! `Retry-After` immediately), every request carries a deadline
//! (`request_deadline_ms`, exceeded → `503`), and a connection that
//! trickles its request slower than `request_timeout_ms` (slowloris) is
//! answered `408` and closed. Keep-alive and pipelined requests are
//! served in order from the same buffer; cross-connection batching is
//! inherited from the engine's single-flight/batch-steal design — each
//! connection thread is just one more concurrent asker.

use super::engine::NodeStatus;
use crate::error::{Error, Result};
use crate::fault;
use crate::graph::NodeId;
use crate::obs;
use crate::util::json::{num, obj, s, Json};
use crate::util::Stopwatch;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

/// Accept-loop poll tick (nonblocking accept + shutdown check).
const ACCEPT_TICK_MS: u64 = 20;
/// Per-read socket timeout inside a connection (poll tick for the
/// request-completion and keep-alive-idle clocks).
const READ_TICK_MS: u64 = 50;
/// Write timeout: a peer that stops draining its response is dropped.
const WRITE_TIMEOUT_MS: u64 = 5_000;
/// An idle keep-alive connection (no request bytes at all) is closed
/// after this long.
const KEEPALIVE_IDLE_MS: u64 = 10_000;
/// Cap on node ids in one /classify request.
const MAX_NODES_PER_REQUEST: usize = 4096;

/// Parser limits (also the defaults for [`HttpServerConfig`]).
#[derive(Clone, Debug)]
pub struct HttpLimits {
    /// Max bytes of request line + headers (through the blank line).
    pub max_header_bytes: usize,
    /// Max declared `Content-Length`.
    pub max_body_bytes: usize,
}

impl Default for HttpLimits {
    fn default() -> Self {
        HttpLimits { max_header_bytes: 8 * 1024, max_body_bytes: 64 * 1024 }
    }
}

/// One parsed request. Only what the front-end acts on is kept.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpRequest {
    pub method: String,
    /// Raw request target, e.g. `/classify?nodes=0,5`.
    pub target: String,
    /// Connection semantics after this exchange (HTTP/1.1 defaults to
    /// keep-alive, HTTP/1.0 to close, `Connection:` overrides).
    pub keep_alive: bool,
    pub body: Vec<u8>,
}

/// Incremental HTTP/1.1 request parser over a growing byte buffer.
///
/// * `Ok(None)` — the buffer holds a *prefix* of a valid request; read
///   more bytes and call again.
/// * `Ok(Some((req, consumed)))` — one full request; drain `consumed`
///   bytes (pipelined requests may follow).
/// * `Err(Error::Serve)` — the bytes can never become a valid request
///   (malformed, oversized, unsupported); answer 400 and close.
///
/// Never panics on any input: every index is bounds-checked and every
/// arithmetic step is over checked/`usize` values well below overflow.
pub fn parse_request(buf: &[u8], limits: &HttpLimits) -> Result<Option<(HttpRequest, usize)>> {
    // locate the header terminator within the header budget
    let window = buf.len().min(limits.max_header_bytes.saturating_add(4));
    let head_end = find_subslice(&buf[..window], b"\r\n\r\n");
    let Some(head_end) = head_end else {
        if buf.len() > limits.max_header_bytes {
            return Err(Error::Serve(format!(
                "header section exceeds {} bytes without terminating",
                limits.max_header_bytes
            )));
        }
        return Ok(None);
    };
    let head = &buf[..head_end];
    if head.iter().any(|&b| b == 0 || (b < 0x20 && b != b'\r' && b != b'\n' && b != b'\t')) {
        return Err(Error::Serve("control bytes in header section".into()));
    }
    let head = std::str::from_utf8(head)
        .map_err(|_| Error::Serve("header section is not valid UTF-8".into()))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => {
            return Err(Error::Serve(format!(
                "malformed request line {request_line:?}"
            )))
        }
    };
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(Error::Serve(format!("malformed method {method:?}")));
    }
    if !target.starts_with('/') {
        return Err(Error::Serve(format!("request target {target:?} must be absolute")));
    }
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(Error::Serve(format!("unsupported version {version:?}"))),
    };
    let mut content_length = 0usize;
    let mut keep_alive = http11;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            return Err(Error::Serve(format!("malformed header line {line:?}")));
        };
        if name.is_empty() || name.contains(' ') || name.contains('\t') {
            return Err(Error::Serve(format!("malformed header name {name:?}")));
        }
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value
                .parse::<usize>()
                .map_err(|_| Error::Serve(format!("bad content-length {value:?}")))?;
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                keep_alive = true;
            }
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            // chunked (or anything else) is out of scope for this
            // front-end; reject it typed instead of misframing the stream
            return Err(Error::Serve(format!("transfer-encoding {value:?} not supported")));
        }
    }
    if content_length > limits.max_body_bytes {
        return Err(Error::Serve(format!(
            "declared body of {content_length} bytes exceeds limit {}",
            limits.max_body_bytes
        )));
    }
    let body_start = head_end + 4;
    let total = body_start.saturating_add(content_length);
    if buf.len() < total {
        return Ok(None);
    }
    Ok(Some((
        HttpRequest {
            method: method.to_string(),
            target: target.to_string(),
            keep_alive,
            body: buf[body_start..total].to_vec(),
        },
        total,
    )))
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Readiness snapshot of the serving bundle (the `/readyz` payload).
#[derive(Clone, Debug)]
pub struct ReadyInfo {
    pub version: usize,
    pub dataset: String,
    pub nodes: usize,
    pub quarantined: usize,
}

/// What the front-end serves. Implemented by `bundle::BundleHandle`
/// (the real engine behind a hot-swappable generation) and by test
/// stubs, so every protocol/overload behaviour is testable without
/// compiled PJRT artifacts.
pub trait Backend: Send + Sync + 'static {
    fn classify(&self, nodes: &[NodeId]) -> Result<Vec<NodeStatus>>;
    fn ready(&self) -> ReadyInfo;
}

/// One node's answer as a canonical text line. Logits are rendered as
/// exact little-endian f32 bit patterns (8 hex digits), so two paths
/// producing bit-identical logits produce byte-identical lines — the
/// contract behind the serve-vs-offline `cmp` drills.
pub fn format_status_line(status: &NodeStatus) -> String {
    match status {
        NodeStatus::Ready(p) => {
            let logits: Vec<String> =
                p.logits.iter().map(|l| format!("{:08x}", l.to_bits())).collect();
            format!("node={} class={} logits={}", p.node, p.class, logits.join(","))
        }
        NodeStatus::Unavailable { node, reason } => {
            format!("node={node} unavailable={reason}")
        }
    }
}

/// Front-end knobs (CLI `--http`, `[serve]` config keys).
#[derive(Clone, Debug)]
pub struct HttpServerConfig {
    /// Bind address (`127.0.0.1:0` asks the OS for a port — combine with
    /// `port_file`).
    pub addr: String,
    /// Max concurrently admitted `/classify` requests; excess answered
    /// `429` + `Retry-After` (0 = unbounded).
    pub max_inflight: usize,
    /// Per-request deadline in ms; exceeded → `503` (0 disables).
    pub request_deadline_ms: u64,
    /// A request (headers + body) must arrive completely within this
    /// window — the slowloris guard (0 disables).
    pub request_timeout_ms: u64,
    /// Written with the bound port after listen (script discovery).
    pub port_file: Option<PathBuf>,
    pub limits: HttpLimits,
}

impl Default for HttpServerConfig {
    fn default() -> Self {
        HttpServerConfig {
            addr: "127.0.0.1:0".into(),
            max_inflight: 256,
            request_deadline_ms: 2_000,
            request_timeout_ms: 2_000,
            port_file: None,
            limits: HttpLimits::default(),
        }
    }
}

struct Shared {
    cfg: HttpServerConfig,
    backend: Arc<dyn Backend>,
    shutdown: AtomicBool,
    /// `/classify` requests currently admitted to the engine.
    inflight: AtomicUsize,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

impl Shared {
    fn track(&self, handle: JoinHandle<()>) {
        let mut conns = self.conns.lock().unwrap_or_else(PoisonError::into_inner);
        // reap finished connection threads so the vec stays bounded by
        // the number of *live* connections
        conns.retain(|h| !h.is_finished());
        conns.push(handle);
    }

    fn drain(&self) {
        loop {
            let batch: Vec<JoinHandle<()>> = {
                let mut conns =
                    self.conns.lock().unwrap_or_else(PoisonError::into_inner);
                std::mem::take(&mut *conns)
            };
            if batch.is_empty() {
                return;
            }
            for h in batch {
                let _ = h.join();
            }
        }
    }
}

/// The running front-end: an accept thread plus one thread per live
/// connection. Dropping (or [`HttpServer::stop`]) shuts down cleanly.
pub struct HttpServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind, write the port file, and start accepting.
    pub fn start(cfg: HttpServerConfig, backend: Arc<dyn Backend>) -> Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| Error::Serve(format!("cannot bind {}: {e}", cfg.addr)))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Serve(format!("cannot resolve bound address: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Serve(format!("cannot configure listener: {e}")))?;
        if let Some(path) = &cfg.port_file {
            // written after bind so a script polling the file never reads
            // a port nobody listens on
            std::fs::write(path, format!("{}\n", addr.port()))?;
        }
        // touch the serving gauges/counters the scrape contract promises
        // even before the first request or quarantine happens
        let reg = obs::registry();
        reg.counter("serve.shards_quarantined");
        reg.counter("serve.swap_rejected");
        reg.counter("serve.http_requests");
        let shared = Arc::new(Shared {
            cfg,
            backend,
            shutdown: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            conns: Mutex::new(Vec::new()),
        });
        let sh = Arc::clone(&shared);
        // lint: allow(spawn_outside_parallel) — long-lived accept loop for the HTTP front-end, not a fork-join computation
        let accept = std::thread::Builder::new()
            .name("lf-http-accept".into())
            .spawn(move || accept_loop(&sh, listener))?;
        obs::event("serve", "http.listen", vec![("port", num(addr.port() as f64))]);
        log::info!("http front-end listening on {addr}");
        Ok(HttpServer { shared, addr, accept: Some(accept) })
    }

    /// The bound address (port resolved, even when `addr` asked for 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the accept loop exits (i.e. until shutdown — the CLI
    /// serve path parks here and is killed externally).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.shared.drain();
    }

    /// Stop accepting, close out connection threads, and return.
    pub fn stop(mut self) {
        self.shutdown_and_join();
    }

    fn shutdown_and_join(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.shared.drain();
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

fn accept_loop(sh: &Arc<Shared>, listener: TcpListener) {
    loop {
        if sh.shutdown.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                if let Some(inj) = fault::point("http.accept").fire() {
                    // no corruptible payload at accept: fail and corrupt
                    // alike drop the connection — the client's retry
                    // absorbs it
                    log::warn!("http.accept: dropping connection from {peer}: {}", inj.error());
                    drop(stream);
                    continue;
                }
                obs::registry().counter("serve.http_connections").inc();
                let sh2 = Arc::clone(sh);
                // lint: allow(spawn_outside_parallel) — one thread per live HTTP connection with its own lifecycle, not a fork-join computation
                let spawned = std::thread::Builder::new()
                    .name("lf-http-conn".into())
                    .spawn(move || handle_connection(&sh2, stream));
                match spawned {
                    Ok(handle) => sh.track(handle),
                    Err(e) => log::warn!("cannot spawn connection thread: {e}"),
                }
            }
            Err(e) => {
                if e.kind() != ErrorKind::WouldBlock {
                    log::warn!("http accept error: {e}");
                }
                // lint: allow(sleep_outside_backoff) — std has no timed accept; bounded poll tick, not a retry loop
                std::thread::sleep(Duration::from_millis(ACCEPT_TICK_MS));
            }
        }
    }
}

/// Serve one connection: keep-alive loop of parse → respond, with the
/// slowloris and idle clocks. Every exit path is a deliberate close.
fn handle_connection(sh: &Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    if stream
        .set_read_timeout(Some(Duration::from_millis(READ_TICK_MS)))
        .is_err()
        || stream
            .set_write_timeout(Some(Duration::from_millis(WRITE_TIMEOUT_MS)))
            .is_err()
    {
        return;
    }
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    // arms when the first byte of a not-yet-complete request arrives
    let mut request_started: Option<Stopwatch> = None;
    let idle = Stopwatch::start();
    let mut idle_since = 0.0f64;
    loop {
        if sh.shutdown.load(Ordering::Relaxed) {
            return;
        }
        // drain every complete pipelined request already buffered
        loop {
            match parse_request(&buf, &sh.cfg.limits) {
                Ok(Some((req, consumed))) => {
                    buf.drain(..consumed);
                    let started = request_started.take();
                    let keep = respond(sh, &mut stream, &req, started);
                    if !keep || !req.keep_alive {
                        return;
                    }
                    idle_since = idle.secs();
                }
                Ok(None) => break,
                Err(e) => {
                    obs::registry().counter("serve.http_parse_errors").inc();
                    let msg = format!("{e}\n");
                    let _ = write_response(
                        &mut stream,
                        400,
                        "Bad Request",
                        "text/plain",
                        msg.as_bytes(),
                        false,
                        &[],
                    );
                    return;
                }
            }
        }
        // slowloris: a partially-arrived request must complete in time
        if let Some(sw) = &request_started {
            let limit = sh.cfg.request_timeout_ms;
            if limit > 0 && sw.millis() > limit as f64 {
                obs::registry().counter("serve.http_slow_requests").inc();
                let _ = write_response(
                    &mut stream,
                    408,
                    "Request Timeout",
                    "text/plain",
                    b"request did not arrive in time\n",
                    false,
                    &[],
                );
                return;
            }
        } else if (idle.secs() - idle_since) * 1e3 > KEEPALIVE_IDLE_MS as f64 {
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return, // peer closed
            Ok(n) => {
                if request_started.is_none() {
                    request_started = Some(Stopwatch::start());
                }
                buf.extend_from_slice(&chunk[..n]);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// Route and answer one request. Returns whether the connection may be
/// kept alive (a handler-level failure still answers; only write errors
/// force a close).
fn respond(
    sh: &Arc<Shared>,
    stream: &mut TcpStream,
    req: &HttpRequest,
    started: Option<Stopwatch>,
) -> bool {
    let reg = obs::registry();
    reg.counter("serve.http_requests").inc();
    let sw = started.unwrap_or_else(Stopwatch::start);
    let (path, query) = match req.target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.target.as_str(), ""),
    };
    let ok = match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            write_response(stream, 200, "OK", "text/plain", b"ok\n", req.keep_alive, &[])
        }
        ("GET", "/readyz") => {
            let info = sh.backend.ready();
            let body = format!(
                "ready v={} dataset={} nodes={} quarantined={}\n",
                info.version, info.dataset, info.nodes, info.quarantined
            );
            write_response(
                stream,
                200,
                "OK",
                "text/plain",
                body.as_bytes(),
                req.keep_alive,
                &[],
            )
        }
        ("GET", "/metrics") => {
            let body = reg.render_prometheus();
            write_response(
                stream,
                200,
                "OK",
                "text/plain; version=0.0.4",
                body.as_bytes(),
                req.keep_alive,
                &[],
            )
        }
        ("GET" | "POST", "/classify") => classify(sh, stream, req, query, &sw),
        ("GET" | "POST", _) => write_response(
            stream,
            404,
            "Not Found",
            "text/plain",
            b"unknown path\n",
            req.keep_alive,
            &[],
        ),
        _ => write_response(
            stream,
            405,
            "Method Not Allowed",
            "text/plain",
            b"only GET and POST are served\n",
            req.keep_alive,
            &[],
        ),
    };
    reg.histogram("serve.http_request_secs").record(sw.secs());
    ok.is_ok()
}

/// The `/classify` handler: bounded admission, deadline, then the
/// backend (engine) call.
fn classify(
    sh: &Arc<Shared>,
    stream: &mut TcpStream,
    req: &HttpRequest,
    query: &str,
    sw: &Stopwatch,
) -> std::io::Result<()> {
    let reg = obs::registry();
    let deadline = sh.cfg.request_deadline_ms;
    // bounded admission: never queue more engine work than configured —
    // shed load *now* with an honest retry hint instead of building an
    // invisible backlog
    let max = sh.cfg.max_inflight;
    if max > 0 {
        let admitted = sh.inflight.fetch_add(1, Ordering::AcqRel);
        if admitted >= max {
            sh.inflight.fetch_sub(1, Ordering::AcqRel);
            reg.counter("serve.http_throttled").inc();
            return write_response(
                stream,
                429,
                "Too Many Requests",
                "text/plain",
                b"admission queue full, retry later\n",
                req.keep_alive,
                &[("Retry-After", "1")],
            );
        }
    } else {
        sh.inflight.fetch_add(1, Ordering::AcqRel);
    }
    let result = classify_admitted(sh, req, query, sw);
    sh.inflight.fetch_sub(1, Ordering::AcqRel);
    match result {
        Ok(body_and_type) => {
            // the work is done, but a blown deadline is still reported
            // honestly: the caller's SLO was missed
            if deadline > 0 && sw.millis() > deadline as f64 {
                reg.counter("serve.http_deadline_exceeded").inc();
                return write_response(
                    stream,
                    503,
                    "Service Unavailable",
                    "text/plain",
                    b"request deadline exceeded\n",
                    req.keep_alive,
                    &[("Retry-After", "1")],
                );
            }
            let (body, ctype) = body_and_type;
            write_response(stream, 200, "OK", ctype, body.as_bytes(), req.keep_alive, &[])
        }
        Err(ClassifyError::BadRequest(msg)) => {
            let msg = format!("{msg}\n");
            write_response(
                stream,
                400,
                "Bad Request",
                "text/plain",
                msg.as_bytes(),
                req.keep_alive,
                &[],
            )
        }
        Err(ClassifyError::Backend(e)) => {
            reg.counter("serve.http_backend_errors").inc();
            let msg = format!("backend error: {e}\n");
            write_response(
                stream,
                503,
                "Service Unavailable",
                "text/plain",
                msg.as_bytes(),
                req.keep_alive,
                &[("Retry-After", "1")],
            )
        }
    }
}

enum ClassifyError {
    BadRequest(String),
    Backend(Error),
}

fn classify_admitted(
    sh: &Arc<Shared>,
    req: &HttpRequest,
    query: &str,
    _sw: &Stopwatch,
) -> std::result::Result<(String, &'static str), ClassifyError> {
    let mut nodes_param: Option<String> = None;
    let mut text_format = false;
    for pair in query.split('&').filter(|p| !p.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        match k {
            "nodes" => nodes_param = Some(v.to_string()),
            "format" => match v {
                "text" => text_format = true,
                "json" | "" => text_format = false,
                other => {
                    return Err(ClassifyError::BadRequest(format!(
                        "unknown format {other:?} (expected text or json)"
                    )))
                }
            },
            other => {
                return Err(ClassifyError::BadRequest(format!(
                    "unknown query parameter {other:?}"
                )))
            }
        }
    }
    let list = match nodes_param {
        Some(list) => list,
        None => String::from_utf8(req.body.clone())
            .map_err(|_| ClassifyError::BadRequest("body is not valid UTF-8".into()))?,
    };
    let nodes = parse_nodes(&list).map_err(ClassifyError::BadRequest)?;
    let statuses =
        sh.backend.classify(&nodes).map_err(ClassifyError::Backend)?;
    if text_format {
        let mut out = String::new();
        for st in &statuses {
            out.push_str(&format_status_line(st));
            out.push('\n');
        }
        Ok((out, "text/plain"))
    } else {
        let rows: Vec<Json> = statuses
            .iter()
            .map(|st| match st {
                NodeStatus::Ready(p) => obj(vec![
                    ("node", num(p.node as f64)),
                    ("class", num(p.class as f64)),
                    ("score", num(p.score as f64)),
                    (
                        "logits",
                        Json::Arr(p.logits.iter().map(|&l| num(l as f64)).collect()),
                    ),
                ]),
                NodeStatus::Unavailable { node, reason } => {
                    obj(vec![("node", num(*node as f64)), ("unavailable", s(reason))])
                }
            })
            .collect();
        Ok((Json::Arr(rows).to_string(), "application/json"))
    }
}

/// Parse a comma-separated node-id list (`"0,5,9"`).
fn parse_nodes(text: &str) -> std::result::Result<Vec<NodeId>, String> {
    let text = text.trim();
    if text.is_empty() {
        return Err("no node ids given (use ?nodes=0,5,9 or a POST body)".into());
    }
    let mut nodes = Vec::new();
    for tok in text.split(',') {
        let tok = tok.trim();
        let id: NodeId = tok
            .parse()
            .map_err(|_| format!("bad node id {tok:?}"))?;
        nodes.push(id);
        if nodes.len() > MAX_NODES_PER_REQUEST {
            return Err(format!(
                "too many node ids (limit {MAX_NODES_PER_REQUEST} per request)"
            ));
        }
    }
    Ok(nodes)
}

/// Serialize one response. `extra` adds headers (e.g. `Retry-After`).
fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
    extra: &[(&str, &str)],
) -> std::io::Result<()> {
    let reg = obs::registry();
    match status {
        200..=299 => reg.counter("serve.http_responses_2xx").inc(),
        400..=499 => reg.counter("serve.http_responses_4xx").inc(),
        _ => reg.counter("serve.http_responses_5xx").inc(),
    }
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {}\r\n",
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (name, value) in extra {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::Prediction;
    use crate::testing::prop;

    fn limits() -> HttpLimits {
        HttpLimits::default()
    }

    fn parse(bytes: &[u8]) -> Result<Option<(HttpRequest, usize)>> {
        parse_request(bytes, &limits())
    }

    #[test]
    fn parses_simple_get() {
        let raw = b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n";
        let (req, consumed) = parse(raw).unwrap().unwrap();
        assert_eq!(consumed, raw.len());
        assert_eq!(req.method, "GET");
        assert_eq!(req.target, "/healthz");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert!(req.body.is_empty());
    }

    #[test]
    fn connection_header_controls_keep_alive() {
        let raw = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        assert!(!parse(raw).unwrap().unwrap().0.keep_alive);
        let raw = b"GET / HTTP/1.0\r\n\r\n";
        assert!(!parse(raw).unwrap().unwrap().0.keep_alive);
        let raw = b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n";
        assert!(parse(raw).unwrap().unwrap().0.keep_alive);
    }

    #[test]
    fn reads_body_by_content_length() {
        let raw = b"POST /classify HTTP/1.1\r\nContent-Length: 5\r\n\r\n0,5,9";
        let (req, consumed) = parse(raw).unwrap().unwrap();
        assert_eq!(consumed, raw.len());
        assert_eq!(req.body, b"0,5,9");
        // body not yet complete → incomplete, not an error
        assert!(parse(&raw[..raw.len() - 2]).unwrap().is_none());
    }

    #[test]
    fn pipelined_requests_come_back_one_at_a_time() {
        let raw = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let (first, consumed) = parse(raw).unwrap().unwrap();
        assert_eq!(first.target, "/a");
        let (second, rest) = parse(&raw[consumed..]).unwrap().unwrap();
        assert_eq!(second.target, "/b");
        assert_eq!(consumed + rest, raw.len());
    }

    #[test]
    fn rejects_malformed_inputs_typed() {
        for bad in [
            &b"FOO BAR\r\n\r\n"[..],
            b"GET /x HTTP/2.0\r\n\r\n",
            b"GET /x HTTP/1.1 extra\r\n\r\n",
            b"get /x HTTP/1.1\r\n\r\n",
            b"GET x HTTP/1.1\r\n\r\n",
            b"GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n",
            b"GET /x HTTP/1.1\r\nContent-Length: pony\r\n\r\n",
            b"GET /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
            b"GET /x HTTP/1.1\r\nBad name: v\r\n\r\n",
            b"\x00\x01\x02\x03\r\n\r\n",
        ] {
            let err = parse(bad).unwrap_err();
            assert!(matches!(err, Error::Serve(_)), "{bad:?} -> {err}");
        }
    }

    #[test]
    fn oversized_header_and_body_are_rejected() {
        let lim = limits();
        // headers that never terminate within the budget
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend(std::iter::repeat(b'a').take(lim.max_header_bytes + 8));
        let err = parse(&raw).unwrap_err();
        assert!(err.to_string().contains("header section exceeds"), "{err}");
        // an honest but oversized declared body
        let raw = format!(
            "POST /classify HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            lim.max_body_bytes + 1
        );
        let err = parse(raw.as_bytes()).unwrap_err();
        assert!(err.to_string().contains("exceeds limit"), "{err}");
    }

    /// Truncation at *every* prefix of a valid request is either
    /// "incomplete" or a typed error — never a panic, and never a bogus
    /// complete parse.
    #[test]
    fn prop_truncation_at_every_prefix() {
        prop::check(
            "http-truncation",
            25,
            0x4774_0001,
            |rng| random_request(rng),
            |raw| {
                let full = parse_request(raw, &limits())
                    .map_err(|e| format!("full request rejected: {e}"))?
                    .ok_or("full request parsed as incomplete")?;
                if full.1 != raw.len() {
                    return Err(format!("consumed {} of {}", full.1, raw.len()));
                }
                for cut in 0..raw.len() {
                    match parse_request(&raw[..cut], &limits()) {
                        Ok(Some((_, consumed))) if consumed > cut => {
                            return Err(format!("prefix {cut}: consumed past the end"))
                        }
                        // complete parse of a shorter request embedded in
                        // the prefix cannot happen for our generator (one
                        // request, one terminator), but Ok(None)/Err are
                        // both legal rejections of a truncated stream
                        _ => {}
                    }
                }
                Ok(())
            },
        );
    }

    /// Single-bit flips anywhere in the request: the parser must come
    /// back with *some* typed verdict (complete, incomplete, or a typed
    /// error) — never a panic.
    #[test]
    fn prop_single_bit_flips_never_panic() {
        prop::check(
            "http-bit-flips",
            10,
            0x4774_0002,
            |rng| {
                let raw = random_request(rng);
                let bit = rng.index(raw.len() * 8);
                (raw, bit)
            },
            |(raw, bit)| {
                let mut mutated = raw.clone();
                mutated[bit / 8] ^= 1 << (bit % 8);
                match parse_request(&mutated, &limits()) {
                    Ok(Some((_, consumed))) if consumed > mutated.len() => {
                        Err("consumed past the end".into())
                    }
                    _ => Ok(()),
                }
            },
        );
    }

    /// Pipelined garbage after a valid request: the valid one parses,
    /// the garbage yields a typed error or incomplete — never a panic.
    #[test]
    fn prop_pipelined_garbage_is_contained() {
        prop::check(
            "http-pipelined-garbage",
            25,
            0x4774_0003,
            |rng| {
                let mut raw = random_request(rng);
                let tail = raw.len() + rng.index(64);
                while raw.len() < tail {
                    raw.push((rng.index(256)) as u8);
                }
                raw
            },
            |raw| {
                let (_req, consumed) = parse_request(raw, &limits())
                    .map_err(|e| format!("valid head rejected: {e}"))?
                    .ok_or("valid head parsed as incomplete")?;
                match parse_request(&raw[consumed..], &limits()) {
                    Ok(Some((_, c))) if c > raw.len() - consumed => {
                        Err("garbage consumed past the end".into())
                    }
                    _ => Ok(()),
                }
            },
        );
    }

    fn random_request(rng: &mut crate::util::rng::Rng) -> Vec<u8> {
        let methods = ["GET", "POST"];
        let method = methods[rng.index(methods.len())];
        let path = format!("/p{}", rng.index(1000));
        let n_headers = rng.index(4);
        let mut raw = format!("{method} {path} HTTP/1.1\r\n");
        for h in 0..n_headers {
            raw.push_str(&format!("X-H{h}: v{}\r\n", rng.index(100)));
        }
        let body: Vec<u8> = (0..rng.index(32))
            .map(|_| b'a' + (rng.index(26)) as u8)
            .collect();
        if !body.is_empty() || method == "POST" {
            raw.push_str(&format!("Content-Length: {}\r\n", body.len()));
        }
        raw.push_str("\r\n");
        let mut bytes = raw.into_bytes();
        bytes.extend_from_slice(&body);
        bytes
    }

    #[test]
    fn parse_nodes_accepts_lists_and_rejects_junk() {
        assert_eq!(parse_nodes("0,5,9").unwrap(), vec![0, 5, 9]);
        assert_eq!(parse_nodes(" 3 , 4 ").unwrap(), vec![3, 4]);
        assert!(parse_nodes("").is_err());
        assert!(parse_nodes("1,x").is_err());
        assert!(parse_nodes("-1").is_err());
    }

    #[test]
    fn status_lines_are_canonical() {
        let ready = NodeStatus::Ready(Prediction {
            node: 7,
            class: 2,
            score: 1.5,
            logits: vec![1.0, -0.5],
        });
        assert_eq!(
            format_status_line(&ready),
            "node=7 class=2 logits=3f800000,bf000000"
        );
        let gone = NodeStatus::Unavailable { node: 9, reason: "shard quarantined".into() };
        assert_eq!(format_status_line(&gone), "node=9 unavailable=shard quarantined");
    }

    // ---- server-level tests over a loopback socket (stub backend) ------

    struct StubBackend {
        /// Simulated engine latency per request, ms.
        delay_ms: u64,
    }

    impl Backend for StubBackend {
        fn classify(&self, nodes: &[NodeId]) -> Result<Vec<NodeStatus>> {
            if self.delay_ms > 0 {
                std::thread::sleep(Duration::from_millis(self.delay_ms));
            }
            Ok(nodes
                .iter()
                .map(|&n| {
                    NodeStatus::Ready(Prediction {
                        node: n,
                        class: n as usize % 2,
                        score: 1.0,
                        logits: vec![n as f32, -(n as f32)],
                    })
                })
                .collect())
        }

        fn ready(&self) -> ReadyInfo {
            ReadyInfo { version: 3, dataset: "stub".into(), nodes: 42, quarantined: 0 }
        }
    }

    fn start_stub(cfg: HttpServerConfig, delay_ms: u64) -> HttpServer {
        HttpServer::start(cfg, Arc::new(StubBackend { delay_ms })).unwrap()
    }

    /// Minimal test client: one request, returns (status, body).
    fn roundtrip(stream: &mut TcpStream, request: &str) -> (u16, String) {
        stream.write_all(request.as_bytes()).unwrap();
        read_one_response(stream)
    }

    fn read_one_response(stream: &mut TcpStream) -> (u16, String) {
        let mut buf = Vec::new();
        let mut chunk = [0u8; 1024];
        loop {
            if let Some(head_end) = find_subslice(&buf, b"\r\n\r\n") {
                let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
                let status: u16 = head
                    .split(' ')
                    .nth(1)
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(0);
                let clen: usize = head
                    .lines()
                    .find_map(|l| {
                        let (k, v) = l.split_once(':')?;
                        k.eq_ignore_ascii_case("content-length")
                            .then(|| v.trim().parse().ok())?
                    })
                    .unwrap_or(0);
                let body_start = head_end + 4;
                while buf.len() < body_start + clen {
                    let n = stream.read(&mut chunk).unwrap();
                    assert!(n > 0, "peer closed mid-body");
                    buf.extend_from_slice(&chunk[..n]);
                }
                let body =
                    String::from_utf8_lossy(&buf[body_start..body_start + clen]).to_string();
                return (status, body);
            }
            let n = stream.read(&mut chunk).unwrap_or(0);
            if n == 0 {
                return (0, String::new());
            }
            buf.extend_from_slice(&chunk[..n]);
        }
    }

    #[test]
    fn serves_health_ready_and_classify_over_keep_alive() {
        let server = start_stub(HttpServerConfig::default(), 0);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        let (status, body) = roundtrip(&mut c, "GET /healthz HTTP/1.1\r\n\r\n");
        assert_eq!((status, body.as_str()), (200, "ok\n"));
        // same connection keeps serving (keep-alive)
        let (status, body) = roundtrip(&mut c, "GET /readyz HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        assert!(body.contains("v=3"), "{body}");
        let (status, body) =
            roundtrip(&mut c, "GET /classify?nodes=1,2&format=text HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        assert_eq!(body.lines().count(), 2);
        assert!(body.starts_with("node=1 class=1 logits="), "{body}");
        // POST body is an alternative to the query param
        let (status, body) = roundtrip(
            &mut c,
            "POST /classify?format=text HTTP/1.1\r\nContent-Length: 3\r\n\r\n5,6",
        );
        // Content-Length 3 but body "5,6" is 3 bytes
        assert_eq!(status, 200, "{body}");
        server.stop();
    }

    #[test]
    fn malformed_request_gets_400_and_close() {
        let server = start_stub(HttpServerConfig::default(), 0);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        let (status, body) = roundtrip(&mut c, "GET /x HTTP/9.9\r\n\r\n");
        assert_eq!(status, 400);
        assert!(body.contains("unsupported version"), "{body}");
        // server closed the connection after the 400
        let mut probe = [0u8; 1];
        c.set_read_timeout(Some(Duration::from_millis(2000))).unwrap();
        assert_eq!(c.read(&mut probe).unwrap_or(0), 0, "connection must be closed");
        server.stop();
    }

    #[test]
    fn unknown_paths_and_methods_are_typed() {
        let server = start_stub(HttpServerConfig::default(), 0);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        let (status, _) = roundtrip(&mut c, "GET /nope HTTP/1.1\r\n\r\n");
        assert_eq!(status, 404);
        let (status, _) = roundtrip(&mut c, "PUT /classify HTTP/1.1\r\n\r\n");
        assert_eq!(status, 405);
        let (status, body) = roundtrip(&mut c, "GET /classify?nodes=zebra HTTP/1.1\r\n\r\n");
        assert_eq!(status, 400);
        assert!(body.contains("bad node id"), "{body}");
        server.stop();
    }

    #[test]
    fn over_admission_is_throttled_with_retry_after() {
        let cfg = HttpServerConfig { max_inflight: 1, ..HttpServerConfig::default() };
        let server = start_stub(cfg, 300);
        let addr = server.addr();
        // first request occupies the only admission slot for ~300ms
        let busy = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            roundtrip(&mut c, "GET /classify?nodes=1 HTTP/1.1\r\n\r\n")
        });
        std::thread::sleep(Duration::from_millis(100));
        let mut c = TcpStream::connect(addr).unwrap();
        let (status, body) = roundtrip(&mut c, "GET /classify?nodes=2 HTTP/1.1\r\n\r\n");
        assert_eq!(status, 429, "{body}");
        let (status, _) = busy.join().unwrap();
        assert_eq!(status, 200, "admitted request still completes");
        server.stop();
    }

    #[test]
    fn blown_deadline_is_a_503() {
        let cfg = HttpServerConfig { request_deadline_ms: 50, ..HttpServerConfig::default() };
        let server = start_stub(cfg, 200);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        let (status, body) = roundtrip(&mut c, "GET /classify?nodes=1 HTTP/1.1\r\n\r\n");
        assert_eq!(status, 503);
        assert!(body.contains("deadline"), "{body}");
        server.stop();
    }

    #[test]
    fn slowloris_partial_request_gets_408() {
        let cfg = HttpServerConfig { request_timeout_ms: 150, ..HttpServerConfig::default() };
        let server = start_stub(cfg, 0);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        // a request that never finishes arriving
        c.write_all(b"GET /healthz HT").unwrap();
        let (status, _) = read_one_response(&mut c);
        assert_eq!(status, 408);
        server.stop();
    }

    #[test]
    fn metrics_endpoint_exports_the_serving_registry() {
        let server = start_stub(HttpServerConfig::default(), 0);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        let (status, body) = roundtrip(&mut c, "GET /metrics HTTP/1.1\r\n\r\n");
        assert_eq!(status, 200);
        assert!(body.contains("serve_http_requests"), "{body}");
        assert!(body.contains("serve_shards_quarantined"), "{body}");
        server.stop();
    }
}
