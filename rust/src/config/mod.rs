//! Configuration system: a TOML-subset parser + the typed experiment
//! config consumed by the launcher (no `serde`/`toml` offline — see
//! DESIGN.md "Offline-build constraints").
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! (`"..."`), integer, float, and boolean values, `#` comments. This covers
//! every config the launcher reads; nested tables/arrays are rejected with
//! a clear error rather than mis-parsed.

use crate::coordinator::FailurePolicy;
use crate::error::{Error, Result};
use crate::partition::{PartitionSpec, StageSpec};
use crate::train::{ExecPath, Mode, ModelKind};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    fn parse(raw: &str, lineno: usize) -> Result<Value> {
        let raw = raw.trim();
        if let Some(stripped) = raw.strip_prefix('"') {
            let inner = stripped
                .strip_suffix('"')
                .ok_or_else(|| Error::Config(format!("line {lineno}: unterminated string")))?;
            return Ok(Value::Str(inner.to_string()));
        }
        match raw {
            "true" => return Ok(Value::Bool(true)),
            "false" => return Ok(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = raw.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = raw.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        Err(Error::Config(format!("line {lineno}: cannot parse value {raw:?}")))
    }
}

/// Parsed `[section] → key → value` map.
#[derive(Clone, Debug, Default)]
pub struct Toml {
    sections: HashMap<String, HashMap<String, Value>>,
}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml> {
        let mut out = Toml::default();
        let mut section = String::new();
        for (i, line) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = match line.find('#') {
                // only strip comments outside strings (strings here never
                // contain '#' in our configs; reject if ambiguous)
                Some(pos) if !line[..pos].contains('"') => &line[..pos],
                _ => line,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| Error::Config(format!("line {lineno}: bad section")))?;
                if name.contains('[') || name.contains('.') {
                    return Err(Error::Config(format!(
                        "line {lineno}: nested tables are not supported"
                    )));
                }
                section = name.trim().to_string();
                out.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {lineno}: expected key = value"))
            })?;
            if value.trim().starts_with('[') || value.trim().starts_with('{') {
                return Err(Error::Config(format!(
                    "line {lineno}: arrays/inline tables are not supported"
                )));
            }
            out.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), Value::parse(value, lineno)?);
        }
        Ok(out)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        match self.get(section, key) {
            Some(Value::Str(s)) => s.clone(),
            _ => default.to_string(),
        }
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        match self.get(section, key) {
            Some(Value::Int(i)) => *i,
            Some(Value::Float(f)) => *f as i64,
            _ => default,
        }
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        match self.get(section, key) {
            Some(Value::Float(f)) => *f,
            Some(Value::Int(i)) => *i as f64,
            _ => default,
        }
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        match self.get(section, key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }
}

/// Typed experiment configuration (the launcher's input).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// `karate` | `arxiv` | `proteins` | a path to an edge list.
    pub dataset: String,
    /// Node count for synthetic datasets (0 = dataset default).
    pub dataset_n: usize,
    pub seed: u64,
    /// Partitioning strategy (`[partition] spec = "..."`, or the legacy
    /// `method` key plus optional `alpha`/`beta` overrides).
    pub spec: PartitionSpec,
    pub k: usize,
    /// Worker threads for the partitioning pipeline (`[partition]
    /// threads`, `--threads`; ≥ 1, same output for every value).
    pub partition_threads: usize,
    pub model: ModelKind,
    pub mode: Mode,
    pub epochs: usize,
    pub mlp_epochs: usize,
    pub machines: usize,
    /// PJRT execution strategy for the training loops (`[train] exec =
    /// "session" | "reference"`, `--exec`): the device-resident session
    /// (default) or the host round-trip reference path.
    pub exec: ExecPath,
    /// Retry budget for a transiently-failed partition (`[train]
    /// max_retries`).
    pub max_retries: u32,
    /// Policy for a partition that exhausts its retries (`[train]
    /// on_failure = "abort" | "skip"`, `--on-failure`).
    pub on_failure: FailurePolicy,
    /// Per-partition training deadline in seconds (`[train] deadline`,
    /// `--deadline`; 0 disables the watchdog).
    pub deadline_secs: f64,
    /// Fault-injection plan spec (`[fault] plan`, `--fault-plan`) —
    /// parsed and installed by the launcher at startup.
    pub fault_plan: Option<String>,
    /// Replay journaled partitions instead of retraining them
    /// (`[train] resume`, `--resume`; needs a shard dir).
    pub resume: bool,
    pub artifacts_dir: PathBuf,
    /// When set, `train` exports a serving bundle (shards + classifier)
    /// here (`[serve] export_dir`, or `--shards` on the CLI).
    pub shards_out: Option<PathBuf>,
    /// Serving-engine knobs (`[serve]` section).
    pub serve: ServeConfig,
    /// Distributed-transport knobs (`[net]` section), used by the
    /// `coordinator serve` and `worker join` subcommands.
    pub net: NetConfig,
}

/// Configuration of the embedding-serving layer (`[serve]` section).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Shard-bundle directory the `serve`/`query` subcommands read.
    pub shards_dir: PathBuf,
    /// Max queries folded into one MLP forward.
    pub batch_size: usize,
    /// Engine worker threads (each owns a PJRT runtime).
    pub workers: usize,
    /// LRU result-cache entries across all stripes (0 disables caching;
    /// single-flight miss coalescing stays on).
    pub cache_capacity: usize,
    /// Cache stripes (rounded up to a power of two by the engine;
    /// 0 = auto: 4 per worker).
    pub cache_stripes: usize,
    /// Eagerly load every shard slab before serving (`repro serve` also
    /// takes `--warm` on the CLI).
    pub warm: bool,
    /// HTTP front-end bind address (`[serve] http`, `--http`); `None`
    /// keeps the stdin query loop.
    pub http: Option<String>,
    /// Max concurrently admitted HTTP `/classify` requests; excess gets
    /// 429 + `Retry-After` (`[serve] max_inflight`, 0 = unbounded).
    pub max_inflight: usize,
    /// Per-request deadline in milliseconds, exceeded → 503
    /// (`[serve] request_deadline_ms`, 0 disables).
    pub request_deadline_ms: u64,
    /// Watch the bundle directory and hot-swap to newly published
    /// versions (`[serve] watch`, `--watch`).
    pub watch: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards_dir: PathBuf::from("shards"),
            batch_size: 64,
            workers: 2,
            cache_capacity: 4096,
            cache_stripes: 8,
            warm: false,
            http: None,
            max_inflight: 256,
            request_deadline_ms: 2_000,
            watch: false,
        }
    }
}

impl ServeConfig {
    pub fn from_toml(t: &Toml) -> Self {
        let d = ServeConfig::default();
        // negative values clamp to 0 instead of wrapping through `as usize`
        // (workers = -1 must not become a 2^64-thread spawn request)
        let nneg = |section: &str, key: &str, default: usize| {
            t.int_or(section, key, default as i64).max(0) as usize
        };
        ServeConfig {
            shards_dir: match t.get("serve", "shards_dir") {
                Some(Value::Str(s)) => PathBuf::from(s),
                _ => d.shards_dir,
            },
            batch_size: nneg("serve", "batch_size", d.batch_size),
            workers: nneg("serve", "workers", d.workers),
            cache_capacity: nneg("serve", "cache_capacity", d.cache_capacity),
            cache_stripes: nneg("serve", "cache_stripes", d.cache_stripes),
            warm: t.bool_or("serve", "warm", d.warm),
            http: match t.get("serve", "http") {
                Some(Value::Str(s)) => Some(s.clone()),
                _ => d.http,
            },
            max_inflight: nneg("serve", "max_inflight", d.max_inflight),
            request_deadline_ms: t
                .int_or("serve", "request_deadline_ms", d.request_deadline_ms as i64)
                .max(0) as u64,
            watch: t.bool_or("serve", "watch", d.watch),
        }
    }
}

/// Configuration of the distributed TCP transport (`[net]` section):
/// the leader's bind address, liveness cadence, and the reconnect
/// behaviour on both sides. Shared by `coordinator serve` (bind, join
/// deadline, grace window) and `worker join` (redial budget); workers
/// adopt the leader's heartbeat cadence from the `Welcome` frame.
#[derive(Clone, Debug, PartialEq)]
pub struct NetConfig {
    /// Leader bind address (`--bind`); port 0 asks the OS for a free
    /// port — combine with `port_file` so scripts can find it.
    pub bind: String,
    /// Worker heartbeat interval in milliseconds. The leader suspects a
    /// session silent for ~3 intervals (plus seeded jitter).
    pub heartbeat_ms: u64,
    /// How long a suspected worker may take to reconnect before its
    /// slot is retired, in milliseconds.
    pub grace_ms: u64,
    /// Leader gives up (retiring every slot) when no worker has joined
    /// within this many seconds; 0 waits forever.
    pub join_timeout_secs: f64,
    /// Consecutive failed dial attempts before `worker join` gives up.
    pub reconnect_attempts: u32,
    /// When set, the leader writes its bound port here after listen —
    /// race-free port discovery for scripts binding port 0 (`--port-file`).
    pub port_file: Option<PathBuf>,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            bind: "127.0.0.1:0".to_string(),
            heartbeat_ms: 500,
            grace_ms: 2000,
            join_timeout_secs: 30.0,
            reconnect_attempts: 5,
            port_file: None,
        }
    }
}

impl NetConfig {
    pub fn from_toml(t: &Toml) -> Result<Self> {
        let d = NetConfig::default();
        // negative intervals clamp to 0 (where 0 has a defined meaning)
        // instead of wrapping through `as u64`
        let nneg = |key: &str, default: u64| -> u64 {
            t.int_or("net", key, default as i64).max(0) as u64
        };
        Ok(NetConfig {
            bind: match t.get("net", "bind") {
                Some(Value::Str(s)) => s.clone(),
                _ => d.bind,
            },
            heartbeat_ms: nneg("heartbeat_ms", d.heartbeat_ms),
            grace_ms: nneg("grace_ms", d.grace_ms),
            join_timeout_secs: float_opt(t, "net", "join_timeout_secs")?
                .unwrap_or(d.join_timeout_secs)
                .max(0.0),
            reconnect_attempts: nneg("reconnect_attempts", d.reconnect_attempts as u64)
                as u32,
            port_file: match t.get("net", "port_file") {
                Some(Value::Str(s)) => Some(PathBuf::from(s)),
                _ => d.port_file,
            },
        })
    }
}

/// `[obs] trace = "path"` — when set, the launcher enables span tracing
/// at startup and writes a Chrome-trace JSON here on exit. The CLI
/// `--trace-out` flag wins over this key.
pub fn obs_trace_path(t: &Toml) -> Result<Option<PathBuf>> {
    match t.get("obs", "trace") {
        None => Ok(None),
        Some(Value::Str(s)) => Ok(Some(PathBuf::from(s))),
        Some(other) => Err(Error::Config(format!(
            "[obs] trace must be a string path, got {other:?}"
        ))),
    }
}

/// Numeric key as a float, accepting integer literals; `None` if absent,
/// a clear error if present with a non-numeric type.
fn float_opt(t: &Toml, section: &str, key: &str) -> Result<Option<f64>> {
    match t.get(section, key) {
        None => Ok(None),
        Some(Value::Float(f)) => Ok(Some(*f)),
        Some(Value::Int(i)) => Ok(Some(*i as f64)),
        Some(other) => Err(Error::Config(format!(
            "[{section}] {key} must be a number, got {other:?}"
        ))),
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "arxiv".into(),
            dataset_n: 0,
            seed: 42,
            spec: PartitionSpec::default(),
            k: 4,
            partition_threads: 1,
            model: ModelKind::Gcn,
            mode: Mode::Inner,
            epochs: 80,
            mlp_epochs: 200,
            machines: 4,
            exec: ExecPath::Session,
            max_retries: 1,
            on_failure: FailurePolicy::Abort,
            deadline_secs: 0.0,
            fault_plan: None,
            resume: false,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            shards_out: None,
            serve: ServeConfig::default(),
            net: NetConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML file ([dataset]/[partition]/[train] sections).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&Toml::parse(&text)?)
    }

    pub fn from_toml(t: &Toml) -> Result<Self> {
        let d = ExperimentConfig::default();
        let mode = match t.str_or("train", "mode", "inner").as_str() {
            "inner" => Mode::Inner,
            "repli" => Mode::Repli,
            other => return Err(Error::Config(format!("unknown mode {other:?}"))),
        };
        // `spec` (grammar) wins; the legacy `alpha`/`beta` keys are
        // stage-parameter overrides for the `method` path only — an
        // explicit spec is never silently rewritten.
        let explicit = match t.get("partition", "spec") {
            Some(Value::Str(s)) => Some(s.clone()),
            Some(other) => {
                return Err(Error::Config(format!(
                    "[partition] spec must be a string, got {other:?}"
                )))
            }
            None => None,
        };
        let explicit_spec = explicit.is_some();
        if explicit_spec && t.get("partition", "method").is_some() {
            log::warn!("[partition] method ignored: spec wins");
        }
        let spec_str = match explicit {
            Some(s) => s,
            None => match t.get("partition", "method") {
                Some(Value::Str(s)) => s.clone(),
                Some(other) => {
                    return Err(Error::Config(format!(
                        "[partition] method must be a string, got {other:?}"
                    )))
                }
                None => "lf".to_string(),
            },
        };
        let mut spec: PartitionSpec = spec_str.parse()?;
        // overrides fill gaps only — parameters written inside the spec
        // string itself (either key) are never clobbered
        let alpha_in_spec = spec
            .stages()
            .iter()
            .any(|st| matches!(st, StageSpec::Fusion { alpha: Some(_) }));
        let beta_in_spec = matches!(
            spec.stages().first(),
            Some(StageSpec::Leiden { beta: Some(_), .. })
                | Some(StageSpec::Louvain { beta: Some(_), .. })
        );
        if let Some(a) = float_opt(t, "partition", "alpha")? {
            if explicit_spec || alpha_in_spec {
                log::warn!("[partition] alpha ignored: set it inside the spec string instead");
            } else if !spec.set_fusion_alpha(a) {
                log::warn!("[partition] alpha has no effect: {spec} has no fusion stage");
            }
        }
        if let Some(b) = float_opt(t, "partition", "beta")? {
            if explicit_spec || beta_in_spec {
                log::warn!("[partition] beta ignored: set it inside the spec string instead");
            } else if !spec.set_detect_beta(b) {
                log::warn!("[partition] beta has no effect: {spec} has no size-capped detector");
            }
        }
        Ok(ExperimentConfig {
            dataset: t.str_or("dataset", "name", &d.dataset),
            dataset_n: t.int_or("dataset", "n", 0) as usize,
            seed: t.int_or("dataset", "seed", d.seed as i64) as u64,
            spec,
            k: t.int_or("partition", "k", d.k as i64) as usize,
            partition_threads: t
                .int_or("partition", "threads", d.partition_threads as i64)
                .max(1) as usize,
            model: ModelKind::parse(&t.str_or("train", "model", "gcn"))?,
            mode,
            epochs: t.int_or("train", "epochs", d.epochs as i64) as usize,
            mlp_epochs: t.int_or("train", "mlp_epochs", d.mlp_epochs as i64) as usize,
            machines: t.int_or("train", "machines", d.machines as i64) as usize,
            exec: ExecPath::parse(&t.str_or("train", "exec", d.exec.as_str()))?,
            max_retries: t
                .int_or("train", "max_retries", d.max_retries as i64)
                .max(0) as u32,
            on_failure: FailurePolicy::parse(&t.str_or(
                "train",
                "on_failure",
                d.on_failure.as_str(),
            ))?,
            deadline_secs: {
                let v = float_opt(t, "train", "deadline")?.unwrap_or(d.deadline_secs);
                if v < 0.0 {
                    return Err(Error::Config(format!(
                        "[train] deadline must be >= 0 seconds, got {v}"
                    )));
                }
                v
            },
            fault_plan: match t.get("fault", "plan") {
                Some(Value::Str(s)) => Some(s.clone()),
                Some(other) => {
                    return Err(Error::Config(format!(
                        "[fault] plan must be a string, got {other:?}"
                    )))
                }
                None => None,
            },
            resume: t.bool_or("train", "resume", d.resume),
            artifacts_dir: match t.get("train", "artifacts_dir") {
                Some(Value::Str(s)) => PathBuf::from(s),
                _ => d.artifacts_dir,
            },
            shards_out: match t.get("serve", "export_dir") {
                Some(Value::Str(s)) => Some(PathBuf::from(s)),
                _ => None,
            },
            serve: ServeConfig::from_toml(t),
            net: NetConfig::from_toml(t)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[dataset]
name = "arxiv"
n = 5000
seed = 7

[partition]
method = "lf"
k = 8
alpha = 0.05

[train]
model = "sage"
mode = "repli"
epochs = 40
machines = 2
"#;

    #[test]
    fn parses_sample() {
        let cfg = ExperimentConfig::from_toml(&Toml::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.dataset, "arxiv");
        assert_eq!(cfg.dataset_n, 5000);
        assert_eq!(cfg.k, 8);
        assert_eq!(cfg.model, ModelKind::Sage);
        assert_eq!(cfg.mode, Mode::Repli);
        assert_eq!(cfg.machines, 2);
        // defaults fill gaps
        assert_eq!(cfg.mlp_epochs, 200);
        // `method = "lf"` + `alpha = 0.05` → spec with the α override set
        assert_eq!(cfg.spec.to_string(), "leiden+fusion(alpha=0.05)");
    }

    #[test]
    fn partition_threads_key_parses_and_clamps() {
        let t = Toml::parse("[partition]\nthreads = 4\n").unwrap();
        assert_eq!(ExperimentConfig::from_toml(&t).unwrap().partition_threads, 4);
        // non-positive values clamp to the sequential default
        let t = Toml::parse("[partition]\nthreads = -2\n").unwrap();
        assert_eq!(ExperimentConfig::from_toml(&t).unwrap().partition_threads, 1);
        let t = Toml::parse("[partition]\nk = 2\n").unwrap();
        assert_eq!(ExperimentConfig::from_toml(&t).unwrap().partition_threads, 1);
    }

    #[test]
    fn partition_spec_key_wins_over_method() {
        let t = Toml::parse(
            "[partition]\nspec = \"metis(imbalance=0.1)+fusion\"\nmethod = \"lpa\"\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.spec.to_string(), "metis(imbalance=0.1)+fusion");
    }

    #[test]
    fn explicit_spec_is_not_rewritten_by_legacy_keys() {
        let t = Toml::parse(
            "[partition]\nspec = \"leiden+fusion(alpha=0.1)\"\nalpha = 0.05\nbeta = 0.25\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.spec.to_string(), "leiden+fusion(alpha=0.1)");
        // same guarantee when the grammar form arrives via `method`
        let t = Toml::parse(
            "[partition]\nmethod = \"leiden(beta=0.1)+fusion(alpha=0.2)\"\nalpha = 0.05\nbeta = 0.25\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.spec.to_string(), "leiden(beta=0.1)+fusion(alpha=0.2)");
    }

    #[test]
    fn legacy_beta_key_overrides_detect_stage() {
        let t = Toml::parse("[partition]\nmethod = \"lf\"\nbeta = 0.25\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.spec.to_string(), "leiden(beta=0.25)+fusion");
    }

    #[test]
    fn rejects_bad_spec_string() {
        let t = Toml::parse("[partition]\nspec = \"leiden+\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
        let t = Toml::parse("[partition]\nmethod = \"nope\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
        // a mistyped non-string spec must error, not silently fall back
        let t = Toml::parse("[partition]\nspec = 0.5\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
        // ... and so must a non-numeric alpha/beta override
        let t = Toml::parse("[partition]\nmethod = \"lf\"\nalpha = \"0.1\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
        // ... and a non-string method (forgotten quotes)
        let t = Toml::parse("[partition]\nmethod = 2\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
    }

    #[test]
    fn parses_serve_section() {
        let t = Toml::parse(
            "[serve]\nshards_dir = \"out/shards\"\nexport_dir = \"out/shards\"\n\
             batch_size = 128\nworkers = 4\ncache_capacity = 100\n\
             cache_stripes = 16\nwarm = true\nhttp = \"127.0.0.1:8080\"\n\
             max_inflight = 32\nrequest_deadline_ms = 500\nwatch = true\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.serve.shards_dir, PathBuf::from("out/shards"));
        assert_eq!(cfg.serve.batch_size, 128);
        assert_eq!(cfg.serve.workers, 4);
        assert_eq!(cfg.serve.cache_capacity, 100);
        assert_eq!(cfg.serve.cache_stripes, 16);
        assert!(cfg.serve.warm);
        assert_eq!(cfg.serve.http.as_deref(), Some("127.0.0.1:8080"));
        assert_eq!(cfg.serve.max_inflight, 32);
        assert_eq!(cfg.serve.request_deadline_ms, 500);
        assert!(cfg.serve.watch);
        assert_eq!(cfg.shards_out, Some(PathBuf::from("out/shards")));
    }

    #[test]
    fn serve_http_defaults_off() {
        let s = ServeConfig::from_toml(&Toml::parse("").unwrap());
        assert_eq!(s.http, None);
        assert_eq!(s.max_inflight, 256);
        assert_eq!(s.request_deadline_ms, 2_000);
        assert!(!s.watch);
    }

    #[test]
    fn serve_negative_values_clamp_to_zero() {
        let t = Toml::parse(
            "[serve]\nworkers = -1\ncache_capacity = -5\ncache_stripes = -3\n\
             max_inflight = -2\nrequest_deadline_ms = -7\n",
        )
        .unwrap();
        let s = ServeConfig::from_toml(&t);
        assert_eq!(s.workers, 0);
        assert_eq!(s.cache_capacity, 0);
        assert_eq!(s.cache_stripes, 0, "-3 clamps to 0 (= auto), not 2^64");
        assert_eq!(s.max_inflight, 0, "-2 clamps to 0 (= unbounded)");
        assert_eq!(s.request_deadline_ms, 0, "-7 clamps to 0 (= no deadline)");
    }

    #[test]
    fn serve_defaults_without_section() {
        let cfg = ExperimentConfig::from_toml(&Toml::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.serve, ServeConfig::default());
        assert_eq!(cfg.shards_out, None);
    }

    #[test]
    fn parses_net_section() {
        let t = Toml::parse(
            "[net]\nbind = \"0.0.0.0:7700\"\nheartbeat_ms = 250\ngrace_ms = 5000\n\
             join_timeout_secs = 10\nreconnect_attempts = 3\nport_file = \"out/port\"\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.net.bind, "0.0.0.0:7700");
        assert_eq!(cfg.net.heartbeat_ms, 250);
        assert_eq!(cfg.net.grace_ms, 5000);
        assert_eq!(cfg.net.join_timeout_secs, 10.0);
        assert_eq!(cfg.net.reconnect_attempts, 3);
        assert_eq!(cfg.net.port_file, Some(PathBuf::from("out/port")));
    }

    #[test]
    fn net_defaults_and_clamps() {
        let cfg = ExperimentConfig::from_toml(&Toml::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.net, NetConfig::default());
        // negative intervals clamp to 0 instead of wrapping through u64
        let t = Toml::parse(
            "[net]\nheartbeat_ms = -9\ngrace_ms = -1\njoin_timeout_secs = -2.0\n",
        )
        .unwrap();
        let n = NetConfig::from_toml(&t).unwrap();
        assert_eq!(n.heartbeat_ms, 0);
        assert_eq!(n.grace_ms, 0);
        assert_eq!(n.join_timeout_secs, 0.0);
        // a non-numeric join timeout is a clear error, not a default
        let t = Toml::parse("[net]\njoin_timeout_secs = \"soon\"\n").unwrap();
        assert!(NetConfig::from_toml(&t).is_err());
    }

    #[test]
    fn value_types() {
        let t = Toml::parse("[s]\na = 1\nb = 2.5\nc = \"x\"\nd = true\n").unwrap();
        assert_eq!(t.int_or("s", "a", 0), 1);
        assert_eq!(t.float_or("s", "b", 0.0), 2.5);
        assert_eq!(t.str_or("s", "c", ""), "x");
        assert!(t.bool_or("s", "d", false));
        assert_eq!(t.int_or("s", "missing", 9), 9);
    }

    #[test]
    fn float_coerces_from_int() {
        let t = Toml::parse("[s]\nalpha = 1\n").unwrap();
        assert_eq!(t.float_or("s", "alpha", 0.0), 1.0);
    }

    #[test]
    fn rejects_unsupported_syntax() {
        assert!(Toml::parse("[a.b]\n").is_err());
        assert!(Toml::parse("[s]\nx = [1, 2]\n").is_err());
        assert!(Toml::parse("[s]\nnovalue\n").is_err());
        assert!(Toml::parse("[s]\nx = \"unterminated\n").is_err());
    }

    #[test]
    fn rejects_unknown_mode_and_model() {
        let t = Toml::parse("[train]\nmode = \"weird\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
        let t = Toml::parse("[train]\nmodel = \"gat\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
    }

    #[test]
    fn train_exec_key_parses_and_rejects_unknown() {
        // default: the device-resident session
        let cfg = ExperimentConfig::from_toml(&Toml::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.exec, ExecPath::Session);
        let t = Toml::parse("[train]\nexec = \"reference\"\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.exec, ExecPath::Reference);
        let t = Toml::parse("[train]\nexec = \"device\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
    }

    #[test]
    fn fault_and_failure_keys_parse() {
        let t = Toml::parse(
            "[train]\non_failure = \"skip\"\ndeadline = 30\nmax_retries = 3\n\
             [fault]\nplan = \"worker.train:part=0:fail\"\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.on_failure, FailurePolicy::Skip);
        assert_eq!(cfg.deadline_secs, 30.0);
        assert_eq!(cfg.max_retries, 3);
        assert_eq!(cfg.fault_plan.as_deref(), Some("worker.train:part=0:fail"));
        // defaults: strict abort, no watchdog, no plan
        let cfg = ExperimentConfig::from_toml(&Toml::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.on_failure, FailurePolicy::Abort);
        assert_eq!(cfg.deadline_secs, 0.0);
        assert_eq!(cfg.max_retries, 1);
        assert_eq!(cfg.fault_plan, None);
    }

    #[test]
    fn fault_and_failure_keys_reject_bad_values() {
        let t = Toml::parse("[train]\non_failure = \"retry\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
        let t = Toml::parse("[train]\ndeadline = -1\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
        let t = Toml::parse("[fault]\nplan = 5\n").unwrap();
        assert!(ExperimentConfig::from_toml(&t).is_err());
        // negative retry budgets clamp to zero rather than wrapping
        let t = Toml::parse("[train]\nmax_retries = -4\n").unwrap();
        assert_eq!(ExperimentConfig::from_toml(&t).unwrap().max_retries, 0);
    }

    #[test]
    fn obs_trace_key_parses_and_rejects_non_string() {
        let t = Toml::parse("[obs]\ntrace = \"out/trace.json\"\n").unwrap();
        assert_eq!(
            obs_trace_path(&t).unwrap(),
            Some(PathBuf::from("out/trace.json"))
        );
        // absent section/key → no trace output configured
        assert_eq!(obs_trace_path(&Toml::parse(SAMPLE).unwrap()).unwrap(), None);
        // a mistyped value must error, not silently disable tracing
        let t = Toml::parse("[obs]\ntrace = true\n").unwrap();
        assert!(obs_trace_path(&t).is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let t = Toml::parse("# top\n\n[s] # trailing\nx = 1 # eol\n").unwrap();
        assert_eq!(t.int_or("s", "x", 0), 1);
    }
}
