//! A lightweight Rust lexer for the in-crate linter.
//!
//! Produces a flat token stream (identifiers, literals, punctuation) plus
//! a side list of comments — enough surface syntax for the pattern rules
//! in [`super::rules`] without building an AST. The tricky corners a
//! naive regex scan gets wrong are handled properly:
//!
//! * **raw strings** `r"…"`, `r#"…"#` (any hash depth), byte and
//!   raw-byte strings `b"…"`, `br#"…"#` — a `"unwrap()"` inside one must
//!   not look like a call;
//! * **raw identifiers** `r#match` (lexed as the identifier `match`);
//! * **nested block comments** `/* a /* b */ c */` per the Rust grammar;
//! * **char literals vs lifetimes**: `'a'` is a char, `'a` is a
//!   lifetime, `'\''` and `'∀'` are chars — disambiguated by looking for
//!   the closing tick after exactly one (possibly escaped, possibly
//!   multi-byte) character;
//! * **multi-char operators** (`::`, `!=`, `..=`, …) lexed as single
//!   tokens so `x != y` can never read as a macro bang.
//!
//! Tokens carry 1-based line numbers; rules report and suppress by line.

/// Token classification — deliberately coarse: the rules match on
/// `(kind, text)` pairs and adjacency, never on deeper structure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword (`fn`, `unwrap`, `HashMap`, …).
    Ident,
    /// String literal of any flavor: `"…"`, `r#"…"#`, `b"…"`, `br"…"`.
    Str,
    /// Char or byte-char literal: `'x'`, `'\n'`, `b'\0'`.
    Char,
    /// Lifetime (or loop label): `'a`, `'static`.
    Lifetime,
    /// Numeric literal, suffix included: `42`, `0xFF`, `1.5e-3_f64`.
    Num,
    /// Punctuation / operator, multi-char operators as one token.
    Punct,
}

/// One lexed token. `text` is the exact source slice (quotes included
/// for literals); `line` is 1-based.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    pub line: u32,
}

impl Token {
    /// Literal payload of a [`TokenKind::Str`] token: quotes, raw-string
    /// hashes, and `b`/`r` prefixes stripped. Escape sequences are left
    /// as written — the rules only compare short ASCII names, which
    /// never contain escapes.
    pub fn str_value(&self) -> &str {
        let t = self.text.as_str();
        let t = t.strip_prefix('b').unwrap_or(t);
        if let Some(raw) = t.strip_prefix('r') {
            let hashes = raw.bytes().take_while(|&b| b == b'#').count();
            let inner = &raw[hashes..];
            let inner = inner.strip_prefix('"').unwrap_or(inner);
            let end = inner.len().saturating_sub(1 + hashes);
            return inner.get(..end).unwrap_or(inner);
        }
        let t = t.strip_prefix('"').unwrap_or(t);
        t.strip_suffix('"').unwrap_or(t)
    }
}

/// A comment (line or block), with the line it starts on. Block comment
/// text keeps its newlines; suppression comments are single-line.
#[derive(Clone, Debug)]
pub struct Comment {
    pub text: String,
    pub line: u32,
}

/// Multi-char operators, longest first so `>>=` wins over `>>` over `>`.
const MULTI_OPS: &[&str] = &[
    "<<=", ">>=", "..=", "::", "==", "!=", "<=", ">=", "->", "=>", "+=", "-=", "*=", "/=",
    "%=", "^=", "&=", "|=", "..", "&&", "||", "<<", ">>",
];

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte length of the UTF-8 character starting at `b` (1 for ASCII and
/// for malformed lead bytes, so the scanner always makes progress).
fn char_len(b: u8) -> usize {
    match b {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

/// Lex `src` into tokens and comments. Never fails: unterminated
/// literals and comments extend to end-of-file (the linter must degrade
/// gracefully on code mid-edit), and unknown bytes become one-char
/// `Punct` tokens.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Comment>) {
    Lexer { b: src.as_bytes(), src, i: 0, line: 1 }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    src: &'a str,
    i: usize,
    line: u32,
}

impl<'a> Lexer<'a> {
    fn run(mut self) -> (Vec<Token>, Vec<Comment>) {
        let mut tokens = Vec::new();
        let mut comments = Vec::new();
        while self.i < self.b.len() {
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b' ' | b'\t' | b'\r' => self.i += 1,
                b'/' if self.starts_with("//") => {
                    let start = self.i;
                    while self.i < self.b.len() && self.b[self.i] != b'\n' {
                        self.i += 1;
                    }
                    comments.push(Comment {
                        text: self.src[start..self.i].to_string(),
                        line: self.line,
                    });
                }
                b'/' if self.starts_with("/*") => {
                    let (start, start_line) = (self.i, self.line);
                    self.i += 2;
                    let mut depth = 1usize;
                    while self.i < self.b.len() && depth > 0 {
                        if self.starts_with("/*") {
                            depth += 1;
                            self.i += 2;
                        } else if self.starts_with("*/") {
                            depth -= 1;
                            self.i += 2;
                        } else {
                            if self.b[self.i] == b'\n' {
                                self.line += 1;
                            }
                            self.i += char_len(self.b[self.i]);
                        }
                    }
                    comments.push(Comment {
                        text: self.src[start..self.i].to_string(),
                        line: start_line,
                    });
                }
                b'r' | b'b' => {
                    if let Some(tok) = self.raw_or_byte_literal() {
                        tokens.push(tok);
                    } else {
                        tokens.push(self.ident());
                    }
                }
                _ if is_ident_start(c) => tokens.push(self.ident()),
                b'"' => tokens.push(self.string_literal(self.i)),
                b'\'' => tokens.push(self.tick()),
                b'0'..=b'9' => tokens.push(self.number()),
                _ => tokens.push(self.punct()),
            }
        }
        (tokens, comments)
    }

    fn starts_with(&self, s: &str) -> bool {
        self.b[self.i..].starts_with(s.as_bytes())
    }

    fn slice_token(&self, kind: TokenKind, start: usize, line: u32) -> Token {
        Token { kind, text: self.src[start..self.i].to_string(), line }
    }

    /// `r"…"`, `r#"…"#`, `br"…"`, `b"…"`, or raw identifier `r#name`.
    /// Returns `None` when the `r`/`b` here is just the start of a plain
    /// identifier (`rows`, `buf`), letting the caller lex it as one.
    fn raw_or_byte_literal(&mut self) -> Option<Token> {
        let start = self.i;
        let start_line = self.line;
        let two = self.b.get(self.i..self.i + 2);
        let prefix_len = match two {
            Some(b"br") | Some(b"rb") => 2,
            _ => 1,
        };
        let has_r = self.b[self.i] == b'r' || prefix_len == 2;
        let mut j = self.i + prefix_len;
        if has_r {
            let mut hashes = 0usize;
            while self.b.get(j) == Some(&b'#') {
                hashes += 1;
                j += 1;
            }
            if self.b.get(j) == Some(&b'"') {
                // raw string: scan for `"###...` of the same depth
                self.i = j + 1;
                loop {
                    match self.b.get(self.i) {
                        None => break,
                        Some(b'"') => {
                            let tail = &self.b[self.i + 1..];
                            if tail.len() >= hashes
                                && tail[..hashes].iter().all(|&h| h == b'#')
                            {
                                self.i += 1 + hashes;
                                break;
                            }
                            self.i += 1;
                        }
                        Some(b'\n') => {
                            self.line += 1;
                            self.i += 1;
                        }
                        Some(&b) => self.i += char_len(b),
                    }
                }
                return Some(self.slice_token(TokenKind::Str, start, start_line));
            }
            if prefix_len == 1 && hashes == 1 {
                if let Some(&b) = self.b.get(j) {
                    if is_ident_start(b) {
                        // raw identifier r#name → the identifier `name`
                        let name_start = j;
                        while self.b.get(j).is_some_and(|&b| is_ident_cont(b)) {
                            j += 1;
                        }
                        self.i = j;
                        return Some(Token {
                            kind: TokenKind::Ident,
                            text: self.src[name_start..j].to_string(),
                            line: start_line,
                        });
                    }
                }
            }
        }
        if self.b[self.i] == b'b' {
            match self.b.get(self.i + 1) {
                Some(b'"') => return Some(self.string_literal(start)),
                Some(b'\'') => {
                    self.i += 1; // consume the `b`; tick() scans from `'`
                    let mut tok = self.tick();
                    tok.text = self.src[start..self.i].to_string();
                    return Some(tok);
                }
                _ => {}
            }
        }
        None
    }

    fn ident(&mut self) -> Token {
        let start = self.i;
        while self.i < self.b.len() && is_ident_cont(self.b[self.i]) {
            self.i += 1;
        }
        self.slice_token(TokenKind::Ident, start, self.line)
    }

    /// Plain (or byte) string literal; `start` marks any `b` prefix.
    /// `self.i` may point at the prefix or the quote — scanning begins at
    /// the first `"` at or after it.
    fn string_literal(&mut self, start: usize) -> Token {
        let start_line = self.line;
        while self.b.get(self.i) != Some(&b'"') && self.i < self.b.len() {
            self.i += 1;
        }
        self.i += 1; // opening quote
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'\\' => self.i += 2,
                b'"' => {
                    self.i += 1;
                    break;
                }
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b => self.i += char_len(b),
            }
        }
        self.slice_token(TokenKind::Str, start, start_line)
    }

    /// A tick: char literal (`'x'`, `'\n'`, `'∀'`), lifetime (`'a`), or
    /// a stray `'`. One (possibly escaped) character followed by a
    /// closing tick is a char literal; an identifier tail is a lifetime.
    fn tick(&mut self) -> Token {
        let start = self.i;
        self.i += 1; // the tick
        match self.b.get(self.i) {
            Some(b'\\') => {
                // escaped char literal: scan to the closing tick
                self.i += 2; // backslash + escape head (n, ', u, x, …)
                while self.i < self.b.len() && self.b[self.i] != b'\'' {
                    self.i += char_len(self.b[self.i]);
                }
                self.i = (self.i + 1).min(self.b.len());
                self.slice_token(TokenKind::Char, start, self.line)
            }
            Some(&b) => {
                let advance = char_len(b);
                if self.b.get(self.i + advance) == Some(&b'\'') {
                    self.i += advance + 1;
                    self.slice_token(TokenKind::Char, start, self.line)
                } else if is_ident_start(b) {
                    self.i += 1;
                    while self.i < self.b.len() && is_ident_cont(self.b[self.i]) {
                        self.i += 1;
                    }
                    self.slice_token(TokenKind::Lifetime, start, self.line)
                } else {
                    self.slice_token(TokenKind::Punct, start, self.line)
                }
            }
            None => self.slice_token(TokenKind::Punct, start, self.line),
        }
    }

    fn number(&mut self) -> Token {
        let start = self.i;
        while self.i < self.b.len() && matches!(self.b[self.i], b'0'..=b'9' | b'_') {
            self.i += 1;
        }
        // fraction only when a digit follows the dot — `0..n` stays a range
        if self.b.get(self.i) == Some(&b'.')
            && self.b.get(self.i + 1).is_some_and(|b| b.is_ascii_digit())
        {
            self.i += 1;
            while self.i < self.b.len() && matches!(self.b[self.i], b'0'..=b'9' | b'_') {
                self.i += 1;
            }
        }
        if matches!(self.b.get(self.i), Some(b'e') | Some(b'E'))
            && self
                .b
                .get(self.i + 1)
                .is_some_and(|&b| b.is_ascii_digit() || b == b'+' || b == b'-')
        {
            self.i += 2;
            while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
                self.i += 1;
            }
        }
        // hex/binary digits and type suffixes: 0xFF, 42u64, 1.0f32
        while self.i < self.b.len() && is_ident_cont(self.b[self.i]) {
            self.i += 1;
        }
        self.slice_token(TokenKind::Num, start, self.line)
    }

    fn punct(&mut self) -> Token {
        for op in MULTI_OPS {
            if self.starts_with(op) {
                let start = self.i;
                self.i += op.len();
                return self.slice_token(TokenKind::Punct, start, self.line);
            }
        }
        let start = self.i;
        self.i += char_len(self.b[self.i]);
        self.slice_token(TokenKind::Punct, start, self.line)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokenKind, String)> {
        lex(src).0.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_and_puncts() {
        let toks = kinds("let x = a.unwrap();");
        let texts: Vec<&str> = toks.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(texts, vec!["let", "x", "=", "a", ".", "unwrap", "(", ")", ";"]);
    }

    #[test]
    fn raw_string_hides_call_syntax() {
        let toks = kinds(r##"let s = r#"x.unwrap()"#;"##);
        assert_eq!(toks[3].0, TokenKind::Str);
        assert!(!toks.iter().any(|(k, t)| *k == TokenKind::Ident && t == "unwrap"));
    }

    #[test]
    fn raw_string_hash_depth_matters() {
        // the inner `"#` must not close an r##"…"## string
        let src = "r##\"contains \"# inside\"## rest";
        let (toks, _) = lex(src);
        assert_eq!(toks[0].kind, TokenKind::Str);
        assert_eq!(toks[0].str_value(), "contains \"# inside");
        assert_eq!(toks[1].text, "rest");
    }

    #[test]
    fn byte_and_raw_byte_strings() {
        let toks = kinds(r#"b"abc" br"def" b'x' rb"ghi""#);
        assert_eq!(toks[0], (TokenKind::Str, "b\"abc\"".to_string()));
        assert_eq!(toks[1], (TokenKind::Str, "br\"def\"".to_string()));
        assert_eq!(toks[2], (TokenKind::Char, "b'x'".to_string()));
        assert_eq!(toks[3].0, TokenKind::Str);
    }

    #[test]
    fn raw_identifier_is_an_ident() {
        let toks = kinds("let r#match = 1;");
        assert_eq!(toks[1], (TokenKind::Ident, "match".to_string()));
    }

    #[test]
    fn nested_block_comments() {
        let (toks, comments) = lex("a /* one /* two */ still one */ b");
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[1].text, "b");
        assert_eq!(comments.len(), 1);
        assert!(comments[0].text.contains("still one"));
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = kinds(r"'a' 'b 'static '\'' '\n' '∀'");
        let got: Vec<TokenKind> = toks.iter().map(|(k, _)| *k).collect();
        assert_eq!(
            got,
            vec![
                TokenKind::Char,
                TokenKind::Lifetime,
                TokenKind::Lifetime,
                TokenKind::Char,
                TokenKind::Char,
                TokenKind::Char,
            ]
        );
    }

    #[test]
    fn lifetime_in_generics() {
        let toks = kinds("fn f<'a>(x: &'a str) -> &'a str { x }");
        let lifetimes = toks.iter().filter(|(k, _)| *k == TokenKind::Lifetime).count();
        assert_eq!(lifetimes, 3);
    }

    #[test]
    fn multi_char_operators_stay_whole() {
        let toks = kinds("if x != y && a..=b { p ->q }");
        let texts: Vec<&str> = toks.iter().map(|(_, t)| t.as_str()).collect();
        assert!(texts.contains(&"!="));
        assert!(texts.contains(&"&&"));
        assert!(texts.contains(&"..="));
        assert!(texts.contains(&"->"));
        // crucially, no bare `!` token that could read as a macro bang
        assert!(!texts.contains(&"!"));
    }

    #[test]
    fn numbers_do_not_swallow_ranges() {
        let toks = kinds("for i in 0..len { x[i] = 1.5e-3; }");
        let texts: Vec<&str> = toks.iter().map(|(_, t)| t.as_str()).collect();
        assert!(texts.contains(&"0"));
        assert!(texts.contains(&".."));
        assert!(texts.contains(&"len"));
        assert!(texts.contains(&"1.5e-3"));
    }

    #[test]
    fn line_numbers_track_all_literal_forms() {
        let src = "a\n\"two\nlines\"\nb /* c\nd */ e\nf";
        let (toks, comments) = lex(src);
        let find = |name: &str| toks.iter().find(|t| t.text == name).map(|t| t.line);
        assert_eq!(find("a"), Some(1));
        assert_eq!(find("b"), Some(4));
        assert_eq!(find("e"), Some(5));
        assert_eq!(find("f"), Some(6));
        assert_eq!(comments[0].line, 4);
    }

    #[test]
    fn attribute_tokens_surface_in_order() {
        let toks = kinds("#[cfg(test)]\nmod tests {}");
        let texts: Vec<&str> = toks.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(texts[..6], ["#", "[", "cfg", "(", "test", ")"]);
    }

    #[test]
    fn str_value_strips_every_quoting_form() {
        let cases = [
            ("\"plain\"", "plain"),
            ("r\"raw\"", "raw"),
            ("r#\"hashed\"#", "hashed"),
            ("b\"bytes\"", "bytes"),
        ];
        for (src, want) in cases {
            let (toks, _) = lex(src);
            assert_eq!(toks[0].str_value(), want, "{src}");
        }
    }

    #[test]
    fn unterminated_forms_reach_eof_without_panicking() {
        for src in ["\"open", "r#\"open", "/* open", "'"] {
            let _ = lex(src); // must not panic or loop
        }
    }
}
