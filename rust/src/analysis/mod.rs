//! `pallas-lint`: the in-crate static analysis pass behind `repro lint`.
//!
//! The repo's scientific claims rest on invariants nothing checked
//! statically until now: byte-identical partitions across thread
//! counts, bit-exact serve-vs-offline logits, bit-exact
//! session-vs-reference training. One stray `HashMap` iteration in a
//! partition kernel, or an `unwrap()` that poisons a worker's lock,
//! silently breaks those contracts — and tests only catch the
//! regression after the fact. This module catches the *pattern* at
//! review time.
//!
//! Like the crate's JSON/TOML/proptest layers, the subsystem is
//! dependency-free by design (the build must work offline): a
//! hand-rolled lexer ([`lexer`]) feeds a token-pattern rule engine
//! ([`rules`]) that produces per-file, per-line diagnostics
//! ([`report`]) with human, JSON, and `--fixable` renderings.
//!
//! Entry points:
//! - [`lint_root`] — lex and lint every `.rs` file under a directory
//!   (what `repro lint --src <dir>` calls);
//! - [`lint_sources`] — the same over in-memory `(path, source)` pairs
//!   (what the fixture tests call).
//!
//! Exceptions are granted *inline and justified only*:
//!
//! ```text
//! // lint: allow(<rule>) — <justification>
//! ```
//!
//! on the violating line or the line directly above. An `allow`
//! without a justification still fails the run. See DESIGN.md
//! "Static analysis" for the rule catalog and how to add a rule.

pub mod lexer;
pub mod report;
pub mod rules;

use crate::error::Result;
use std::path::Path;

pub use report::{Diagnostic, Report, Suppression};
pub use rules::{all_rules, FileSet, Rule};

/// Lint every `.rs` file under `root` (recursively, in sorted path
/// order) and return the full report. The caller decides whether
/// unannotated findings are fatal (`repro lint` exits non-zero).
pub fn lint_root(root: &Path) -> Result<Report> {
    let set = FileSet::load(root)?;
    Ok(rules::run_rules(&set))
}

/// Lint in-memory `(relative_path, source)` pairs — used by the
/// golden-fixture tests and anyone embedding the linter.
pub fn lint_sources(sources: &[(&str, &str)]) -> Report {
    rules::run_rules(&FileSet::from_sources(sources))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_sources_end_to_end() {
        let report = lint_sources(&[(
            "partition/fusion.rs",
            "use std::collections::HashMap;\nfn f(x: Option<u32>) -> u32 { x.unwrap() }\n",
        )]);
        assert_eq!(report.files_scanned, 1);
        assert_eq!(report.unannotated_count(), 2);
        let rules: Vec<_> = report.unannotated().map(|d| d.rule).collect();
        assert_eq!(rules, vec!["nondet_iter", "panic_in_lib"]);
    }

    #[test]
    fn lint_root_walks_a_directory() {
        let dir =
            std::env::temp_dir().join(format!("lf_lint_root_{}", std::process::id()));
        let sub = dir.join("graph");
        std::fs::create_dir_all(&sub).expect("create fixture dir");
        std::fs::write(sub.join("a.rs"), "use std::collections::HashSet;\n")
            .expect("write fixture");
        std::fs::write(dir.join("b.rs"), "fn ok() {}\n").expect("write fixture");
        let report = lint_root(&dir).expect("lint fixture tree");
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(report.files_scanned, 2);
        assert_eq!(report.unannotated_count(), 1);
        assert_eq!(report.diagnostics[0].file, "graph/a.rs");
    }
}
