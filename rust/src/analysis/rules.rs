//! The lint rule engine: source model, suppression handling, and the
//! rule set grounded in this repo's invariants.
//!
//! Each rule is a token-pattern check over [`SourceFile`]s — no AST, no
//! type information — chosen so that every rule is *decidable from the
//! token stream* and cheap enough to run on every tier-1 invocation.
//! The trade-off is that rules are deliberately conservative: they flag
//! the syntactic pattern wherever it appears in non-test library code,
//! and legitimate uses carry an inline justified suppression
//! (`// lint: allow(<rule>) — <why this one is sound>`), which keeps
//! every exception reviewable in the diff and in `repro lint --fixable`.
//!
//! Rule catalog (see DESIGN.md "Static analysis" for the rationale):
//!
//! | rule | invariant it protects |
//! |------|----------------------|
//! | `nondet_iter` | byte-identical partitions: no unordered `HashMap`/`HashSet` in determinism-contract modules |
//! | `panic_in_lib` | panic-safety: no `unwrap`/`expect`/`panic!`/`todo!`/`unreachable!`/`unimplemented!` in library code (a worker panic poisons shared `Mutex`es) |
//! | `spawn_outside_parallel` | all threading goes through `util::parallel`'s ordered fork-join |
//! | `bare_instant` | timing flows through `util::Stopwatch`/`obs` so it stays observable |
//! | `dropped_span_guard` | an `obs::trace` span bound to `_` (or unbound) dies immediately — always a bug |
//! | `undeclared_switch` | every `args.has("x")` switch is declared in `main.rs` `SWITCHES` (closes the `--switch positional` misparse class) |
//! | `undeclared_fault_point` | every `fault::point("x")` is declared in the `FAULT_POINTS` registry (an undeclared point is invisible to plan validation and the chaos sweep) |
//! | `sleep_outside_backoff` | no raw `thread::sleep` outside `fault/` — delays flow through `fault::Backoff` (seeded, metered) or the job queue |
//! | `raw_socket_io` | no `TcpStream`/`TcpListener` outside `net/` and `serve/http.rs` — every other wire byte rides the CRC-checked `LFN1` frame codec and its `net.send`/`net.recv` fault points |
//!
//! To add a rule: implement [`Rule`], add it to [`all_rules`], document
//! it in DESIGN.md, and add one violating + one clean + one suppressed
//! fixture under `tests/lint_fixtures/` (the golden tests iterate the
//! catalog).

use super::lexer::{lex, Comment, Token, TokenKind};
use super::report::{Diagnostic, Report, Suppression};
use crate::error::Result;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// Modules whose outputs are under a byte-identical determinism
/// contract (DESIGN.md "Performance"): partition labels, graph
/// coarsening, subgraph extraction, training batch assembly, the serve
/// ownership index, and the coordinator's result handling.
const DETERMINISM_PREFIXES: &[&str] = &["partition/", "graph/"];
const DETERMINISM_FILES: &[&str] =
    &["serve/index.rs", "train/data.rs", "coordinator/mod.rs", "coordinator/worker.rs"];

/// Macros that abort the surrounding thread.
const PANIC_MACROS: &[&str] = &["panic", "todo", "unimplemented", "unreachable"];

/// Where `Instant::now` may appear bare: the observability layer and
/// the bench harness are the designated owners of wall-clock access.
const INSTANT_EXEMPT_PREFIXES: &[&str] = &["obs/", "benchkit/"];

/// The one module allowed to touch `std::thread` directly.
const THREADING_MODULE: &str = "util/parallel.rs";

/// The one module allowed to call `thread::sleep` directly: `fault/`
/// owns both sanctioned delays (`Backoff::sleep`, injected
/// `delay(ms)` actions). Everything else either backs off through
/// [`crate::fault::Backoff`] or parks on a condvar.
const SLEEP_MODULE_PREFIX: &str = "fault/";

/// The one module allowed to name a raw socket type: `net/` owns the
/// `LFN1` frame codec, and every byte on the wire must pass through it
/// (CRC validation + the `net.send`/`net.recv` fault points).
const NET_MODULE_PREFIX: &str = "net/";

/// The second sanctioned socket owner: the HTTP/1.1 front-end. HTTP is
/// a foreign dialect by definition — it cannot ride the `LFN1` codec —
/// so the file gets a whole-file exemption instead of per-line
/// suppressions; its wire robustness is owned by its own incremental
/// parser (typed errors, slowloris timeouts) and the `http.accept`
/// fault point.
const HTTP_FRONTEND_FILE: &str = "serve/http.rs";

/// One lexed, region-annotated source file.
pub struct SourceFile {
    /// Path relative to the lint root, `/`-separated.
    pub path: String,
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
    /// Inclusive line ranges covered by `#[cfg(test)]` / `#[test]` items.
    test_regions: Vec<(u32, u32)>,
    /// line → `lint: allow` entries: (rule, justification).
    suppressions: BTreeMap<u32, Vec<(String, Option<String>)>>,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str) -> SourceFile {
        let (tokens, comments) = lex(src);
        let test_regions = test_regions(&tokens);
        let suppressions = parse_suppressions(&comments);
        SourceFile { path: path.to_string(), tokens, comments, test_regions, suppressions }
    }

    /// Whether `line` falls inside a `#[cfg(test)]` module or `#[test]`
    /// function — rules skip test code (tests may unwrap freely).
    pub fn in_test_code(&self, line: u32) -> bool {
        self.test_regions.iter().any(|&(a, b)| a <= line && line <= b)
    }

    /// Suppression state for a finding of `rule` at `line`: an allow
    /// comment counts when it sits on the line itself or directly above.
    fn suppression_for(&self, rule: &str, line: u32) -> Suppression {
        for l in [line, line.saturating_sub(1)] {
            if let Some(entries) = self.suppressions.get(&l) {
                for (r, just) in entries {
                    if r == rule {
                        return match just {
                            Some(j) => Suppression::Justified(j.clone()),
                            None => Suppression::MissingJustification,
                        };
                    }
                }
            }
        }
        Suppression::None
    }
}

/// The set of files a lint run covers, in sorted path order.
pub struct FileSet {
    pub files: Vec<SourceFile>,
}

impl FileSet {
    /// Load every `.rs` file under `root` (recursively, sorted), paths
    /// stored relative to `root`.
    pub fn load(root: &Path) -> Result<FileSet> {
        let mut paths = Vec::new();
        collect_rs_files(root, root, &mut paths)?;
        paths.sort();
        let mut files = Vec::with_capacity(paths.len());
        for rel in paths {
            let src = std::fs::read_to_string(root.join(&rel))?;
            files.push(SourceFile::parse(&rel, &src));
        }
        Ok(FileSet { files })
    }

    /// Build a set from in-memory sources — the fixture-test entry point.
    pub fn from_sources(sources: &[(&str, &str)]) -> FileSet {
        FileSet {
            files: sources.iter().map(|(p, s)| SourceFile::parse(p, s)).collect(),
        }
    }
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path.strip_prefix(root).unwrap_or(&path);
            let rel = rel
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Find the index of the delimiter matching `tokens[open]` (which must
/// be `open_text`). Returns `tokens.len() - 1` on unbalanced input so
/// callers always make progress.
fn matching_delim(tokens: &[Token], open: usize, open_text: &str, close_text: &str) -> usize {
    let mut depth = 0usize;
    for (idx, t) in tokens.iter().enumerate().skip(open) {
        if t.text == open_text {
            depth += 1;
        } else if t.text == close_text {
            depth -= 1;
            if depth == 0 {
                return idx;
            }
        }
    }
    tokens.len().saturating_sub(1)
}

/// Line ranges covered by `#[cfg(test)]`- or `#[test]`-attributed items.
/// `#[cfg(not(test))]` is recognised and *not* treated as test code.
fn test_regions(tokens: &[Token]) -> Vec<(u32, u32)> {
    let mut regions = Vec::new();
    let mut i = 0;
    while i < tokens.len() {
        let is_attr_start = tokens[i].text == "#"
            && tokens.get(i + 1).is_some_and(|t| t.text == "[");
        if !is_attr_start {
            i += 1;
            continue;
        }
        let close = matching_delim(tokens, i + 1, "[", "]");
        let inner = &tokens[i + 2..close.max(i + 2)];
        let mut is_test = false;
        for (k, t) in inner.iter().enumerate() {
            if t.kind == TokenKind::Ident && t.text == "test" {
                let negated = k >= 2
                    && inner[k - 2].text == "not"
                    && inner[k - 1].text == "(";
                if !negated {
                    is_test = true;
                    break;
                }
            }
        }
        if !is_test {
            i = close + 1;
            continue;
        }
        let start_line = tokens[i].line;
        // skip any further attributes between this one and the item
        let mut m = close + 1;
        while m + 1 < tokens.len()
            && tokens[m].text == "#"
            && tokens[m + 1].text == "["
        {
            m = matching_delim(tokens, m + 1, "[", "]") + 1;
        }
        // the item body: first `{` at header depth (match to its close),
        // or a `;` for body-less items (`mod tests;`)
        let mut d_paren = 0i32;
        let mut d_brack = 0i32;
        let mut end_line = tokens.last().map(|t| t.line).unwrap_or(start_line);
        while m < tokens.len() {
            match tokens[m].text.as_str() {
                "(" => d_paren += 1,
                ")" => d_paren -= 1,
                "[" => d_brack += 1,
                "]" => d_brack -= 1,
                ";" if d_paren == 0 && d_brack == 0 => {
                    end_line = tokens[m].line;
                    break;
                }
                "{" if d_paren == 0 && d_brack == 0 => {
                    let body_close = matching_delim(tokens, m, "{", "}");
                    end_line = tokens[body_close].line;
                    m = body_close;
                    break;
                }
                _ => {}
            }
            m += 1;
        }
        regions.push((start_line, end_line));
        i = m + 1;
    }
    regions
}

/// Extract `lint: allow(rule, …) — justification` entries per line.
fn parse_suppressions(
    comments: &[Comment],
) -> BTreeMap<u32, Vec<(String, Option<String>)>> {
    const MARKER: &str = "lint: allow(";
    let mut map: BTreeMap<u32, Vec<(String, Option<String>)>> = BTreeMap::new();
    for c in comments {
        let Some(idx) = c.text.find(MARKER) else { continue };
        let rest = &c.text[idx + MARKER.len()..];
        let Some(close) = rest.find(')') else { continue };
        let mut just = rest[close + 1..].trim();
        for sep in ["—", "--", "-", ":"] {
            if let Some(stripped) = just.strip_prefix(sep) {
                just = stripped.trim();
                break;
            }
        }
        let just = if just.is_empty() { None } else { Some(just.to_string()) };
        for rule in rest[..close].split(',') {
            let rule = rule.trim();
            if !rule.is_empty() {
                map.entry(c.line).or_default().push((rule.to_string(), just.clone()));
            }
        }
    }
    map
}

/// A lint rule: a named, documented check over the whole file set.
pub trait Rule {
    fn name(&self) -> &'static str;
    /// One-line description for reports and the DESIGN.md catalog.
    fn summary(&self) -> &'static str;
    fn check(&self, set: &FileSet, out: &mut Vec<Diagnostic>);
}

/// The full rule set, in catalog order.
pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(NondetIter),
        Box::new(PanicInLib),
        Box::new(SpawnOutsideParallel),
        Box::new(BareInstant),
        Box::new(DroppedSpanGuard),
        Box::new(UndeclaredSwitch),
        Box::new(UndeclaredFaultPoint),
        Box::new(SleepOutsideBackoff),
        Box::new(RawSocketIo),
    ]
}

/// Run every rule over `set` and assemble the sorted report.
pub fn run_rules(set: &FileSet) -> Report {
    let mut out = Vec::new();
    for rule in all_rules() {
        rule.check(set, &mut out);
    }
    Report::new(out, set.files.len())
}

/// Emit at most one diagnostic per (rule, line) per file, resolving the
/// suppression state from the file's `lint: allow` comments.
fn emit(
    file: &SourceFile,
    rule: &'static str,
    line: u32,
    message: String,
    seen: &mut BTreeSet<u32>,
    out: &mut Vec<Diagnostic>,
) {
    if !seen.insert(line) {
        return;
    }
    out.push(Diagnostic {
        rule,
        file: file.path.clone(),
        line,
        message,
        suppression: file.suppression_for(rule, line),
    });
}

fn is_determinism_module(path: &str) -> bool {
    DETERMINISM_PREFIXES.iter().any(|p| path.starts_with(p))
        || DETERMINISM_FILES.contains(&path)
}

// ---- nondet_iter ----------------------------------------------------------

/// Unordered containers are banned from determinism-contract modules:
/// one `HashMap` iteration in a partition kernel silently breaks the
/// byte-identical-across-thread-counts contract. Ordered accumulation
/// (integer sums, membership tests) is legitimate — and must say so via
/// a justified suppression, which is the point: every unordered
/// container in the contract modules is visible and reviewed.
struct NondetIter;

impl Rule for NondetIter {
    fn name(&self) -> &'static str {
        "nondet_iter"
    }

    fn summary(&self) -> &'static str {
        "no unordered HashMap/HashSet in determinism-contract modules"
    }

    fn check(&self, set: &FileSet, out: &mut Vec<Diagnostic>) {
        for file in &set.files {
            if !is_determinism_module(&file.path) {
                continue;
            }
            let mut seen = BTreeSet::new();
            for t in &file.tokens {
                if t.kind == TokenKind::Ident
                    && (t.text == "HashMap" || t.text == "HashSet")
                    && !file.in_test_code(t.line)
                {
                    emit(
                        file,
                        self.name(),
                        t.line,
                        format!(
                            "unordered {} in determinism-contract module \
                             (iteration order varies run to run)",
                            t.text
                        ),
                        &mut seen,
                        out,
                    );
                }
            }
        }
    }
}

// ---- panic_in_lib ---------------------------------------------------------

/// `unwrap`/`expect`/`panic!`-family calls in library code: a panic in
/// a coordinator worker or serve thread poisons every `Mutex` it holds
/// and cascades. Library code propagates `Error` instead; provably
/// infallible uses carry a justified suppression stating the invariant.
struct PanicInLib;

impl Rule for PanicInLib {
    fn name(&self) -> &'static str {
        "panic_in_lib"
    }

    fn summary(&self) -> &'static str {
        "no unwrap/expect/panic!/todo! in non-test library code"
    }

    fn check(&self, set: &FileSet, out: &mut Vec<Diagnostic>) {
        for file in &set.files {
            let mut seen = BTreeSet::new();
            let toks = &file.tokens;
            for (i, t) in toks.iter().enumerate() {
                if t.kind != TokenKind::Ident || file.in_test_code(t.line) {
                    continue;
                }
                let name = t.text.as_str();
                if name == "unwrap" || name == "expect" {
                    let is_method_call = i > 0
                        && toks[i - 1].text == "."
                        && toks.get(i + 1).is_some_and(|n| n.text == "(");
                    if is_method_call {
                        emit(
                            file,
                            self.name(),
                            t.line,
                            format!(".{name}() can panic in library code"),
                            &mut seen,
                            out,
                        );
                    }
                } else if PANIC_MACROS.contains(&name)
                    && toks.get(i + 1).is_some_and(|n| n.text == "!")
                {
                    emit(
                        file,
                        self.name(),
                        t.line,
                        format!("{name}! aborts the surrounding thread in library code"),
                        &mut seen,
                        out,
                    );
                }
            }
        }
    }
}

// ---- spawn_outside_parallel -----------------------------------------------

/// Direct `std::thread` use outside `util::parallel`: the fork-join
/// helper is where the ordered-reduction determinism argument lives,
/// so ad-hoc threading elsewhere needs an explicit, justified opt-out
/// (e.g. the coordinator's long-lived worker topology).
struct SpawnOutsideParallel;

impl Rule for SpawnOutsideParallel {
    fn name(&self) -> &'static str {
        "spawn_outside_parallel"
    }

    fn summary(&self) -> &'static str {
        "all threading goes through util::parallel"
    }

    fn check(&self, set: &FileSet, out: &mut Vec<Diagnostic>) {
        for file in &set.files {
            if file.path == THREADING_MODULE {
                continue;
            }
            let mut seen = BTreeSet::new();
            let toks = &file.tokens;
            for (i, t) in toks.iter().enumerate() {
                let hit = t.kind == TokenKind::Ident
                    && t.text == "thread"
                    && toks.get(i + 1).is_some_and(|n| n.text == "::")
                    && toks.get(i + 2).is_some_and(|n| {
                        matches!(n.text.as_str(), "spawn" | "scope" | "Builder")
                    });
                if hit && !file.in_test_code(t.line) {
                    let what = toks[i + 2].text.clone();
                    emit(
                        file,
                        self.name(),
                        t.line,
                        format!("thread::{what} outside util::parallel"),
                        &mut seen,
                        out,
                    );
                }
            }
        }
    }
}

// ---- bare_instant ---------------------------------------------------------

/// `Instant::now` in kernels bypasses `util::Stopwatch` and the PR 6
/// observability registry — timings taken this way never reach traces
/// or metrics. Only `obs/` and `benchkit/` own the clock.
struct BareInstant;

impl Rule for BareInstant {
    fn name(&self) -> &'static str {
        "bare_instant"
    }

    fn summary(&self) -> &'static str {
        "no bare Instant::now outside obs/ and benchkit/"
    }

    fn check(&self, set: &FileSet, out: &mut Vec<Diagnostic>) {
        for file in &set.files {
            if INSTANT_EXEMPT_PREFIXES.iter().any(|p| file.path.starts_with(p)) {
                continue;
            }
            let mut seen = BTreeSet::new();
            let toks = &file.tokens;
            for (i, t) in toks.iter().enumerate() {
                let hit = t.kind == TokenKind::Ident
                    && t.text == "Instant"
                    && toks.get(i + 1).is_some_and(|n| n.text == "::")
                    && toks.get(i + 2).is_some_and(|n| n.text == "now");
                if hit && !file.in_test_code(t.line) {
                    emit(
                        file,
                        self.name(),
                        t.line,
                        "bare Instant::now — time through util::Stopwatch / obs \
                         so the reading stays observable"
                            .to_string(),
                        &mut seen,
                        out,
                    );
                }
            }
        }
    }
}

// ---- dropped_span_guard ---------------------------------------------------

/// An `obs::trace` span is an RAII guard: binding it to `_` (or not
/// binding it at all) drops it on the same statement, recording a
/// zero-length span. Always a bug — bind to `_span` or a named guard.
struct DroppedSpanGuard;

impl Rule for DroppedSpanGuard {
    fn name(&self) -> &'static str {
        "dropped_span_guard"
    }

    fn summary(&self) -> &'static str {
        "span guards must outlive their statement"
    }

    fn check(&self, set: &FileSet, out: &mut Vec<Diagnostic>) {
        for file in &set.files {
            let mut seen = BTreeSet::new();
            let toks = &file.tokens;
            for (i, t) in toks.iter().enumerate() {
                let is_call = t.kind == TokenKind::Ident
                    && t.text == "span"
                    && toks.get(i + 1).is_some_and(|n| n.text == "(");
                if !is_call || file.in_test_code(t.line) {
                    continue;
                }
                // walk back over a `path::` prefix (obs::span, trace::span)
                let mut j = i;
                while j >= 2
                    && toks[j - 1].text == "::"
                    && toks[j - 2].kind == TokenKind::Ident
                {
                    j -= 2;
                }
                let prev = j.checked_sub(1).map(|p| toks[p].text.as_str());
                let bound_to_underscore = prev == Some("=")
                    && j >= 3
                    && toks[j - 2].text == "_"
                    && toks[j - 3].text == "let";
                if bound_to_underscore {
                    emit(
                        file,
                        self.name(),
                        t.line,
                        "span guard bound to _ is dropped immediately \
                         (bind to _span or a named guard)"
                            .to_string(),
                        &mut seen,
                        out,
                    );
                    continue;
                }
                let statement_position =
                    matches!(prev, None | Some(";") | Some("{") | Some("}"));
                if statement_position && call_is_discarded(toks, i + 1) {
                    emit(
                        file,
                        self.name(),
                        t.line,
                        "unbound span guard is dropped at the end of its own \
                         statement"
                            .to_string(),
                        &mut seen,
                        out,
                    );
                }
            }
        }
    }
}

/// With `tokens[open]` the `(` of a call, determine whether the whole
/// expression — including any chained `.method(…)` calls — is
/// terminated by `;` (i.e. its value is discarded).
fn call_is_discarded(tokens: &[Token], open: usize) -> bool {
    let mut p = matching_delim(tokens, open, "(", ")") + 1;
    while p + 1 < tokens.len()
        && tokens[p].text == "."
        && tokens[p + 1].kind == TokenKind::Ident
    {
        p += 2;
        if tokens.get(p).is_some_and(|t| t.text == "(") {
            p = matching_delim(tokens, p, "(", ")") + 1;
        }
    }
    tokens.get(p).is_some_and(|t| t.text == ";")
}

// ---- undeclared_switch ----------------------------------------------------

/// Every switch queried via `args.has("x")` must be listed in the
/// `SWITCHES` registry in `main.rs` — an undeclared switch silently
/// swallows the next CLI token as its value (the PR 1 misparse class).
/// Inert when the file set has no `main.rs` with a `SWITCHES` const.
struct UndeclaredSwitch;

impl Rule for UndeclaredSwitch {
    fn name(&self) -> &'static str {
        "undeclared_switch"
    }

    fn summary(&self) -> &'static str {
        "every args.has(name) appears in main.rs SWITCHES"
    }

    fn check(&self, set: &FileSet, out: &mut Vec<Diagnostic>) {
        let Some(declared) = declared_switches(set) else { return };
        for file in &set.files {
            let mut seen = BTreeSet::new();
            let toks = &file.tokens;
            for (i, t) in toks.iter().enumerate() {
                let is_has_call = t.kind == TokenKind::Ident
                    && t.text == "has"
                    && i >= 1
                    && toks[i - 1].text == "."
                    && toks.get(i + 1).is_some_and(|n| n.text == "(")
                    && toks.get(i + 2).is_some_and(|n| n.kind == TokenKind::Str);
                if !is_has_call || file.in_test_code(t.line) {
                    continue;
                }
                let name = toks[i + 2].str_value().to_string();
                if !declared.contains(&name) {
                    emit(
                        file,
                        self.name(),
                        t.line,
                        format!(
                            "switch {name:?} queried but not declared in \
                             main.rs SWITCHES (undeclared switches swallow \
                             the next CLI token)"
                        ),
                        &mut seen,
                        out,
                    );
                }
            }
        }
    }
}

/// Parse the string literals of `const SWITCHES: … = &[…];` in
/// `main.rs`. `None` when no such registry exists in the set.
fn declared_switches(set: &FileSet) -> Option<BTreeSet<String>> {
    let main = set
        .files
        .iter()
        .find(|f| f.path == "main.rs" || f.path.ends_with("/main.rs"))?;
    let toks = &main.tokens;
    let at = toks
        .iter()
        .position(|t| t.kind == TokenKind::Ident && t.text == "SWITCHES")?;
    // skip the type annotation: the initializer list is the first `[`
    // after the `=`
    let eq = toks[at..].iter().position(|t| t.text == "=")? + at;
    let open = toks[eq..].iter().position(|t| t.text == "[")? + eq;
    let close = matching_delim(toks, open, "[", "]");
    let mut names = BTreeSet::new();
    for t in &toks[open + 1..close] {
        if t.kind == TokenKind::Str {
            names.insert(t.str_value().to_string());
        }
    }
    Some(names)
}

// ---- undeclared_fault_point -----------------------------------------------

/// Every `fault::point("x")` call site must name a point listed in the
/// `FAULT_POINTS` registry (`fault/mod.rs`): plan validation and the
/// nightly chaos sweep iterate that const, so an undeclared point is
/// injectable by accident yet invisible to `--fault-plan` validation
/// and never exercised by CI. Inert when the file set carries no
/// registry (fixture sets, other codebases).
struct UndeclaredFaultPoint;

impl Rule for UndeclaredFaultPoint {
    fn name(&self) -> &'static str {
        "undeclared_fault_point"
    }

    fn summary(&self) -> &'static str {
        "every fault::point(name) appears in the FAULT_POINTS registry"
    }

    fn check(&self, set: &FileSet, out: &mut Vec<Diagnostic>) {
        let Some(declared) = declared_fault_points(set) else { return };
        for file in &set.files {
            let mut seen = BTreeSet::new();
            let toks = &file.tokens;
            for (i, t) in toks.iter().enumerate() {
                let is_point_call = t.kind == TokenKind::Ident
                    && t.text == "point"
                    && i >= 2
                    && toks[i - 1].text == "::"
                    && toks[i - 2].text == "fault"
                    && toks.get(i + 1).is_some_and(|n| n.text == "(")
                    && toks.get(i + 2).is_some_and(|n| n.kind == TokenKind::Str);
                if !is_point_call || file.in_test_code(t.line) {
                    continue;
                }
                let name = toks[i + 2].str_value().to_string();
                if !declared.contains(&name) {
                    emit(
                        file,
                        self.name(),
                        t.line,
                        format!(
                            "fault point {name:?} is not declared in FAULT_POINTS \
                             (plan validation and the chaos sweep cannot see it)"
                        ),
                        &mut seen,
                        out,
                    );
                }
            }
        }
    }
}

/// Parse the string literals of `const FAULT_POINTS: … = &[…];`
/// wherever it lives in the set. `None` when no registry exists.
fn declared_fault_points(set: &FileSet) -> Option<BTreeSet<String>> {
    for file in &set.files {
        let toks = &file.tokens;
        let Some(at) = toks
            .iter()
            .position(|t| t.kind == TokenKind::Ident && t.text == "FAULT_POINTS")
        else {
            continue;
        };
        // the declaration site (preceded by `const`), not a use site
        if !(at >= 1 && toks[at - 1].text == "const") {
            continue;
        }
        let eq = toks[at..].iter().position(|t| t.text == "=")? + at;
        let open = toks[eq..].iter().position(|t| t.text == "[")? + eq;
        let close = matching_delim(toks, open, "[", "]");
        let mut names = BTreeSet::new();
        for t in &toks[open + 1..close] {
            if t.kind == TokenKind::Str {
                names.insert(t.str_value().to_string());
            }
        }
        return Some(names);
    }
    None
}

// ---- sleep_outside_backoff ------------------------------------------------

/// Raw `thread::sleep` outside `fault/` is either an unmetered retry
/// delay (belongs in [`crate::fault::Backoff`], where it is seeded,
/// bounded, and recorded in `coordinator.backoff_secs`) or a disguised
/// busy-wait (belongs on a condvar, like the coordinator's job queue).
/// Either way the duration is invisible to observability and to the
/// determinism argument, so the pattern needs a justified opt-out.
struct SleepOutsideBackoff;

impl Rule for SleepOutsideBackoff {
    fn name(&self) -> &'static str {
        "sleep_outside_backoff"
    }

    fn summary(&self) -> &'static str {
        "no raw thread::sleep outside fault/ (use Backoff or a condvar)"
    }

    fn check(&self, set: &FileSet, out: &mut Vec<Diagnostic>) {
        for file in &set.files {
            if file.path.starts_with(SLEEP_MODULE_PREFIX) {
                continue;
            }
            let mut seen = BTreeSet::new();
            let toks = &file.tokens;
            for (i, t) in toks.iter().enumerate() {
                let hit = t.kind == TokenKind::Ident
                    && t.text == "thread"
                    && toks.get(i + 1).is_some_and(|n| n.text == "::")
                    && toks.get(i + 2).is_some_and(|n| n.text == "sleep");
                if hit && !file.in_test_code(t.line) {
                    emit(
                        file,
                        self.name(),
                        t.line,
                        "raw thread::sleep — back off through fault::Backoff \
                         (seeded + metered) or wait on a condvar"
                            .to_string(),
                        &mut seen,
                        out,
                    );
                }
            }
        }
    }
}

// ---- raw_socket_io --------------------------------------------------------

/// Socket I/O outside `net/` bypasses the `LFN1` frame codec: bytes
/// that never pass a CRC, `net.send`/`net.recv` fault points that never
/// fire, and a second wire dialect nobody versioned. Anything that
/// needs the network speaks typed `net::Message`s over `net::frame`;
/// only `net/` itself may name a socket type.
struct RawSocketIo;

impl Rule for RawSocketIo {
    fn name(&self) -> &'static str {
        "raw_socket_io"
    }

    fn summary(&self) -> &'static str {
        "no TcpStream/TcpListener outside net/ and serve/http.rs (all other socket I/O rides the frame codec)"
    }

    fn check(&self, set: &FileSet, out: &mut Vec<Diagnostic>) {
        for file in &set.files {
            if file.path.starts_with(NET_MODULE_PREFIX) || file.path == HTTP_FRONTEND_FILE {
                continue;
            }
            let mut seen = BTreeSet::new();
            for t in &file.tokens {
                let hit = t.kind == TokenKind::Ident
                    && (t.text == "TcpStream" || t.text == "TcpListener");
                if hit && !file.in_test_code(t.line) {
                    emit(
                        file,
                        self.name(),
                        t.line,
                        format!(
                            "raw socket type {} — speak LFN1 frames through net::frame \
                             (checksummed, fault-injectable) instead",
                            t.text
                        ),
                        &mut seen,
                        out,
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(path: &str, src: &str) -> Report {
        run_rules(&FileSet::from_sources(&[(path, src)]))
    }

    fn rules_hit(report: &Report) -> Vec<&'static str> {
        report.unannotated().map(|d| d.rule).collect()
    }

    #[test]
    fn catalog_names_are_unique_and_documented() {
        let rules = all_rules();
        let names: BTreeSet<&str> = rules.iter().map(|r| r.name()).collect();
        assert_eq!(names.len(), rules.len());
        for r in &rules {
            assert!(!r.summary().is_empty(), "{} lacks a summary", r.name());
        }
    }

    #[test]
    fn nondet_iter_only_fires_in_contract_modules() {
        let src = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, u32> = HashMap::new(); }\n";
        let hit = lint_one("partition/leiden.rs", src);
        assert_eq!(rules_hit(&hit), vec!["nondet_iter", "nondet_iter"]);
        let clean = lint_one("cli/mod.rs", src);
        assert!(rules_hit(&clean).is_empty());
    }

    #[test]
    fn nondet_iter_skips_test_modules() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn oracle() { let _m: HashMap<u32, u32> = HashMap::new(); }\n}\n";
        assert!(rules_hit(&lint_one("graph/csr.rs", src)).is_empty());
    }

    #[test]
    fn panic_in_lib_flags_methods_and_macros() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    let a = x.unwrap();\n    let b = x.expect(\"msg\");\n    if a > b { panic!(\"boom\"); }\n    todo!()\n}\n";
        let report = lint_one("train/trainer.rs", src);
        assert_eq!(report.unannotated_count(), 4);
    }

    #[test]
    fn panic_in_lib_ignores_unwrap_or_variants_and_strings() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    let s = \"call .unwrap() later\";\n    let _ = s;\n    x.unwrap_or_else(|| 0).max(x.unwrap_or(1)).max(x.unwrap_or_default())\n}\n";
        assert!(rules_hit(&lint_one("train/trainer.rs", src)).is_empty());
    }

    #[test]
    fn panic_in_lib_skips_test_fns_and_modules() {
        let src = "#[test]\nfn t() { None::<u32>.unwrap(); }\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn u() { panic!(\"fine in tests\"); }\n}\n";
        assert!(rules_hit(&lint_one("serve/engine.rs", src)).is_empty());
    }

    #[test]
    fn cfg_not_test_is_still_library_code() {
        let src = "#[cfg(not(test))]\nfn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert_eq!(rules_hit(&lint_one("serve/engine.rs", src)), vec!["panic_in_lib"]);
    }

    #[test]
    fn spawn_rule_exempts_the_parallel_module() {
        let src = "fn go() { std::thread::spawn(|| {}); }\n";
        assert_eq!(
            rules_hit(&lint_one("serve/engine.rs", src)),
            vec!["spawn_outside_parallel"]
        );
        assert!(rules_hit(&lint_one("util/parallel.rs", src)).is_empty());
    }

    #[test]
    fn spawn_rule_covers_scope_and_builder() {
        let src = "fn go() { std::thread::scope(|s| { let _ = s; }); }\nfn b() { let _ = std::thread::Builder::new(); }\n";
        assert_eq!(
            rules_hit(&lint_one("coordinator/mod.rs", src)),
            vec!["spawn_outside_parallel", "spawn_outside_parallel"]
        );
    }

    #[test]
    fn bare_instant_exempts_obs_and_benchkit() {
        let src = "fn t() { let _now = std::time::Instant::now(); }\n";
        assert_eq!(rules_hit(&lint_one("runtime/client.rs", src)), vec!["bare_instant"]);
        assert!(rules_hit(&lint_one("obs/trace.rs", src)).is_empty());
        assert!(rules_hit(&lint_one("benchkit/mod.rs", src)).is_empty());
    }

    #[test]
    fn dropped_span_guard_flags_underscore_and_unbound() {
        let src = "fn f() {\n    let _ = obs::span(\"cat\", \"dead\");\n    obs::span(\"cat\", \"also dead\");\n    obs::span(\"cat\", \"chained\").with(\"k\", num(1.0));\n}\n";
        let report = lint_one("coordinator/mod.rs", src);
        assert_eq!(report.unannotated_count(), 3);
    }

    #[test]
    fn dropped_span_guard_accepts_live_bindings() {
        let src = "fn f() -> Span {\n    let _sp = obs::span(\"cat\", \"live\");\n    let mut named = obs::span(\"cat\", \"live2\");\n    named.attr(\"k\", num(1.0));\n    drop(_sp);\n    span(\"cat\", \"returned\")\n}\n";
        assert!(rules_hit(&lint_one("coordinator/mod.rs", src)).is_empty());
    }

    #[test]
    fn undeclared_switch_checks_against_main_registry() {
        let main = "const SWITCHES: &[&str] = &[\"help\", \"warm\"];\nfn f(args: &Args) { let _ = args.has(\"help\"); let _ = args.has(\"verbose\"); }\n";
        let report = run_rules(&FileSet::from_sources(&[("main.rs", main)]));
        let hits: Vec<_> = report.unannotated().collect();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "undeclared_switch");
        assert!(hits[0].message.contains("verbose"));
    }

    #[test]
    fn undeclared_switch_inert_without_a_registry() {
        let src = "fn f(args: &Args) { let _ = args.has(\"anything\"); }\n";
        assert!(rules_hit(&lint_one("coordinator/mod.rs", src)).is_empty());
    }

    #[test]
    fn undeclared_fault_point_checks_against_registry() {
        let registry = "pub const FAULT_POINTS: &[&str] = &[\"worker.train\", \"shard.read\"];\n";
        let user = "fn f() {\n    let _ = fault::point(\"worker.train\").fire();\n    let _ = fault::point(\"worker.trian\").fire();\n}\n";
        let report = run_rules(&FileSet::from_sources(&[
            ("fault/mod.rs", registry),
            ("coordinator/worker.rs", user),
        ]));
        let hits: Vec<_> = report
            .unannotated()
            .filter(|d| d.rule == "undeclared_fault_point")
            .collect();
        assert_eq!(hits.len(), 1);
        assert!(hits[0].message.contains("worker.trian"));
    }

    #[test]
    fn undeclared_fault_point_inert_without_registry_and_in_tests() {
        let user = "fn f() { let _ = fault::point(\"anything\").fire(); }\n";
        assert!(rules_hit(&lint_one("coordinator/worker.rs", user)).is_empty());
        let registry = "pub const FAULT_POINTS: &[&str] = &[\"worker.train\"];\n";
        let test_user = "#[cfg(test)]\nmod tests {\n    fn t() { let _ = fault::point(\"test.synthetic\").fire(); }\n}\n";
        let report = run_rules(&FileSet::from_sources(&[
            ("fault/mod.rs", registry),
            ("serve/shard.rs", test_user),
        ]));
        assert_eq!(report.unannotated_count(), 0, "test regions are exempt");
    }

    #[test]
    fn sleep_rule_exempts_fault_module_and_tests() {
        let src = "fn f() { std::thread::sleep(std::time::Duration::from_millis(5)); }\n";
        assert!(rules_hit(&lint_one("coordinator/worker.rs", src))
            .contains(&"sleep_outside_backoff"));
        assert!(rules_hit(&lint_one("fault/backoff.rs", src)).is_empty());
        let test_src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { std::thread::sleep(std::time::Duration::from_millis(5)); }\n}\n";
        assert!(rules_hit(&lint_one("serve/cache.rs", test_src)).is_empty());
    }

    #[test]
    fn raw_socket_io_fires_outside_net_only() {
        let src = "use std::net::TcpStream;\nfn f(addr: &str) {\n    let _s = TcpStream::connect(addr);\n}\n";
        assert_eq!(
            rules_hit(&lint_one("serve/transport.rs", src)),
            vec!["raw_socket_io", "raw_socket_io"]
        );
        assert!(rules_hit(&lint_one("net/frame.rs", src)).is_empty());
        assert!(rules_hit(&lint_one("net/server.rs", src)).is_empty());
    }

    #[test]
    fn raw_socket_io_exempts_the_http_frontend_file_only() {
        let src = "use std::net::{TcpListener, TcpStream};\nfn f() {\n    let _l = TcpListener::bind(\"127.0.0.1:0\");\n}\n";
        assert!(rules_hit(&lint_one("serve/http.rs", src)).is_empty());
        // the exemption is the exact file, not the serve/ directory
        assert!(rules_hit(&lint_one("serve/http2.rs", src)).contains(&"raw_socket_io"));
        assert!(rules_hit(&lint_one("serve/engine.rs", src)).contains(&"raw_socket_io"));
    }

    #[test]
    fn raw_socket_io_flags_listener_and_skips_tests() {
        let src = "fn f() { let _l = std::net::TcpListener::bind(\"127.0.0.1:0\"); }\n";
        assert_eq!(rules_hit(&lint_one("coordinator/mod.rs", src)), vec!["raw_socket_io"]);
        let test_src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { let _l = std::net::TcpListener::bind(\"127.0.0.1:0\"); }\n}\n";
        assert!(rules_hit(&lint_one("coordinator/mod.rs", test_src)).is_empty());
    }

    #[test]
    fn suppression_with_justification_downgrades() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(panic_in_lib) — checked non-empty two lines up\n    x.unwrap()\n}\n";
        let report = lint_one("train/trainer.rs", src);
        assert_eq!(report.unannotated_count(), 0);
        assert_eq!(report.suppressed_count(), 1);
    }

    #[test]
    fn suppression_on_same_line_works() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap() // lint: allow(panic_in_lib) — infallible: len checked\n}\n";
        let report = lint_one("train/trainer.rs", src);
        assert_eq!(report.unannotated_count(), 0);
        assert_eq!(report.suppressed_count(), 1);
    }

    #[test]
    fn suppression_without_justification_still_fails() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(panic_in_lib)\n    x.unwrap()\n}\n";
        let report = lint_one("train/trainer.rs", src);
        assert_eq!(report.unannotated_count(), 1);
        assert!(matches!(
            report.diagnostics[0].suppression,
            Suppression::MissingJustification
        ));
    }

    #[test]
    fn suppression_for_wrong_rule_does_not_apply() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(nondet_iter) — wrong rule\n    x.unwrap()\n}\n";
        assert_eq!(lint_one("train/trainer.rs", src).unannotated_count(), 1);
    }

    #[test]
    fn suppression_list_covers_multiple_rules() {
        let src = "fn f() {\n    // lint: allow(panic_in_lib, bare_instant) — startup-only path\n    let _t = std::time::Instant::now(); panic!(\"x\");\n}\n";
        let report = lint_one("runtime/client.rs", src);
        // the comment is on the line above both findings
        assert_eq!(report.unannotated_count(), 0);
        assert_eq!(report.suppressed_count(), 2);
    }
}
