//! Lint diagnostics and report rendering (human, JSON, `--fixable`).
//!
//! A [`Diagnostic`] is one finding: rule, file, line, message, and its
//! suppression state. Suppression is per-line via the inline comment
//! syntax
//!
//! ```text
//! // lint: allow(<rule>) — <justification>
//! ```
//!
//! placed on the violating line or the line directly above it. The
//! justification is **required**: an `allow` without one downgrades
//! nothing — it surfaces as an unannotated violation of its own, so
//! every exception in the tree stays visible and explained. Suppressed
//! findings are still recorded (and listed by `repro lint --fixable`)
//! so future PRs can triage and burn them down.

use crate::util::json::{num, obj, Json};
use std::fmt::Write as _;

/// Suppression state of one diagnostic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Suppression {
    /// No `lint: allow` comment covers the line: a hard violation.
    None,
    /// Covered by an `allow` with a justification: recorded, not fatal.
    Justified(String),
    /// Covered by an `allow` **without** a justification — treated as a
    /// violation so silent exceptions cannot accumulate.
    MissingJustification,
}

/// One lint finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub rule: &'static str,
    /// Path relative to the lint root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: u32,
    pub message: String,
    pub suppression: Suppression,
}

impl Diagnostic {
    /// Whether this finding fails the build.
    pub fn is_unannotated(&self) -> bool {
        !matches!(self.suppression, Suppression::Justified(_))
    }
}

/// The result of a lint run over a file set.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// All findings, sorted by (file, line, rule).
    pub diagnostics: Vec<Diagnostic>,
    pub files_scanned: usize,
}

impl Report {
    pub fn new(mut diagnostics: Vec<Diagnostic>, files_scanned: usize) -> Report {
        diagnostics.sort_by(|a, b| {
            (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
        });
        Report { diagnostics, files_scanned }
    }

    /// Findings that fail the build (no justified suppression).
    pub fn unannotated(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.is_unannotated())
    }

    /// Findings excused by a justified `lint: allow`.
    pub fn suppressed(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| !d.is_unannotated())
    }

    pub fn unannotated_count(&self) -> usize {
        self.unannotated().count()
    }

    pub fn suppressed_count(&self) -> usize {
        self.suppressed().count()
    }

    /// Machine-readable report (the `LINT.json` CI artifact).
    pub fn to_json(&self) -> Json {
        let diags = self
            .diagnostics
            .iter()
            .map(|d| {
                let mut pairs = vec![
                    ("rule", Json::Str(d.rule.to_string())),
                    ("file", Json::Str(d.file.clone())),
                    ("line", num(d.line as f64)),
                    ("message", Json::Str(d.message.clone())),
                    ("suppressed", Json::Bool(!d.is_unannotated())),
                ];
                match &d.suppression {
                    Suppression::Justified(j) => {
                        pairs.push(("justification", Json::Str(j.clone())));
                    }
                    Suppression::MissingJustification => {
                        pairs.push(("justification", Json::Null));
                    }
                    Suppression::None => {}
                }
                obj(pairs)
            })
            .collect();
        obj(vec![
            ("version", num(1.0)),
            ("files_scanned", num(self.files_scanned as f64)),
            ("violations", num(self.unannotated_count() as f64)),
            ("suppressed", num(self.suppressed_count() as f64)),
            ("diagnostics", Json::Arr(diags)),
        ])
    }

    /// Compiler-style listing of the findings that fail the build.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for d in self.unannotated() {
            let note = match d.suppression {
                Suppression::MissingJustification => {
                    " (suppression present but missing a justification)"
                }
                _ => "",
            };
            let _ = writeln!(
                out,
                "lint[{}] {}:{}: {}{}",
                d.rule, d.file, d.line, d.message, note
            );
        }
        let _ = writeln!(
            out,
            "lint: {} file(s), {} violation(s), {} suppressed",
            self.files_scanned,
            self.unannotated_count(),
            self.suppressed_count()
        );
        out
    }

    /// `--fixable` triage listing: every justified suppression, with its
    /// justification, so future PRs can burn annotated exceptions down.
    pub fn render_fixable(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "annotated suppressions ({}):", self.suppressed_count());
        for d in self.suppressed() {
            let just = match &d.suppression {
                Suppression::Justified(j) => j.as_str(),
                _ => "",
            };
            let _ = writeln!(out, "  [{}] {}:{} — {}", d.rule, d.file, d.line, just);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(rule: &'static str, file: &str, line: u32, sup: Suppression) -> Diagnostic {
        Diagnostic {
            rule,
            file: file.to_string(),
            line,
            message: format!("{rule} violated"),
            suppression: sup,
        }
    }

    #[test]
    fn sorting_and_counts() {
        let r = Report::new(
            vec![
                diag("b_rule", "z.rs", 9, Suppression::None),
                diag("a_rule", "a.rs", 5, Suppression::Justified("ok".into())),
                diag("a_rule", "a.rs", 2, Suppression::None),
            ],
            3,
        );
        assert_eq!(r.diagnostics[0].line, 2);
        assert_eq!(r.diagnostics[2].file, "z.rs");
        assert_eq!(r.unannotated_count(), 2);
        assert_eq!(r.suppressed_count(), 1);
    }

    #[test]
    fn missing_justification_counts_as_violation() {
        let r = Report::new(
            vec![diag("a_rule", "a.rs", 1, Suppression::MissingJustification)],
            1,
        );
        assert_eq!(r.unannotated_count(), 1);
        assert!(r.render_human().contains("missing a justification"));
    }

    #[test]
    fn json_report_round_trips() {
        let r = Report::new(
            vec![
                diag("a_rule", "a.rs", 3, Suppression::None),
                diag("b_rule", "b.rs", 7, Suppression::Justified("reviewed".into())),
            ],
            2,
        );
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("violations").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("suppressed").unwrap().as_usize(), Some(1));
        let diags = parsed.get("diagnostics").unwrap().as_arr().unwrap();
        assert_eq!(diags.len(), 2);
        assert_eq!(diags[0].get("rule").unwrap().as_str(), Some("a_rule"));
        assert_eq!(diags[1].get("suppressed").unwrap().as_bool(), Some(true));
        assert_eq!(diags[1].get("justification").unwrap().as_str(), Some("reviewed"));
    }

    #[test]
    fn fixable_lists_only_suppressed() {
        let r = Report::new(
            vec![
                diag("a_rule", "a.rs", 3, Suppression::None),
                diag("b_rule", "b.rs", 7, Suppression::Justified("oracle only".into())),
            ],
            2,
        );
        let fixable = r.render_fixable();
        assert!(fixable.contains("b.rs:7"));
        assert!(fixable.contains("oracle only"));
        assert!(!fixable.contains("a.rs:3"));
    }
}
