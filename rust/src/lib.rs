//! # leiden-fusion
//!
//! Production-grade reproduction of *"Leiden-Fusion Partitioning Method for
//! Effective Distributed Training of Graph Embeddings"* (Bai, Constantin,
//! Naacke, 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — graph substrate, the Leiden-Fusion partitioner
//!   and all baselines, the communication-free distributed training
//!   coordinator, and the PJRT runtime that executes AOT-compiled models.
//! * **L2/L1 (python/, build-time only)** — JAX GCN/GraphSAGE/MLP models on
//!   Pallas kernels, lowered once to `artifacts/*.hlo.txt`.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod graph;
pub mod partition;
pub mod runtime;
pub mod testing;
pub mod train;
pub mod util;

pub use error::{Error, Result};
