//! # leiden-fusion
//!
//! Production-grade reproduction of *"Leiden-Fusion Partitioning Method for
//! Effective Distributed Training of Graph Embeddings"* (Bai, Constantin,
//! Naacke, 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — graph substrate, the Leiden-Fusion partitioner
//!   and all baselines, the communication-free distributed training
//!   coordinator, the PJRT runtime that executes AOT-compiled models, and
//!   the embedding **serving layer** ([`serve`]): `LFS1` per-partition
//!   shards written by the coordinator, a lazily-loading
//!   [`serve::ShardedEmbeddingStore`], and a batched, cached query
//!   [`serve::Engine`] answering node-classification requests through the
//!   trained integration MLP — all instrumented by the [`obs`]
//!   observability layer (tracing spans + a metrics registry).
//! * **L2/L1 (python/, build-time only)** — JAX GCN/GraphSAGE/MLP models on
//!   Pallas kernels, lowered once to `artifacts/*.hlo.txt`.
//!
//! See `DESIGN.md` for the system inventory (including the shard format,
//! the query path under *Serving*, and the partitioning spec grammar
//! under *Partitioning*) and `EXPERIMENTS.md` for the paper-vs-measured
//! results.

// Style lints that fight the index-driven numeric idiom used throughout
// (CSR arrays are addressed by node id, `Option::map_or(true, …)` reads
// as the tri-state it models); correctness lints stay enabled and CI
// runs `clippy --all-targets -- -D warnings`.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::unnecessary_map_or)]

pub mod analysis;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod fault;
pub mod graph;
pub mod net;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod serve;
pub mod testing;
pub mod train;
pub mod util;

pub use error::{Error, Result};
