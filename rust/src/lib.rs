//! # leiden-fusion
//!
//! Production-grade reproduction of *"Leiden-Fusion Partitioning Method for
//! Effective Distributed Training of Graph Embeddings"* (Bai, Constantin,
//! Naacke, 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — graph substrate, the Leiden-Fusion partitioner
//!   and all baselines, the communication-free distributed training
//!   coordinator, the PJRT runtime that executes AOT-compiled models, and
//!   the embedding **serving layer** ([`serve`]): `LFS1` per-partition
//!   shards written by the coordinator, a lazily-loading
//!   [`serve::ShardedEmbeddingStore`], and a batched, cached query
//!   [`serve::Engine`] answering node-classification requests through the
//!   trained integration MLP.
//! * **L2/L1 (python/, build-time only)** — JAX GCN/GraphSAGE/MLP models on
//!   Pallas kernels, lowered once to `artifacts/*.hlo.txt`.
//!
//! See `DESIGN.md` for the system inventory (including the shard format
//! and query path under *Serving*) and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod graph;
pub mod partition;
pub mod runtime;
pub mod serve;
pub mod testing;
pub mod train;
pub mod util;

pub use error::{Error, Result};
