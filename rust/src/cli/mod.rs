//! Minimal declarative CLI parser (no `clap` offline).
//!
//! Supports `program <subcommand> --flag value --switch` with typed
//! accessors, defaults, and an auto-generated usage string.
//!
//! Grammar note: without a registry, `--name token` always binds `token`
//! as the flag's value, so a boolean switch followed by a positional is
//! ambiguous. [`Args::parse_declared`] takes a declared-switch registry:
//! a declared switch never consumes the next token (`prog run --fast
//! input.txt` parses as switch `fast` + positional `input.txt`), and
//! `--fast=true` / `--fast=false` set it explicitly. [`Args::parse`] is
//! the registry-free legacy entry point (switches must come last, precede
//! another `--flag`, or use `--name=true`).

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()`-style input (element 0 = program name)
    /// with no declared switches (legacy heuristic grammar).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        Self::parse_declared(argv, &[])
    }

    /// Parse with a declared-switch registry. Names listed in `declared`
    /// are boolean switches: they never bind the following token as a
    /// value, which removes the `--switch positional` ambiguity.
    pub fn parse_declared<I: IntoIterator<Item = String>>(
        argv: I,
        declared: &[&str],
    ) -> Result<Args> {
        let mut it = argv.into_iter().skip(1).peekable();
        let mut out = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Config("bare -- is not supported".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    if declared.contains(&k) {
                        match v {
                            "true" => out.switches.push(k.to_string()),
                            "false" => {}
                            other => {
                                return Err(Error::Config(format!(
                                    "--{k} is a switch; expected true/false, got {other:?}"
                                )))
                            }
                        }
                    } else {
                        out.flags.insert(k.to_string(), v.to_string());
                    }
                } else if declared.contains(&name) {
                    out.switches.push(name.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(String::as_str)
    }

    pub fn str_or(&self, flag: &str, default: &str) -> String {
        self.get(flag).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, flag: &str, default: usize) -> Result<usize> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{flag} expects an integer, got {v:?}"))),
        }
    }

    pub fn u64_or(&self, flag: &str, default: u64) -> Result<u64> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{flag} expects an integer, got {v:?}"))),
        }
    }

    pub fn f64_or(&self, flag: &str, default: f64) -> Result<f64> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{flag} expects a number, got {v:?}"))),
        }
    }

    /// Comma-separated list flag, e.g. `--ks 2,4,8,16`.
    pub fn usize_list_or(&self, flag: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(flag) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim().parse().map_err(|_| {
                        Error::Config(format!("--{flag}: bad list element {t:?}"))
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    fn parse_decl(s: &str, declared: &[&str]) -> Args {
        Args::parse_declared(s.split_whitespace().map(str::to_string), declared).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("prog train file.toml --k 8 --model sage --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("k"), Some("8"));
        assert_eq!(a.str_or("model", "gcn"), "sage");
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["file.toml"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("prog --k=16 --alpha=0.1");
        assert_eq!(a.usize_or("k", 0).unwrap(), 16);
        assert_eq!(a.f64_or("alpha", 0.0).unwrap(), 0.1);
    }

    #[test]
    fn typed_errors() {
        let a = parse("prog --k abc");
        assert!(a.usize_or("k", 0).is_err());
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn list_flag() {
        let a = parse("prog --ks 2,4,8");
        assert_eq!(a.usize_list_or("ks", &[1]).unwrap(), vec![2, 4, 8]);
        assert_eq!(parse("prog").usize_list_or("ks", &[1]).unwrap(), vec![1]);
    }

    #[test]
    fn trailing_switch() {
        let a = parse("prog bench --quick");
        assert!(a.has("quick"));
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
    }

    // ---- declared-switch registry -------------------------------------

    #[test]
    fn undeclared_switch_before_positional_is_misparsed() {
        // the documented legacy ambiguity this registry exists to fix
        let a = parse("prog run --fast input.txt");
        assert!(!a.has("fast"));
        assert_eq!(a.get("fast"), Some("input.txt"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn declared_switch_does_not_swallow_positional() {
        let a = parse_decl("prog run --fast input.txt", &["fast"]);
        assert!(a.has("fast"));
        assert_eq!(a.get("fast"), None);
        assert_eq!(a.positional, vec!["input.txt"]);
    }

    #[test]
    fn declared_switch_between_flags() {
        let a = parse_decl("prog train --fast --k 8 --dry-run --seed 3", &["fast", "dry-run"]);
        assert!(a.has("fast"));
        assert!(a.has("dry-run"));
        assert_eq!(a.usize_or("k", 0).unwrap(), 8);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 3);
        assert!(a.positional.is_empty());
    }

    #[test]
    fn declared_switch_equals_forms() {
        let a = parse_decl("prog --fast=true --slow=false", &["fast", "slow"]);
        assert!(a.has("fast"));
        assert!(!a.has("slow"));
        let bad = Args::parse_declared(
            "prog --fast=7".split_whitespace().map(str::to_string),
            &["fast"],
        );
        assert!(bad.is_err());
    }

    #[test]
    fn undeclared_flags_still_take_values() {
        let a = parse_decl("prog --k 8 --fast out.json", &["fast"]);
        assert_eq!(a.get("k"), Some("8"));
        assert!(a.has("fast"));
        assert_eq!(a.positional, vec!["out.json"]);
    }

    #[test]
    fn empty_registry_matches_legacy_parse() {
        let legacy = parse("prog train --quick --k 8");
        let decl = parse_decl("prog train --quick --k 8", &[]);
        assert_eq!(legacy.has("quick"), decl.has("quick"));
        assert_eq!(legacy.get("k"), decl.get("k"));
    }
}
