//! In-crate fork-join data parallelism.
//!
//! `rayon` is unavailable offline (see DESIGN.md "Offline-build
//! constraints"), so the partitioning hot paths parallelise through this
//! module instead: scoped-thread map over contiguous index chunks with
//! **ordered reduction** — chunk results are always combined in chunk
//! order, never in completion order, which is the property the
//! determinism-under-parallelism contract (DESIGN.md "Performance")
//! builds on. Callers that additionally need floating-point sums to be
//! bit-identical across thread counts must make the summation order
//! independent of the chunking (the CSR coarsening builder does this by
//! summing inside a sorted merge rather than per chunk).

use std::ops::Range;

/// Clamp a requested thread count to what `len` items can usefully feed:
/// at least `min_chunk` items per thread, and never more threads than
/// items. `0` and `1` both mean sequential.
pub fn effective_threads(threads: usize, len: usize, min_chunk: usize) -> usize {
    if threads <= 1 || len == 0 {
        return 1;
    }
    let max_useful = len.div_ceil(min_chunk.max(1));
    threads.min(max_useful).max(1)
}

/// Split `0..len` into at most `threads` near-equal contiguous chunks,
/// apply `f(chunk_index, range)` to each — in parallel when more than one
/// chunk results — and return the outputs **in chunk order**.
///
/// With `threads <= 1` this degenerates to a single inline call, so the
/// sequential and parallel paths share one code path and cannot drift.
pub fn map_chunks<T, F>(threads: usize, len: usize, min_chunk: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Range<usize>) -> T + Sync,
{
    let threads = effective_threads(threads, len, min_chunk);
    if threads == 1 {
        return vec![f(0, 0..len)];
    }
    let chunk = len.div_ceil(threads);
    // re-derive the worker count from the chunk size so every range is
    // non-empty and well-formed (ceil rounding can otherwise leave
    // trailing workers with start > len)
    let threads = len.div_ceil(chunk);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let f = &f;
                let start = t * chunk;
                let end = ((t + 1) * chunk).min(len);
                scope.spawn(move || f(t, start..end))
            })
            .collect();
        // join in spawn order — the ordered reduction
        handles
            .into_iter()
            // lint: allow(panic_in_lib) — re-raising a worker panic on the caller thread is the fork-join contract; swallowing it would return partial results
            .map(|h| h.join().expect("parallel worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_index_exactly_once() {
        for threads in [1, 2, 3, 8] {
            let chunks = map_chunks(threads, 100, 1, |_, r| r.collect::<Vec<_>>());
            let flat: Vec<usize> = chunks.into_iter().flatten().collect();
            assert_eq!(flat, (0..100).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn results_are_in_chunk_order() {
        let out = map_chunks(4, 40, 1, |idx, r| (idx, r.start));
        for (i, &(idx, start)) in out.iter().enumerate() {
            assert_eq!(idx, i);
            assert_eq!(start, i * 10);
        }
    }

    #[test]
    fn sequential_and_parallel_agree() {
        let work = |_, r: Range<usize>| r.map(|i| i * i).sum::<usize>();
        let seq: usize = map_chunks(1, 1000, 1, work).into_iter().sum();
        let par: usize = map_chunks(4, 1000, 1, work).into_iter().sum();
        assert_eq!(seq, par);
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(map_chunks(8, 0, 1, |_, r| r.len()), vec![0]);
        // 3 items, min chunk 2 → at most 2 chunks
        let out = map_chunks(8, 3, 2, |_, r| r.len());
        assert!(out.len() <= 2, "{out:?}");
        assert_eq!(out.iter().sum::<usize>(), 3);
    }

    #[test]
    fn ranges_are_well_formed_when_threads_do_not_divide_len() {
        // threads=7, len=9 → chunk=2; naive `t * chunk` would hand worker
        // 5 the inverted range 10..9 — slicing with it must not panic
        let data: Vec<usize> = (0..9).collect();
        let chunks = map_chunks(7, data.len(), 1, |_, r| data[r].to_vec());
        let flat: Vec<usize> = chunks.into_iter().flatten().collect();
        assert_eq!(flat, data);
    }

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(0, 100, 1), 1);
        assert_eq!(effective_threads(1, 100, 1), 1);
        assert_eq!(effective_threads(16, 4, 1), 4);
        assert_eq!(effective_threads(16, 100, 50), 2);
        assert_eq!(effective_threads(4, 0, 1), 1);
    }
}
