//! Shared infrastructure: RNG, JSON, timing, logging, fork-join
//! parallelism.

pub mod json;
pub mod parallel;
pub mod rng;
pub mod sha256;

use std::time::Instant;

/// Simple stopwatch for coarse phase timing (partitioning, training, ...).
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        // lint: allow(bare_instant) — Stopwatch IS the sanctioned clock wrapper the rule funnels callers into
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Streaming FNV-1a 64-bit hash — the crate's integrity/fingerprint
/// hash (LFS1 shard section checksums, run-journal fingerprints). Not
/// cryptographic: it detects corruption and config drift, not
/// adversaries, and it is byte-order-stable because every caller feeds
/// it little-endian bytes.
#[derive(Clone, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf29ce484222325)
    }

    #[inline]
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

/// Human-readable duration, e.g. `1.23s` / `45.6ms` / `789µs`.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.1}ms", secs * 1e3)
    } else {
        format!("{:.0}µs", secs * 1e6)
    }
}

/// Minimal `log` facade backend writing to stderr; level from `RUST_LOG`
/// (error|warn|info|debug|trace, default info).
pub struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _: &log::Metadata) -> bool {
        true
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{:<5} {}] {}", record.level(), record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the stderr logger (idempotent).
pub fn init_logging() {
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER).map(|_| log::set_max_level(level));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_duration_ranges() {
        assert_eq!(fmt_duration(2.5), "2.50s");
        assert_eq!(fmt_duration(0.0456), "45.6ms");
        assert_eq!(fmt_duration(0.000789), "789µs");
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        assert!(sw.secs() >= 0.0);
        assert!(sw.millis() >= sw.secs());
    }
}

#[cfg(test)]
mod fnv_tests {
    use super::Fnv64;

    #[test]
    fn matches_reference_vectors() {
        // FNV-1a 64 reference values
        assert_eq!(Fnv64::new().finish(), 0xcbf29ce484222325);
        let mut h = Fnv64::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63dc4c8601ec8c);
        let mut h = Fnv64::new();
        h.write(b"foobar");
        assert_eq!(h.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_equals_one_shot() {
        let mut a = Fnv64::new();
        a.write(b"hello ");
        a.write(b"world");
        let mut b = Fnv64::new();
        b.write(b"hello world");
        assert_eq!(a.finish(), b.finish());
    }
}
