//! Deterministic pseudo-random number generation.
//!
//! The crate is fully offline (no `rand`), so we implement SplitMix64 for
//! seeding and Xoshiro256** as the workhorse generator. Every stochastic
//! component in the library (dataset synthesis, LPA tie-breaks, random
//! partitioner, Leiden node visit order) takes an explicit [`Rng`] so runs
//! are reproducible from a single seed recorded in the experiment log.

/// SplitMix64 step — used to expand a single `u64` seed into a full
/// Xoshiro256** state, as recommended by the xoshiro authors.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single value.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid; splitmix of any seed avoids it, but be
        // defensive anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Rng { s }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; generation is not on any hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights. O(len) —
    /// for repeated sampling from the same distribution use
    /// [`WeightedSampler`].
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.index(weights.len().max(1));
        }
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed cumulative-sum sampler: O(n) build, O(log n) per sample.
/// Used by the SBM generator, which draws millions of weighted samples
/// from fixed propensity distributions.
#[derive(Clone, Debug)]
pub struct WeightedSampler {
    cum: Vec<f64>,
}

impl WeightedSampler {
    pub fn new(weights: &[f64]) -> Self {
        let mut cum = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            acc += w.max(0.0);
            cum.push(acc);
        }
        WeightedSampler { cum }
    }

    pub fn total(&self) -> f64 {
        self.cum.last().copied().unwrap_or(0.0)
    }

    /// Sample an index proportional to its weight.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let total = self.total();
        if total <= 0.0 || self.cum.is_empty() {
            return rng.index(self.cum.len().max(1));
        }
        let target = rng.f64() * total;
        // first index with cum[i] > target
        match self
            .cum
            .binary_search_by(|c| c.partial_cmp(&target).unwrap_or(std::cmp::Ordering::Less))
        {
            Ok(i) => (i + 1).min(self.cum.len() - 1),
            Err(i) => i.min(self.cum.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_prefers_heavy() {
        let mut r = Rng::new(17);
        let w = [0.0, 0.0, 10.0, 0.0];
        for _ in 0..50 {
            assert_eq!(r.weighted_index(&w), 2);
        }
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn weighted_sampler_matches_distribution() {
        let mut r = Rng::new(21);
        let s = WeightedSampler::new(&[1.0, 0.0, 3.0]);
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[s.sample(&mut r)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.0..4.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn weighted_sampler_single_element() {
        let mut r = Rng::new(3);
        let s = WeightedSampler::new(&[5.0]);
        for _ in 0..10 {
            assert_eq!(s.sample(&mut r), 0);
        }
    }
}
