//! Minimal JSON reader/writer (offline build — no serde).
//!
//! Used for two things only: parsing `artifacts/manifest.json` (written by
//! the python AOT pipeline) and emitting machine-readable metrics/bench
//! reports. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (the manifest never contains them).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept ordered for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- writer ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let mut end = self.i;
                        while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                            end += 1;
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"y":null},"z":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line\n\"quote\"\t\\".into());
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ∀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{unquoted: 1}").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version":1,"artifacts":[{"name":"m","file":"m.hlo.txt",
            "inputs":[{"name":"x","shape":[64,8],"dtype":"f32"}]}]}"#;
        let v = Json::parse(src).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        let i = &a.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(i.get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(64));
    }
}
