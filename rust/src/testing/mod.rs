//! Test support: a minimal property-testing driver (no `proptest` offline).

pub mod prop;
