//! Test support: a minimal property-testing driver (no `proptest` offline)
//! and the shared artifact-gating helpers for runtime-dependent tests.

pub mod prop;

use crate::runtime::{default_artifacts_dir, Runtime};
use std::path::PathBuf;

/// `Some(dir)` when the compiled PJRT artifact bundle exists (after
/// `make artifacts`), `None` otherwise. Runtime-gated tests use this to
/// skip themselves on unprovisioned machines; `tier1.sh` counts the gated
/// call sites and prints how many self-skipped so a no-artifact run is
/// visibly partial rather than silently green.
pub fn artifacts_if_built() -> Option<PathBuf> {
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

/// The one runtime-gated test helper (previously hand-rolled per module):
/// a ready [`Runtime`] when artifacts exist, `None` to skip otherwise.
/// Artifacts present but unloadable is a hard failure, not a skip.
pub fn runtime_if_built() -> Option<Runtime> {
    let dir = artifacts_if_built()?;
    // lint: allow(panic_in_lib) — test gate by contract: artifacts present but unloadable must fail the test run, not skip it
    Some(Runtime::new(&dir).expect("artifacts present but runtime init failed"))
}
