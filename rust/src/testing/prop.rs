//! Minimal property-testing driver.
//!
//! `proptest` is unavailable offline, so this provides the 20% that covers
//! our needs: seeded case generation from a [`Rng`], a fixed case budget,
//! and failure reports that include the reproducing seed. No shrinking —
//! generators are written to produce small cases at low seeds instead.

use crate::util::rng::Rng;

/// Run `cases` random property checks. `gen` builds an input from a fresh
/// RNG; `check` returns `Err(description)` on violation. Panics with the
/// reproducing seed on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    base_seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    check: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            // lint: allow(panic_in_lib) — panicking with the reproducing seed IS this driver's failure-reporting contract, like proptest's
            panic!(
                "property {name:?} violated (case {case}, seed {seed}): {msg}\n\
                 input: {input:?}"
            );
        }
    }
}

/// Generator helpers for graph-shaped properties.
pub mod gens {
    use crate::graph::{CsrGraph, GraphBuilder, NodeId};
    use crate::util::rng::Rng;

    /// A random connected graph with `n ∈ [n_min, n_max]` nodes: a random
    /// spanning tree plus `extra_per_node · n` random edges.
    pub fn connected_graph(rng: &mut Rng, n_min: usize, n_max: usize, extra_per_node: f64) -> CsrGraph {
        let n = n_min + rng.index(n_max - n_min + 1);
        let mut b = GraphBuilder::new(n);
        // random attachment spanning tree
        for v in 1..n {
            let u = rng.index(v);
            b.add_edge(v as NodeId, u as NodeId);
        }
        let extra = (n as f64 * extra_per_node) as usize;
        for _ in 0..extra {
            let u = rng.index(n) as NodeId;
            let v = rng.index(n) as NodeId;
            if u != v {
                b.add_edge(u, v);
            }
        }
        // lint: allow(panic_in_lib) — test-only generator; a build failure here is a generator bug the property run must surface
        b.build().expect("generated graph is valid")
    }

    /// An arbitrary (possibly disconnected) graph.
    pub fn any_graph(rng: &mut Rng, n_max: usize, density: f64) -> CsrGraph {
        let n = 1 + rng.index(n_max);
        let mut b = GraphBuilder::new(n);
        let m = (n as f64 * density) as usize;
        for _ in 0..m {
            let u = rng.index(n) as NodeId;
            let v = rng.index(n) as NodeId;
            if u != v {
                b.add_edge(u, v);
            }
        }
        // lint: allow(panic_in_lib) — test-only generator; a build failure here is a generator bug the property run must surface
        b.build().expect("generated graph is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_connected;

    #[test]
    fn check_passes_trivially_true_property() {
        check("sum-commutes", 50, 1,
            |rng| (rng.index(100) as i64, rng.index(100) as i64),
            |&(a, b)| if a + b == b + a { Ok(()) } else { Err("math broke".into()) });
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn check_reports_seed_on_failure() {
        check("always-false", 5, 99, |rng| rng.index(10), |_| Err("no".into()));
    }

    #[test]
    fn connected_graph_gen_is_connected() {
        check("gen-connected", 25, 7,
            |rng| gens::connected_graph(rng, 2, 60, 1.5),
            |g| if is_connected(g) { Ok(()) } else { Err("disconnected".into()) });
    }

    #[test]
    fn any_graph_gen_in_bounds() {
        check("gen-bounds", 25, 3,
            |rng| gens::any_graph(rng, 40, 2.0),
            |g| {
                if g.num_nodes() >= 1 && g.num_nodes() <= 40 {
                    Ok(())
                } else {
                    Err(format!("n = {}", g.num_nodes()))
                }
            });
    }
}
