//! Zachary's karate-club network (34 nodes, 78 edges) — the exact dataset
//! used for the paper's Table 1, Figure 2 and Figure 3.
//!
//! Edge list is the canonical 0-indexed version (Zachary 1977, as shipped
//! by networkx). Ground-truth faction labels (Mr. Hi = 0, Officer = 1)
//! follow the standard split after the club fission.

use super::csr::{CsrGraph, NodeId};

/// The 78 undirected edges of the karate-club graph.
pub const KARATE_EDGES: [(NodeId, NodeId); 78] = [
    (0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (0, 6), (0, 7), (0, 8), (0, 10),
    (0, 11), (0, 12), (0, 13), (0, 17), (0, 19), (0, 21), (0, 31),
    (1, 2), (1, 3), (1, 7), (1, 13), (1, 17), (1, 19), (1, 21), (1, 30),
    (2, 3), (2, 7), (2, 8), (2, 9), (2, 13), (2, 27), (2, 28), (2, 32),
    (3, 7), (3, 12), (3, 13),
    (4, 6), (4, 10),
    (5, 6), (5, 10), (5, 16),
    (6, 16),
    (8, 30), (8, 32), (8, 33),
    (9, 33),
    (13, 33),
    (14, 32), (14, 33),
    (15, 32), (15, 33),
    (18, 32), (18, 33),
    (19, 33),
    (20, 32), (20, 33),
    (22, 32), (22, 33),
    (23, 25), (23, 27), (23, 29), (23, 32), (23, 33),
    (24, 25), (24, 27), (24, 31),
    (25, 31),
    (26, 29), (26, 33),
    (27, 33),
    (28, 31), (28, 33),
    (29, 32), (29, 33),
    (30, 32), (30, 33),
    (31, 32), (31, 33),
    (32, 33),
];

/// Ground-truth faction of each member (0 = Mr. Hi, 1 = Officer).
pub const KARATE_FACTIONS: [u8; 34] = [
    0, 0, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1, 1, 0, 0, 1, 0, 1, 0, 1, 1,
    1, 1, 1, 1, 1, 1, 1, 1, 1, 1,
];

/// Build the karate graph.
pub fn karate_graph() -> CsrGraph {
    // lint: allow(panic_in_lib) — compile-time constant edge list, validated by the has_canonical_size test
    CsrGraph::from_edges(34, &KARATE_EDGES).expect("karate edge list is valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components::is_connected;

    #[test]
    fn has_canonical_size() {
        let g = karate_graph();
        assert_eq!(g.num_nodes(), 34);
        assert_eq!(g.num_edges(), 78);
    }

    #[test]
    fn is_single_connected_component() {
        assert!(is_connected(&karate_graph()));
    }

    #[test]
    fn known_degrees() {
        let g = karate_graph();
        assert_eq!(g.degree(0), 16); // Mr. Hi
        assert_eq!(g.degree(33), 17); // the Officer
        assert_eq!(g.degree(11), 1); // weakest member
    }

    #[test]
    fn factions_cover_both_sides() {
        let zeros = KARATE_FACTIONS.iter().filter(|&&f| f == 0).count();
        assert_eq!(zeros, 17); // classic 17/17 split
        assert_eq!(KARATE_FACTIONS.len(), 34);
    }

    #[test]
    fn hub_edges_present() {
        let g = karate_graph();
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(32, 33));
        assert!(!g.has_edge(0, 33)); // the two leaders are not connected
    }
}
