//! Incremental graph construction with normalisation.
//!
//! Raw edge streams (generators, file loaders) may contain self-loops,
//! duplicates, and both orientations of the same undirected edge; the
//! builder canonicalises to `u < v`, merges duplicates (summing weights),
//! and hands a clean list to [`CsrGraph`].

use super::csr::{CsrGraph, NodeId};
use crate::error::Result;
// lint: allow(nondet_iter) — O(1) keyed dedup only; build() sorts the pairs before CSR construction so no output depends on hash order
use std::collections::HashMap;

/// Accumulates edges, then builds a [`CsrGraph`].
#[derive(Default)]
pub struct GraphBuilder {
    n: usize,
    // lint: allow(nondet_iter) — keyed access only; iterated once in build() where the result is immediately sorted
    edges: HashMap<(NodeId, NodeId), f32>,
    weighted: bool,
}

impl GraphBuilder {
    pub fn new(n: usize) -> Self {
        // lint: allow(nondet_iter) — see the field note: dedup map, sorted on build
        GraphBuilder { n, edges: HashMap::new(), weighted: false }
    }

    /// Add an undirected edge; orientation and duplicates are normalised.
    /// Self-loops are silently dropped (GNN self-contribution is handled by
    /// the runtime's normalisation weights, not graph structure).
    pub fn add_edge(&mut self, u: NodeId, v: NodeId) -> &mut Self {
        self.add_weighted(u, v, 1.0)
    }

    /// Add a weighted undirected edge; duplicate insertions sum weights.
    pub fn add_weighted(&mut self, u: NodeId, v: NodeId, w: f32) -> &mut Self {
        if u == v {
            return self;
        }
        if w != 1.0 {
            self.weighted = true;
        }
        let key = if u < v { (u, v) } else { (v, u) };
        let slot = self.edges.entry(key).or_insert(0.0);
        if *slot != 0.0 {
            self.weighted = true; // duplicate ⇒ merged weight differs from 1
        }
        *slot += w;
        self
    }

    pub fn num_pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Whether `{u, v}` has been added already.
    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        let key = if u < v { (u, v) } else { (v, u) };
        self.edges.contains_key(&key)
    }

    /// Finalise into CSR form.
    pub fn build(self) -> Result<CsrGraph> {
        let mut pairs: Vec<((NodeId, NodeId), f32)> = self.edges.into_iter().collect();
        pairs.sort_unstable_by_key(|&(k, _)| k);
        let edges: Vec<(NodeId, NodeId)> = pairs.iter().map(|&(k, _)| k).collect();
        if self.weighted {
            let weights: Vec<f32> = pairs.iter().map(|&(_, w)| w).collect();
            CsrGraph::from_weighted_edges(self.n, &edges, Some(&weights))
        } else {
            CsrGraph::from_edges(self.n, &edges)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deduplicates_and_drops_self_loops() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1).add_edge(1, 0).add_edge(2, 2).add_edge(1, 2);
        let g = b.build().unwrap();
        assert_eq!(g.num_edges(), 2);
        // duplicate (0,1)+(1,0) merged; weight sums to 2 ⇒ weighted graph
        assert!(g.is_weighted());
        assert_eq!(g.neighbor_weights(0), Some(&[2.0f32][..]));
    }

    #[test]
    fn unweighted_stays_unweighted() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1).add_edge(1, 2);
        let g = b.build().unwrap();
        assert!(!g.is_weighted());
    }

    #[test]
    fn weights_sum() {
        let mut b = GraphBuilder::new(2);
        b.add_weighted(0, 1, 1.5).add_weighted(1, 0, 2.5);
        let g = b.build().unwrap();
        assert_eq!(g.total_weight(), 4.0);
    }

    #[test]
    fn has_edge_checks_both_orientations() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(2, 1);
        assert!(b.has_edge(1, 2));
        assert!(b.has_edge(2, 1));
        assert!(!b.has_edge(0, 1));
    }
}
