//! Graph (de)serialisation: text edge lists and a compact binary format.
//!
//! * **Text** — one `u v [w]` edge per line, `#` comments; interoperable
//!   with SNAP/OGB-style dumps so users can bring their own graphs.
//! * **Binary** — `LFG1` magic, little-endian, CSR arrays verbatim. Used to
//!   cache generated datasets between benchmark runs.

use super::builder::GraphBuilder;
use super::csr::{CsrGraph, NodeId};
use crate::error::{Error, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Write a text edge list (weights included when present).
pub fn write_edge_list(g: &CsrGraph, path: &Path) -> Result<()> {
    let mut out = BufWriter::new(std::fs::File::create(path)?);
    writeln!(out, "# nodes {}", g.num_nodes())?;
    for (u, v, w) in g.edges() {
        if g.is_weighted() {
            writeln!(out, "{u} {v} {w}")?;
        } else {
            writeln!(out, "{u} {v}")?;
        }
    }
    Ok(())
}

/// Read a text edge list. Node count is `max id + 1` unless a
/// `# nodes N` header is present.
pub fn read_edge_list(path: &Path) -> Result<CsrGraph> {
    let reader = BufReader::new(std::fs::File::open(path)?);
    let mut declared_n: Option<usize> = None;
    let mut edges: Vec<(NodeId, NodeId, f32)> = Vec::new();
    let mut max_id: NodeId = 0;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if let Some(rest) = trimmed.strip_prefix('#') {
            let mut toks = rest.split_whitespace();
            if toks.next() == Some("nodes") {
                if let Some(Ok(n)) = toks.next().map(|t| t.parse()) {
                    declared_n = Some(n);
                }
            }
            continue;
        }
        let mut toks = trimmed.split_whitespace();
        let parse = |t: Option<&str>| -> Result<NodeId> {
            t.ok_or_else(|| Error::Graph(format!("line {}: missing field", lineno + 1)))?
                .parse()
                .map_err(|e| Error::Graph(format!("line {}: {e}", lineno + 1)))
        };
        let u = parse(toks.next())?;
        let v = parse(toks.next())?;
        let w = match toks.next() {
            Some(t) => t
                .parse()
                .map_err(|e| Error::Graph(format!("line {}: {e}", lineno + 1)))?,
            None => 1.0,
        };
        max_id = max_id.max(u).max(v);
        edges.push((u, v, w));
    }
    let n = declared_n.unwrap_or(if edges.is_empty() { 0 } else { max_id as usize + 1 });
    let mut b = GraphBuilder::new(n);
    for (u, v, w) in edges {
        b.add_weighted(u, v, w);
    }
    b.build()
}

const MAGIC: &[u8; 4] = b"LFG1";

/// Write the compact binary format.
pub fn write_binary(g: &CsrGraph, path: &Path) -> Result<()> {
    let mut out = BufWriter::new(std::fs::File::create(path)?);
    out.write_all(MAGIC)?;
    let n = g.num_nodes() as u64;
    let m = g.num_edges() as u64;
    let weighted = g.is_weighted() as u8;
    out.write_all(&n.to_le_bytes())?;
    out.write_all(&m.to_le_bytes())?;
    out.write_all(&[weighted])?;
    for (u, v, w) in g.edges() {
        out.write_all(&u.to_le_bytes())?;
        out.write_all(&v.to_le_bytes())?;
        if weighted == 1 {
            out.write_all(&w.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read the compact binary format.
pub fn read_binary(path: &Path) -> Result<CsrGraph> {
    let mut reader = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    reader.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Graph("bad magic (not an LFG1 file)".into()));
    }
    let mut buf8 = [0u8; 8];
    reader.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    reader.read_exact(&mut buf8)?;
    let m = u64::from_le_bytes(buf8) as usize;
    let mut flag = [0u8; 1];
    reader.read_exact(&mut flag)?;
    let weighted = flag[0] == 1;
    let mut edges = Vec::with_capacity(m);
    let mut weights = if weighted { Some(Vec::with_capacity(m)) } else { None };
    let mut buf4 = [0u8; 4];
    for _ in 0..m {
        reader.read_exact(&mut buf4)?;
        let u = u32::from_le_bytes(buf4);
        reader.read_exact(&mut buf4)?;
        let v = u32::from_le_bytes(buf4);
        edges.push((u, v));
        if let Some(w) = weights.as_mut() {
            reader.read_exact(&mut buf4)?;
            w.push(f32::from_le_bytes(buf4));
        }
    }
    CsrGraph::from_weighted_edges(n, &edges, weights.as_deref())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate::karate_graph;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lf_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn text_roundtrip_unweighted() {
        let g = karate_graph();
        let path = tmpfile("karate.txt");
        write_edge_list(&g, &path).unwrap();
        let g2 = read_edge_list(&path).unwrap();
        assert_eq!(g2.num_nodes(), g.num_nodes());
        assert_eq!(g2.num_edges(), g.num_edges());
        for v in 0..g.num_nodes() as NodeId {
            assert_eq!(g.neighbors(v), g2.neighbors(v));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn text_roundtrip_weighted() {
        let g = CsrGraph::from_weighted_edges(3, &[(0, 1), (1, 2)], Some(&[0.5, 2.0]))
            .unwrap();
        let path = tmpfile("w.txt");
        write_edge_list(&g, &path).unwrap();
        let g2 = read_edge_list(&path).unwrap();
        assert!(g2.is_weighted());
        assert_eq!(g2.total_weight(), 2.5);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let g = karate_graph();
        let path = tmpfile("karate.bin");
        write_binary(&g, &path).unwrap();
        let g2 = read_binary(&path).unwrap();
        assert_eq!(g2.num_nodes(), 34);
        assert_eq!(g2.num_edges(), 78);
        for v in 0..34 {
            assert_eq!(g.neighbors(v), g2.neighbors(v));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let path = tmpfile("bad.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_binary(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn text_parses_comments_and_header() {
        let path = tmpfile("hdr.txt");
        std::fs::write(&path, "# nodes 10\n# a comment\n0 1\n5 6 2.5\n").unwrap();
        let g = read_edge_list(&path).unwrap();
        assert_eq!(g.num_nodes(), 10);
        assert_eq!(g.num_edges(), 2);
        assert!(g.is_weighted());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn text_rejects_malformed() {
        let path = tmpfile("bad.txt");
        std::fs::write(&path, "0 x\n").unwrap();
        assert!(read_edge_list(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
