//! Subgraph extraction: **Inner** and **Repli** (paper §5.2).
//!
//! Given a partition's node set, training needs a local graph:
//!
//! * **Inner** — the induced subgraph: only edges with both endpoints in
//!   the partition. Cut edges are dropped; boundary nodes lose neighbours.
//! * **Repli** — cut edges are preserved by *replicating* the external
//!   endpoint into the subgraph as a read-only "halo" node. Replicas carry
//!   their features (copied once before training — no communication during
//!   training) but are excluded from the loss mask and from the embedding
//!   integration (each node's embedding comes from its *owner* partition).
//!
//! Extraction follows the partitioning core's scratch pattern (DESIGN.md
//! "Performance"): the global→local id map is an epoch-stamped dense
//! array ([`SubgraphScratch`]) instead of a per-extraction `HashMap` — a
//! membership probe is one stamped load, clearing between partitions is
//! O(1), and one scratch reused across extractions allocates nothing
//! after the first. [`extract_subgraphs`] fans per-partition extraction
//! out across threads (`util/parallel`) with the same byte-identical
//! determinism contract as the partition pipeline: partitions are
//! independent and chunk results reduce in chunk order, so the output
//! never depends on the thread count.

use super::csr::{CsrGraph, NodeId};
use crate::error::{Error, Result};
use crate::util::parallel::map_chunks;

/// A local training graph with its mapping back to global node ids.
#[derive(Clone, Debug)]
pub struct Subgraph {
    /// Local id → global id. Owned nodes come first, replicas after.
    pub nodes: Vec<NodeId>,
    /// Number of owned nodes (prefix of `nodes`); the rest are replicas.
    pub num_owned: usize,
    /// The local graph over `nodes` (local ids).
    pub graph: CsrGraph,
}

impl Subgraph {
    /// Whether a local node is owned (vs a replica).
    #[inline]
    pub fn is_owned(&self, local: usize) -> bool {
        local < self.num_owned
    }

    pub fn num_replicas(&self) -> usize {
        self.nodes.len() - self.num_owned
    }
}

/// Which extraction to run (mirrors `train::Mode`, which lives above this
/// layer and converts into it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubgraphKind {
    Inner,
    Repli,
}

/// Reusable epoch-stamped dense `global id → local id` map.
///
/// `local[v]` is valid only while `stamp[v]` equals the current epoch;
/// `begin` bumps the epoch (an O(1) clear) and grows the arrays to the
/// graph's node count on first use. One scratch reused across many
/// extractions keeps the loops allocation-free after the high-water mark.
#[derive(Debug, Default)]
pub struct SubgraphScratch {
    local: Vec<u32>,
    stamp: Vec<u32>,
    epoch: u32,
}

impl SubgraphScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a fresh extraction over a graph with `n` nodes.
    fn begin(&mut self, n: usize) {
        if self.local.len() < n {
            self.local.resize(n, 0);
            self.stamp.resize(n, 0);
        }
        // On wrap, stale stamps could alias the new epoch — do the one
        // full clear every 2^32 - 1 epochs that correctness needs.
        if self.epoch == u32::MAX {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 1;
        } else {
            self.epoch += 1;
        }
    }

    #[inline]
    fn get(&self, v: NodeId) -> Option<u32> {
        let i = v as usize;
        if self.stamp[i] == self.epoch {
            Some(self.local[i])
        } else {
            None
        }
    }

    #[inline]
    fn set(&mut self, v: NodeId, local: u32) {
        let i = v as usize;
        self.stamp[i] = self.epoch;
        self.local[i] = local;
    }
}

/// Induced subgraph over `members` (global ids — order defines local ids).
pub fn inner_subgraph(g: &CsrGraph, members: &[NodeId]) -> Result<Subgraph> {
    inner_subgraph_with(g, members, &mut SubgraphScratch::new())
}

/// [`inner_subgraph`] with a caller-provided scratch (reuse it across
/// partitions to avoid re-allocating the dense id map).
pub fn inner_subgraph_with(
    g: &CsrGraph,
    members: &[NodeId],
    scratch: &mut SubgraphScratch,
) -> Result<Subgraph> {
    scratch.begin(g.num_nodes());
    for (i, &v) in members.iter().enumerate() {
        scratch.set(v, i as u32);
    }
    let mut edges = Vec::new();
    let mut weights = Vec::new();
    let mut weighted = false;
    for (i, &v) in members.iter().enumerate() {
        for (j, &u) in g.neighbors(v).iter().enumerate() {
            if v < u {
                if let Some(lu) = scratch.get(u) {
                    edges.push((i as u32, lu));
                    let w = g.weight_at(v, j);
                    weights.push(w);
                    weighted |= g.is_weighted();
                }
            }
        }
    }
    let graph = if weighted {
        CsrGraph::from_weighted_edges(members.len(), &edges, Some(&weights))?
    } else {
        CsrGraph::from_edges(members.len(), &edges)?
    };
    Ok(Subgraph { nodes: members.to_vec(), num_owned: members.len(), graph })
}

/// Subgraph with 1-hop halo replication: all edges incident to an owned
/// node are kept; external endpoints become replica nodes. Edges between
/// two replicas are *not* included (they belong to other partitions).
pub fn repli_subgraph(g: &CsrGraph, members: &[NodeId]) -> Result<Subgraph> {
    repli_subgraph_with(g, members, &mut SubgraphScratch::new())
}

/// [`repli_subgraph`] with a caller-provided scratch (reuse it across
/// partitions to avoid re-allocating the dense id map).
pub fn repli_subgraph_with(
    g: &CsrGraph,
    members: &[NodeId],
    scratch: &mut SubgraphScratch,
) -> Result<Subgraph> {
    scratch.begin(g.num_nodes());
    let mut nodes = members.to_vec();
    for (i, &v) in members.iter().enumerate() {
        scratch.set(v, i as u32);
    }
    let num_owned = members.len();
    // Discover replicas in deterministic order.
    for &v in members {
        for &u in g.neighbors(v) {
            if scratch.get(u).is_none() {
                scratch.set(u, nodes.len() as u32);
                nodes.push(u);
            }
        }
    }
    let mut edges = Vec::new();
    let mut weights = Vec::new();
    for (i, &v) in members.iter().enumerate() {
        for (j, &u) in g.neighbors(v).iter().enumerate() {
            let lu = scratch
                .get(u)
                .ok_or_else(|| Error::Graph(format!("neighbour {u} not registered")))?;
            let owned_u = (lu as usize) < num_owned;
            // Keep each edge once: owned-owned when v < u; owned-replica
            // always emitted from the owned side.
            if owned_u && v >= u {
                continue;
            }
            edges.push((i as u32, lu));
            weights.push(g.weight_at(v, j));
        }
    }
    let graph = if g.is_weighted() {
        CsrGraph::from_weighted_edges(nodes.len(), &edges, Some(&weights))?
    } else {
        CsrGraph::from_edges(nodes.len(), &edges)?
    };
    Ok(Subgraph { nodes, num_owned, graph })
}

/// Extract one subgraph per partition, `threads`-wide. Partitions are
/// independent, each worker reuses one scratch across its chunk, and
/// chunk results reduce in chunk order — the output is byte-identical
/// for every thread count (the partition pipeline's determinism
/// contract).
pub fn extract_subgraphs(
    g: &CsrGraph,
    members: &[Vec<NodeId>],
    kind: SubgraphKind,
    threads: usize,
) -> Result<Vec<Subgraph>> {
    let chunks = map_chunks(threads, members.len(), 1, |_, range| {
        let mut scratch = SubgraphScratch::new();
        range
            .map(|p| match kind {
                SubgraphKind::Inner => inner_subgraph_with(g, &members[p], &mut scratch),
                SubgraphKind::Repli => repli_subgraph_with(g, &members[p], &mut scratch),
            })
            .collect::<Result<Vec<_>>>()
    });
    let mut out = Vec::with_capacity(members.len());
    for chunk in chunks {
        out.extend(chunk?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Path 0-1-2-3-4 plus chord (1,3).
    fn path_graph() -> CsrGraph {
        CsrGraph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (1, 3)]).unwrap()
    }

    #[test]
    fn inner_keeps_only_internal_edges() {
        let g = path_graph();
        let sg = inner_subgraph(&g, &[1, 2, 3]).unwrap();
        assert_eq!(sg.nodes, vec![1, 2, 3]);
        assert_eq!(sg.num_owned, 3);
        assert_eq!(sg.num_replicas(), 0);
        // local edges: (0,1)=(1,2), (1,2)=(2,3), (0,2)=(1,3)
        assert_eq!(sg.graph.num_edges(), 3);
        assert!(sg.graph.has_edge(0, 2));
    }

    #[test]
    fn repli_adds_halo_nodes() {
        let g = path_graph();
        let sg = repli_subgraph(&g, &[1, 2]).unwrap();
        // owned {1,2}; replicas {0, 3} (neighbours of owned outside set)
        assert_eq!(sg.num_owned, 2);
        assert_eq!(sg.num_replicas(), 2);
        assert_eq!(sg.nodes[..2], [1, 2]);
        let mut replicas = sg.nodes[2..].to_vec();
        replicas.sort_unstable();
        assert_eq!(replicas, vec![0, 3]);
        // edges: (1,2) internal; (1,0),(1,3),(2,3) to replicas = 4 total
        assert_eq!(sg.graph.num_edges(), 4);
    }

    #[test]
    fn repli_excludes_replica_replica_edges() {
        // triangle 0-1-2; own only {0} → replicas 1,2; edge (1,2) excluded
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]).unwrap();
        let sg = repli_subgraph(&g, &[0]).unwrap();
        assert_eq!(sg.num_replicas(), 2);
        assert_eq!(sg.graph.num_edges(), 2);
    }

    #[test]
    fn repli_of_full_set_equals_inner() {
        let g = path_graph();
        let all: Vec<NodeId> = (0..5).collect();
        let a = inner_subgraph(&g, &all).unwrap();
        let b = repli_subgraph(&g, &all).unwrap();
        assert_eq!(b.num_replicas(), 0);
        assert_eq!(a.graph.num_edges(), b.graph.num_edges());
    }

    #[test]
    fn weighted_subgraphs_preserve_weights() {
        let g = CsrGraph::from_weighted_edges(3, &[(0, 1), (1, 2)], Some(&[2.0, 5.0]))
            .unwrap();
        let sg = inner_subgraph(&g, &[1, 2]).unwrap();
        assert_eq!(sg.graph.total_weight(), 5.0);
        let rg = repli_subgraph(&g, &[1]).unwrap();
        assert_eq!(rg.graph.total_weight(), 7.0);
    }

    #[test]
    fn local_ids_follow_member_order() {
        let g = path_graph();
        let sg = inner_subgraph(&g, &[3, 1, 2]).unwrap();
        assert_eq!(sg.nodes, vec![3, 1, 2]);
        // edge (1,2) → local (1,2); edge (2,3) → local (2,0); chord (1,3) → (1,0)
        assert!(sg.graph.has_edge(1, 2));
        assert!(sg.graph.has_edge(0, 2));
        assert!(sg.graph.has_edge(0, 1));
    }

    #[test]
    fn scratch_reuse_matches_fresh_extraction() {
        let g = path_graph();
        let mut scratch = SubgraphScratch::new();
        // run several extractions through one scratch; each must match a
        // fresh-scratch run exactly (the epoch clear really clears)
        for members in [vec![1, 2, 3], vec![0, 4], vec![2], vec![3, 1, 2]] {
            let a = inner_subgraph_with(&g, &members, &mut scratch).unwrap();
            let b = inner_subgraph(&g, &members).unwrap();
            assert_subgraph_eq(&a, &b);
            let a = repli_subgraph_with(&g, &members, &mut scratch).unwrap();
            let b = repli_subgraph(&g, &members).unwrap();
            assert_subgraph_eq(&a, &b);
        }
    }

    fn assert_subgraph_eq(a: &Subgraph, b: &Subgraph) {
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(a.num_owned, b.num_owned);
        assert_eq!(a.graph.num_nodes(), b.graph.num_nodes());
        assert_eq!(a.graph.num_edges(), b.graph.num_edges());
        for v in 0..a.graph.num_nodes() as NodeId {
            assert_eq!(a.graph.neighbors(v), b.graph.neighbors(v), "node {v}");
            for j in 0..a.graph.neighbors(v).len() {
                assert_eq!(
                    a.graph.weight_at(v, j).to_bits(),
                    b.graph.weight_at(v, j).to_bits(),
                    "weight at ({v}, {j})"
                );
            }
        }
    }

    #[test]
    fn parallel_extraction_is_byte_identical_across_thread_counts() {
        use crate::testing::prop::gens;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x5AB6);
        let g = gens::connected_graph(&mut rng, 60, 120, 2.0);
        // round-robin the nodes into 7 uneven "partitions"
        let k = 7;
        let mut members: Vec<Vec<NodeId>> = vec![Vec::new(); k];
        for v in 0..g.num_nodes() as NodeId {
            members[(v as usize * 31 + 7) % k].push(v);
        }
        for kind in [SubgraphKind::Inner, SubgraphKind::Repli] {
            let seq = extract_subgraphs(&g, &members, kind, 1).unwrap();
            assert_eq!(seq.len(), k);
            for threads in [2, 3, 8] {
                let par = extract_subgraphs(&g, &members, kind, threads).unwrap();
                assert_eq!(par.len(), k, "{kind:?} threads={threads}");
                for (a, b) in par.iter().zip(&seq) {
                    assert_subgraph_eq(a, b);
                }
            }
        }
    }
}
