//! Subgraph extraction: **Inner** and **Repli** (paper §5.2).
//!
//! Given a partition's node set, training needs a local graph:
//!
//! * **Inner** — the induced subgraph: only edges with both endpoints in
//!   the partition. Cut edges are dropped; boundary nodes lose neighbours.
//! * **Repli** — cut edges are preserved by *replicating* the external
//!   endpoint into the subgraph as a read-only "halo" node. Replicas carry
//!   their features (copied once before training — no communication during
//!   training) but are excluded from the loss mask and from the embedding
//!   integration (each node's embedding comes from its *owner* partition).

use super::csr::{CsrGraph, NodeId};
use crate::error::Result;
use std::collections::HashMap;

/// A local training graph with its mapping back to global node ids.
#[derive(Clone, Debug)]
pub struct Subgraph {
    /// Local id → global id. Owned nodes come first, replicas after.
    pub nodes: Vec<NodeId>,
    /// Number of owned nodes (prefix of `nodes`); the rest are replicas.
    pub num_owned: usize,
    /// The local graph over `nodes` (local ids).
    pub graph: CsrGraph,
}

impl Subgraph {
    /// Whether a local node is owned (vs a replica).
    #[inline]
    pub fn is_owned(&self, local: usize) -> bool {
        local < self.num_owned
    }

    pub fn num_replicas(&self) -> usize {
        self.nodes.len() - self.num_owned
    }
}

/// Induced subgraph over `members` (global ids — order defines local ids).
pub fn inner_subgraph(g: &CsrGraph, members: &[NodeId]) -> Result<Subgraph> {
    let mut local_of: HashMap<NodeId, u32> = HashMap::with_capacity(members.len());
    for (i, &v) in members.iter().enumerate() {
        local_of.insert(v, i as u32);
    }
    let mut edges = Vec::new();
    let mut weights = Vec::new();
    let mut weighted = false;
    for (i, &v) in members.iter().enumerate() {
        for (j, &u) in g.neighbors(v).iter().enumerate() {
            if v < u {
                if let Some(&lu) = local_of.get(&u) {
                    edges.push((i as u32, lu));
                    let w = g.weight_at(v, j);
                    weights.push(w);
                    weighted |= g.is_weighted();
                }
            }
        }
    }
    let graph = if weighted {
        CsrGraph::from_weighted_edges(members.len(), &edges, Some(&weights))?
    } else {
        CsrGraph::from_edges(members.len(), &edges)?
    };
    Ok(Subgraph { nodes: members.to_vec(), num_owned: members.len(), graph })
}

/// Subgraph with 1-hop halo replication: all edges incident to an owned
/// node are kept; external endpoints become replica nodes. Edges between
/// two replicas are *not* included (they belong to other partitions).
pub fn repli_subgraph(g: &CsrGraph, members: &[NodeId]) -> Result<Subgraph> {
    let mut local_of: HashMap<NodeId, u32> = HashMap::with_capacity(members.len() * 2);
    let mut nodes = members.to_vec();
    for (i, &v) in members.iter().enumerate() {
        local_of.insert(v, i as u32);
    }
    let num_owned = members.len();
    // Discover replicas in deterministic order.
    for &v in members {
        for &u in g.neighbors(v) {
            if !local_of.contains_key(&u) {
                local_of.insert(u, nodes.len() as u32);
                nodes.push(u);
            }
        }
    }
    let mut edges = Vec::new();
    let mut weights = Vec::new();
    for (i, &v) in members.iter().enumerate() {
        for (j, &u) in g.neighbors(v).iter().enumerate() {
            let lu = local_of[&u];
            let owned_u = (lu as usize) < num_owned;
            // Keep each edge once: owned-owned when v < u; owned-replica
            // always emitted from the owned side.
            if owned_u && v >= u {
                continue;
            }
            edges.push((i as u32, lu));
            weights.push(g.weight_at(v, j));
        }
    }
    let graph = if g.is_weighted() {
        CsrGraph::from_weighted_edges(nodes.len(), &edges, Some(&weights))?
    } else {
        CsrGraph::from_edges(nodes.len(), &edges)?
    };
    Ok(Subgraph { nodes, num_owned, graph })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Path 0-1-2-3-4 plus chord (1,3).
    fn path_graph() -> CsrGraph {
        CsrGraph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (1, 3)]).unwrap()
    }

    #[test]
    fn inner_keeps_only_internal_edges() {
        let g = path_graph();
        let sg = inner_subgraph(&g, &[1, 2, 3]).unwrap();
        assert_eq!(sg.nodes, vec![1, 2, 3]);
        assert_eq!(sg.num_owned, 3);
        assert_eq!(sg.num_replicas(), 0);
        // local edges: (0,1)=(1,2), (1,2)=(2,3), (0,2)=(1,3)
        assert_eq!(sg.graph.num_edges(), 3);
        assert!(sg.graph.has_edge(0, 2));
    }

    #[test]
    fn repli_adds_halo_nodes() {
        let g = path_graph();
        let sg = repli_subgraph(&g, &[1, 2]).unwrap();
        // owned {1,2}; replicas {0, 3} (neighbours of owned outside set)
        assert_eq!(sg.num_owned, 2);
        assert_eq!(sg.num_replicas(), 2);
        assert_eq!(sg.nodes[..2], [1, 2]);
        let mut replicas = sg.nodes[2..].to_vec();
        replicas.sort_unstable();
        assert_eq!(replicas, vec![0, 3]);
        // edges: (1,2) internal; (1,0),(1,3),(2,3) to replicas = 4 total
        assert_eq!(sg.graph.num_edges(), 4);
    }

    #[test]
    fn repli_excludes_replica_replica_edges() {
        // triangle 0-1-2; own only {0} → replicas 1,2; edge (1,2) excluded
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]).unwrap();
        let sg = repli_subgraph(&g, &[0]).unwrap();
        assert_eq!(sg.num_replicas(), 2);
        assert_eq!(sg.graph.num_edges(), 2);
    }

    #[test]
    fn repli_of_full_set_equals_inner() {
        let g = path_graph();
        let all: Vec<NodeId> = (0..5).collect();
        let a = inner_subgraph(&g, &all).unwrap();
        let b = repli_subgraph(&g, &all).unwrap();
        assert_eq!(b.num_replicas(), 0);
        assert_eq!(a.graph.num_edges(), b.graph.num_edges());
    }

    #[test]
    fn weighted_subgraphs_preserve_weights() {
        let g = CsrGraph::from_weighted_edges(3, &[(0, 1), (1, 2)], Some(&[2.0, 5.0]))
            .unwrap();
        let sg = inner_subgraph(&g, &[1, 2]).unwrap();
        assert_eq!(sg.graph.total_weight(), 5.0);
        let rg = repli_subgraph(&g, &[1]).unwrap();
        assert_eq!(rg.graph.total_weight(), 7.0);
    }

    #[test]
    fn local_ids_follow_member_order() {
        let g = path_graph();
        let sg = inner_subgraph(&g, &[3, 1, 2]).unwrap();
        assert_eq!(sg.nodes, vec![3, 1, 2]);
        // edge (1,2) → local (1,2); edge (2,3) → local (2,0); chord (1,3) → (1,0)
        assert!(sg.graph.has_edge(1, 2));
        assert!(sg.graph.has_edge(0, 2));
        assert!(sg.graph.has_edge(0, 1));
    }
}
