//! Graph statistics: degree distribution, clustering, homophily.
//!
//! Used to validate that the synthetic stand-ins preserve the properties
//! the paper's method interacts with (community structure, degree skew,
//! density), and surfaced by `repro info`/`repro partition` for arbitrary
//! user graphs.

use super::csr::{CsrGraph, NodeId};

/// Summary statistics of a graph.
#[derive(Clone, Debug)]
pub struct GraphStats {
    pub nodes: usize,
    pub edges: usize,
    pub avg_degree: f64,
    pub max_degree: usize,
    pub min_degree: usize,
    /// Gini coefficient of the degree distribution (0 = uniform).
    pub degree_gini: f64,
    /// Global clustering coefficient (3·triangles / wedges), sampled for
    /// large graphs.
    pub clustering: f64,
}

/// Compute summary stats. Triangle counting samples up to `sample_nodes`
/// vertices (exact when the graph is smaller).
pub fn graph_stats(g: &CsrGraph, sample_nodes: usize) -> GraphStats {
    let n = g.num_nodes();
    let degrees: Vec<usize> = (0..n as NodeId).map(|v| g.degree(v)).collect();
    let avg = if n == 0 { 0.0 } else { degrees.iter().sum::<usize>() as f64 / n as f64 };

    // Gini of degrees
    let mut sorted = degrees.clone();
    sorted.sort_unstable();
    let total: f64 = sorted.iter().map(|&d| d as f64).sum();
    let gini = if n == 0 || total == 0.0 {
        0.0
    } else {
        let weighted: f64 = sorted
            .iter()
            .enumerate()
            .map(|(i, &d)| (2.0 * (i as f64 + 1.0) - n as f64 - 1.0) * d as f64)
            .sum();
        weighted / (n as f64 * total)
    };

    // clustering coefficient over a node sample
    let step = (n / sample_nodes.max(1)).max(1);
    let mut triangles = 0usize;
    let mut wedges = 0usize;
    for v in (0..n as NodeId).step_by(step) {
        let nbrs = g.neighbors(v);
        let d = nbrs.len();
        if d < 2 {
            continue;
        }
        wedges += d * (d - 1) / 2;
        for i in 0..d {
            for j in (i + 1)..d {
                if g.has_edge(nbrs[i], nbrs[j]) {
                    triangles += 1;
                }
            }
        }
    }
    let clustering = if wedges == 0 { 0.0 } else { triangles as f64 / wedges as f64 };

    GraphStats {
        nodes: n,
        edges: g.num_edges(),
        avg_degree: avg,
        max_degree: degrees.iter().copied().max().unwrap_or(0),
        min_degree: degrees.iter().copied().min().unwrap_or(0),
        degree_gini: gini,
        clustering,
    }
}

/// Degree histogram with log-spaced buckets (for `repro info` output).
pub fn degree_histogram(g: &CsrGraph) -> Vec<(usize, usize)> {
    let mut buckets: Vec<(usize, usize)> = Vec::new();
    let mut bound = 1usize;
    while bound <= g.num_nodes().max(2) {
        buckets.push((bound, 0));
        bound *= 2;
    }
    for v in 0..g.num_nodes() as NodeId {
        let d = g.degree(v);
        let idx = (usize::BITS - d.max(1).leading_zeros() - 1) as usize;
        if let Some(b) = buckets.get_mut(idx) {
            b.1 += 1;
        }
    }
    while buckets.last().map_or(false, |&(_, c)| c == 0) {
        buckets.pop();
    }
    buckets
}

/// Label homophily: fraction of edges whose endpoints share a label.
pub fn edge_homophily(g: &CsrGraph, labels: &[i32]) -> f64 {
    let mut same = 0usize;
    let mut total = 0usize;
    for (u, v, _) in g.edges() {
        total += 1;
        same += (labels[u as usize] == labels[v as usize]) as usize;
    }
    if total == 0 {
        0.0
    } else {
        same as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate::karate_graph;

    #[test]
    fn karate_stats() {
        let g = karate_graph();
        let s = graph_stats(&g, 1000);
        assert_eq!(s.nodes, 34);
        assert_eq!(s.edges, 78);
        assert!((s.avg_degree - 2.0 * 78.0 / 34.0).abs() < 1e-9);
        assert_eq!(s.max_degree, 17);
        assert_eq!(s.min_degree, 1);
        // karate is famously clustered
        assert!(s.clustering > 0.2, "clustering {}", s.clustering);
        assert!(s.degree_gini > 0.2, "gini {}", s.degree_gini);
    }

    #[test]
    fn triangle_graph_clustering_is_one() {
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]).unwrap();
        let s = graph_stats(&g, 10);
        assert!((s.clustering - 1.0).abs() < 1e-9);
    }

    #[test]
    fn star_graph_clustering_is_zero() {
        let g = CsrGraph::from_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]).unwrap();
        let s = graph_stats(&g, 10);
        assert_eq!(s.clustering, 0.0);
        assert!(s.degree_gini > 0.0);
    }

    #[test]
    fn histogram_covers_all_nodes() {
        let g = karate_graph();
        let h = degree_histogram(&g);
        assert_eq!(h.iter().map(|&(_, c)| c).sum::<usize>(), 34);
    }

    #[test]
    fn homophily_extremes() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (2, 3)]).unwrap();
        assert_eq!(edge_homophily(&g, &[1, 1, 2, 2]), 1.0);
        assert_eq!(edge_homophily(&g, &[1, 2, 1, 2]), 0.0);
    }

    #[test]
    fn uniform_degree_gini_near_zero() {
        // ring: all degrees equal
        let edges: Vec<(u32, u32)> = (0..10u32).map(|i| (i, (i + 1) % 10)).collect();
        let g = CsrGraph::from_edges(10, &edges).unwrap();
        let s = graph_stats(&g, 10);
        assert!(s.degree_gini.abs() < 1e-9);
    }
}
