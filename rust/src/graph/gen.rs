//! Synthetic graph generation: degree-corrected stochastic block model.
//!
//! Stand-in for the OGB datasets the paper evaluates on (DESIGN.md
//! "Dataset substitution"): community structure that Leiden can detect,
//! power-law degrees, and a spanning backbone that guarantees the single
//! connected component the paper's method presupposes.

use super::builder::GraphBuilder;
use super::csr::{CsrGraph, NodeId};
use crate::error::Result;
use crate::util::rng::{Rng, WeightedSampler};

/// Configuration of the SBM generator.
#[derive(Clone, Debug)]
pub struct SbmConfig {
    /// Number of nodes.
    pub n: usize,
    /// Number of planted communities (≙ label classes downstream).
    pub communities: usize,
    /// Target average degree (edges ≈ n·avg_degree/2).
    pub avg_degree: f64,
    /// Probability an edge stays within its source's community.
    pub p_in: f64,
    /// Pareto tail exponent for degree propensities (≥ 1; higher = flatter).
    pub degree_exponent: f64,
    /// Edge-weight range `(lo, hi)`; `None` → unweighted.
    pub weight_range: Option<(f32, f32)>,
    /// RNG seed.
    pub seed: u64,
}

impl SbmConfig {
    /// arxiv-like defaults (sparse citation-style graph, 40 classes).
    pub fn arxiv_like(n: usize, seed: u64) -> Self {
        SbmConfig {
            n,
            communities: 40,
            avg_degree: 7.0,
            p_in: 0.8,
            degree_exponent: 2.5,
            weight_range: None,
            seed,
        }
    }

    /// proteins-like defaults (dense association graph, weighted edges).
    /// The density *contrast* vs arxiv-like (~9x) mirrors the paper's
    /// 43x contrast at a scale this testbed can train.
    pub fn proteins_like(n: usize, seed: u64) -> Self {
        SbmConfig {
            n,
            communities: 24,
            avg_degree: 64.0,
            p_in: 0.7,
            degree_exponent: 2.0,
            weight_range: Some((0.05, 1.0)),
            seed,
        }
    }
}

/// A generated graph plus its planted community structure.
pub struct SbmGraph {
    pub graph: CsrGraph,
    /// Planted community of each node (drives labels/features downstream).
    pub community: Vec<u32>,
}

/// Generate a degree-corrected SBM graph guaranteed connected.
pub fn generate_sbm(cfg: &SbmConfig) -> Result<SbmGraph> {
    assert!(cfg.n >= cfg.communities.max(2), "n must exceed community count");
    let mut rng = Rng::new(cfg.seed);

    // ---- community assignment: contiguous-ish but shuffled blocks -------
    let mut community = vec![0u32; cfg.n];
    let mut order: Vec<NodeId> = (0..cfg.n as NodeId).collect();
    rng.shuffle(&mut order);
    for (i, &v) in order.iter().enumerate() {
        community[v as usize] = (i * cfg.communities / cfg.n) as u32;
    }
    let mut members: Vec<Vec<NodeId>> = vec![Vec::new(); cfg.communities];
    for v in 0..cfg.n as NodeId {
        members[community[v as usize] as usize].push(v);
    }

    // ---- degree propensities: bounded Pareto ----------------------------
    let theta: Vec<f64> = (0..cfg.n)
        .map(|_| {
            let u = rng.f64().max(1e-12);
            // inverse-CDF of Pareto(x_min = 1, α = degree_exponent), capped
            u.powf(-1.0 / cfg.degree_exponent).min(50.0)
        })
        .collect();
    // Per-community samplers over member propensities (O(log n) draws).
    let comm_samplers: Vec<WeightedSampler> = members
        .iter()
        .map(|ms| {
            WeightedSampler::new(
                &ms.iter().map(|&v| theta[v as usize]).collect::<Vec<_>>(),
            )
        })
        .collect();

    let mut b = GraphBuilder::new(cfg.n);

    // ---- spanning backbone: connected within and across communities -----
    for ms in &members {
        // random chain through each community
        let mut perm = ms.clone();
        rng.shuffle(&mut perm);
        for w in perm.windows(2) {
            add_edge(&mut b, w[0], w[1], cfg, &mut rng);
        }
    }
    for c in 1..cfg.communities {
        // link a random member of community c to one of community c-1
        let u = members[c][rng.index(members[c].len())];
        let v = members[c - 1][rng.index(members[c - 1].len())];
        add_edge(&mut b, u, v, cfg, &mut rng);
    }

    // ---- bulk edges ------------------------------------------------------
    let target_m = ((cfg.n as f64) * cfg.avg_degree / 2.0) as usize;
    let mut attempts = 0usize;
    let max_attempts = target_m * 20;
    let global_sampler = WeightedSampler::new(&theta);
    while b.num_pending_edges() < target_m && attempts < max_attempts {
        attempts += 1;
        let u = global_sampler.sample(&mut rng) as NodeId;
        let cu = community[u as usize] as usize;
        let v = if rng.chance(cfg.p_in) {
            members[cu][comm_samplers[cu].sample(&mut rng)]
        } else {
            let mut c2 = rng.index(cfg.communities);
            if c2 == cu {
                c2 = (c2 + 1) % cfg.communities;
            }
            members[c2][comm_samplers[c2].sample(&mut rng)]
        };
        if u != v && !b.has_edge(u, v) {
            add_edge(&mut b, u, v, cfg, &mut rng);
        }
    }

    let graph = b.build()?;
    Ok(SbmGraph { graph, community })
}

fn add_edge(b: &mut GraphBuilder, u: NodeId, v: NodeId, cfg: &SbmConfig, rng: &mut Rng) {
    if b.has_edge(u, v) || u == v {
        return;
    }
    match cfg.weight_range {
        Some((lo, hi)) => {
            let w = lo + (hi - lo) * rng.f32();
            b.add_weighted(u, v, w);
        }
        None => {
            b.add_edge(u, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components::is_connected;

    #[test]
    fn generates_connected_graph() {
        let cfg = SbmConfig::arxiv_like(2000, 7);
        let g = generate_sbm(&cfg).unwrap();
        assert!(is_connected(&g.graph));
        assert_eq!(g.graph.num_nodes(), 2000);
    }

    #[test]
    fn respects_target_density() {
        let cfg = SbmConfig::arxiv_like(3000, 1);
        let g = generate_sbm(&cfg).unwrap();
        let avg_deg = 2.0 * g.graph.num_edges() as f64 / g.graph.num_nodes() as f64;
        assert!((5.0..9.5).contains(&avg_deg), "avg degree {avg_deg}");
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = SbmConfig::arxiv_like(500, 42);
        let a = generate_sbm(&cfg).unwrap();
        let b = generate_sbm(&cfg).unwrap();
        assert_eq!(a.graph.num_edges(), b.graph.num_edges());
        assert_eq!(a.community, b.community);
        let c = generate_sbm(&SbmConfig::arxiv_like(500, 43)).unwrap();
        assert_ne!(a.community, c.community);
    }

    #[test]
    fn communities_are_assortative() {
        let cfg = SbmConfig::arxiv_like(2000, 3);
        let g = generate_sbm(&cfg).unwrap();
        let mut internal = 0usize;
        let mut total = 0usize;
        for (u, v, _) in g.graph.edges() {
            total += 1;
            if g.community[u as usize] == g.community[v as usize] {
                internal += 1;
            }
        }
        let frac = internal as f64 / total as f64;
        assert!(frac > 0.6, "internal fraction {frac}");
    }

    #[test]
    fn proteins_like_is_dense_and_weighted() {
        let cfg = SbmConfig::proteins_like(800, 5);
        let g = generate_sbm(&cfg).unwrap();
        assert!(g.graph.is_weighted());
        assert!(is_connected(&g.graph));
        let avg_deg = 2.0 * g.graph.num_edges() as f64 / g.graph.num_nodes() as f64;
        assert!(avg_deg > 30.0, "avg degree {avg_deg}");
    }

    #[test]
    fn community_sizes_roughly_balanced() {
        let cfg = SbmConfig::arxiv_like(4000, 11);
        let g = generate_sbm(&cfg).unwrap();
        let mut counts = vec![0usize; cfg.communities];
        for &c in &g.community {
            counts[c as usize] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(*max <= 2 * *min, "min {min} max {max}");
    }
}
