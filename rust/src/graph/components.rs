//! Connected-component analysis — the paper's central structural criterion.
//!
//! Leiden-Fusion guarantees each partition is a *single* connected component
//! with *zero* isolated nodes (paper §4.1); this module provides both the
//! global analysis (union-find over the whole graph) and the per-partition
//! analysis used by the quality metrics (§5.1) and by the "+F" adapter
//! (§5.4), which must split METIS/LPA partitions into their components
//! before fusing.

use super::csr::{CsrGraph, NodeId};

/// Weighted-union + path-halving union-find.
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        UnionFind { parent: (0..n as u32).collect(), size: vec![1; n] }
    }

    pub fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp; // path halving
            x = gp;
        }
        x
    }

    /// Union by size; returns false if already joined.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra as usize] < self.size[rb as usize] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb as usize] = ra;
        self.size[ra as usize] += self.size[rb as usize];
        true
    }

    pub fn component_size(&mut self, x: u32) -> u32 {
        let r = self.find(x);
        self.size[r as usize]
    }
}

/// Result of a component analysis over a node set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ComponentInfo {
    /// Component label per node (dense, 0-based).
    pub labels: Vec<u32>,
    /// Node count of each component.
    pub sizes: Vec<usize>,
    /// Number of degree-0 nodes in the analysed set.
    pub isolated: usize,
}

impl ComponentInfo {
    pub fn num_components(&self) -> usize {
        self.sizes.len()
    }
}

/// Components of the full graph.
pub fn connected_components(g: &CsrGraph) -> ComponentInfo {
    let n = g.num_nodes();
    let mut uf = UnionFind::new(n);
    for u in 0..n as NodeId {
        for &v in g.neighbors(u) {
            if u < v {
                uf.union(u, v);
            }
        }
    }
    finalize(n, |v| uf.find(v), |v| g.degree(v) == 0)
}

/// Components of the subgraph induced by `members` (a mask over the full
/// graph): edges count only when both endpoints are members. This is the
/// per-partition analysis of §5.1.
pub fn components_within(g: &CsrGraph, member: &[bool]) -> ComponentInfo {
    let n = g.num_nodes();
    debug_assert_eq!(member.len(), n);
    let mut uf = UnionFind::new(n);
    for u in 0..n as NodeId {
        if !member[u as usize] {
            continue;
        }
        for &v in g.neighbors(u) {
            if u < v && member[v as usize] {
                uf.union(u, v);
            }
        }
    }
    let ids: Vec<NodeId> = (0..n as NodeId).filter(|&v| member[v as usize]).collect();
    let mut labels = vec![u32::MAX; n];
    let mut sizes = Vec::new();
    let mut isolated = 0usize;
    // lint: allow(nondet_iter) — keyed entry() only, never iterated; labels follow first-encounter order of the sorted ids loop
    let mut remap: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    for &v in &ids {
        let root = uf.find(v);
        let next = remap.len() as u32;
        let label = *remap.entry(root).or_insert(next);
        labels[v as usize] = label;
        if label as usize >= sizes.len() {
            sizes.push(0);
        }
        sizes[label as usize] += 1;
        let has_inner_edge = g.neighbors(v).iter().any(|&u| member[u as usize]);
        if !has_inner_edge {
            isolated += 1;
        }
    }
    ComponentInfo { labels, sizes, isolated }
}

fn finalize(
    n: usize,
    mut root_of: impl FnMut(u32) -> u32,
    mut is_isolated: impl FnMut(u32) -> bool,
) -> ComponentInfo {
    let mut labels = vec![0u32; n];
    let mut sizes = Vec::new();
    // lint: allow(nondet_iter) — keyed entry() only, never iterated; labels follow first-encounter order of the 0..n loop
    let mut remap: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    let mut isolated = 0usize;
    for v in 0..n as u32 {
        let root = root_of(v);
        let next = remap.len() as u32;
        let label = *remap.entry(root).or_insert(next);
        labels[v as usize] = label;
        if label as usize >= sizes.len() {
            sizes.push(0);
        }
        sizes[label as usize] += 1;
        if is_isolated(v) {
            isolated += 1;
        }
    }
    ComponentInfo { labels, sizes, isolated }
}

/// True iff the whole graph is a single connected component with no
/// isolated nodes — the paper's precondition on input graphs.
pub fn is_connected(g: &CsrGraph) -> bool {
    if g.num_nodes() == 0 {
        return true;
    }
    let info = connected_components(g);
    info.num_components() == 1 && info.isolated == 0
}

/// BFS order from `start` restricted to `member` nodes. Used by subgraph
/// extraction and tested against union-find for agreement.
pub fn bfs_within(g: &CsrGraph, start: NodeId, member: &[bool]) -> Vec<NodeId> {
    let mut seen = vec![false; g.num_nodes()];
    let mut queue = std::collections::VecDeque::new();
    let mut order = Vec::new();
    if !member[start as usize] {
        return order;
    }
    seen[start as usize] = true;
    queue.push_back(start);
    while let Some(u) = queue.pop_front() {
        order.push(u);
        for &v in g.neighbors(u) {
            if member[v as usize] && !seen[v as usize] {
                seen[v as usize] = true;
                queue.push_back(v);
            }
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::CsrGraph;

    fn two_triangles() -> CsrGraph {
        // {0,1,2} and {3,4,5} plus isolated node 6
        CsrGraph::from_edges(7, &[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)])
            .unwrap()
    }

    #[test]
    fn finds_components_and_isolated() {
        let info = connected_components(&two_triangles());
        assert_eq!(info.num_components(), 3);
        assert_eq!(info.isolated, 1);
        let mut sizes = info.sizes.clone();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![1, 3, 3]);
    }

    #[test]
    fn single_component_graph() {
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 2)]).unwrap();
        assert!(is_connected(&g));
        let info = connected_components(&g);
        assert_eq!(info.num_components(), 1);
        assert_eq!(info.isolated, 0);
    }

    #[test]
    fn empty_graph_is_connected() {
        assert!(is_connected(&CsrGraph::from_edges(0, &[]).unwrap()));
    }

    #[test]
    fn components_within_mask() {
        let g = CsrGraph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        // select {0, 1, 3}: edge (0,1) survives, 3 becomes isolated
        let info = components_within(&g, &[true, true, false, true]);
        assert_eq!(info.num_components(), 2);
        assert_eq!(info.isolated, 1);
        assert_eq!(info.labels[2], u32::MAX); // non-member
        assert_eq!(info.labels[0], info.labels[1]);
        assert_ne!(info.labels[0], info.labels[3]);
    }

    #[test]
    fn components_within_full_mask_matches_global() {
        let g = two_triangles();
        let full = vec![true; g.num_nodes()];
        let a = components_within(&g, &full);
        let b = connected_components(&g);
        assert_eq!(a.num_components(), b.num_components());
        assert_eq!(a.isolated, b.isolated);
    }

    #[test]
    fn bfs_respects_membership() {
        let g = CsrGraph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]).unwrap();
        let member = [true, true, false, true, true];
        let order = bfs_within(&g, 0, &member);
        assert_eq!(order, vec![0, 1]); // blocked at node 2
        let order2 = bfs_within(&g, 3, &member);
        assert_eq!(order2, vec![3, 4]);
    }

    #[test]
    fn union_find_sizes() {
        let mut uf = UnionFind::new(5);
        assert!(uf.union(0, 1));
        assert!(uf.union(1, 2));
        assert!(!uf.union(0, 2));
        assert_eq!(uf.component_size(2), 3);
        assert_eq!(uf.component_size(4), 1);
    }
}
